//! Property suite over the fabric simulators: conservation, capacity
//! respect, and monotonicity invariants that must hold for ANY random
//! flow set — these are the physics the whole evaluation rests on.

use nimble::coordinator::reassembly::{ChunkArrival, ReassemblyTable};
use nimble::fabric::fluid::{Flow, FluidSim, SimEngine, SolverKind};
use nimble::fabric::packet::{PacketSim, TRACE_DELIVER};
use nimble::fabric::packet_par::PartitionedPacket;
use nimble::fabric::pipeline::PipelineModel;
use nimble::fabric::{FabricParams, Fault, SchedulerKind, XferMode};
use nimble::prop_assert;
use nimble::topology::path::candidates;
use nimble::topology::Topology;
use nimble::util::quickcheck::{check_seeded, Gen};
use nimble::util::rng::Rng;
use std::collections::BTreeMap;

const MB: f64 = 1024.0 * 1024.0;

fn random_flows(g: &mut Gen, topo: &Topology, max_flows: usize) -> Vec<Flow> {
    let n = g.usize(1, max_flows);
    let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
    (0..n)
        .map(|_| {
            let s = rng.below(topo.num_gpus() as u64) as usize;
            let mut d = rng.below(topo.num_gpus() as u64) as usize;
            if d == s {
                d = (d + 1) % topo.num_gpus();
            }
            let cands = candidates(topo, s, d, true);
            let path = rng.choose(&cands).clone();
            let bytes = g.size_log((64 * 1024) as u64, (256 * 1024 * 1024) as u64) as f64;
            let mode = if g.bool() { XferMode::Kernel } else { XferMode::CopyEngine };
            Flow::new(path, bytes).with_mode(mode).at(g.f64(0.0, 2e-3))
        })
        .collect()
}

/// Byte conservation: each flow deposits exactly `bytes` on every hop
/// of its path, nothing more, nothing less, anywhere.
#[test]
fn prop_fluid_conserves_bytes_per_link() {
    let topo = Topology::paper();
    let sim = FluidSim::new(&topo, FabricParams::default());
    check_seeded(0xFAB1, 40, |g| {
        let flows = random_flows(g, &topo, 24);
        let r = sim.run(&flows);
        let mut expect = vec![0.0f64; topo.links.len()];
        for f in &flows {
            for &h in &f.path.hops {
                expect[h] += f.bytes;
            }
        }
        for (i, (&got, &want)) in r.link_bytes.iter().zip(&expect).enumerate() {
            prop_assert!(
                (got - want).abs() <= want.max(1.0) * 1e-6 + 16.0,
                "link {i}: carried {got}, expected {want}"
            );
        }
        Ok(())
    });
}

/// No link ever runs above capacity: utilization ≤ 1 over the run.
#[test]
fn prop_fluid_respects_link_capacity() {
    let topo = Topology::paper();
    let sim = FluidSim::new(&topo, FabricParams::default());
    check_seeded(0xFAB2, 40, |g| {
        let flows = random_flows(g, &topo, 24);
        let r = sim.run(&flows);
        for (link, util) in r.link_utilization(&topo) {
            prop_assert!(util <= 1.0 + 1e-6, "link {link} ran at {util}");
        }
        Ok(())
    });
}

/// Every flow finishes, after its start, and the makespan is at least
/// the naive single-flow lower bound of the largest transfer.
#[test]
fn prop_fluid_flows_all_finish_sanely() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    let sim = FluidSim::new(&topo, params.clone());
    check_seeded(0xFAB3, 40, |g| {
        let flows = random_flows(g, &topo, 16);
        let r = sim.run(&flows);
        for (i, fr) in r.flows.iter().enumerate() {
            prop_assert!(fr.finish_t.is_finite(), "flow {i} never finished");
            prop_assert!(fr.finish_t >= fr.start_t, "flow {i} finished before start");
            // can't beat its own unshared rate ceiling
            let cap =
                params.flow_rate_cap_gbps(&topo, &flows[i].path, flows[i].bytes) * 1e9;
            let min_duration = flows[i].bytes / cap;
            prop_assert!(
                fr.finish_t - fr.start_t >= min_duration * (1.0 - 1e-9),
                "flow {i} ran faster than its rate cap"
            );
        }
        Ok(())
    });
}

/// Fluid monotonicity: adding a competing flow never speeds up the
/// original one.
#[test]
fn prop_fluid_contention_is_monotone() {
    let topo = Topology::paper();
    let sim = FluidSim::new(&topo, FabricParams::default());
    check_seeded(0xFAB4, 30, |g| {
        let base = random_flows(g, &topo, 8);
        let extra = random_flows(g, &topo, 4);
        let r1 = sim.run(&base);
        let mut all = base.clone();
        all.extend(extra);
        let r2 = sim.run(&all);
        for i in 0..base.len() {
            prop_assert!(
                r2.flows[i].finish_t >= r1.flows[i].finish_t - 1e-9,
                "flow {i} got faster with MORE contention: {} vs {}",
                r1.flows[i].finish_t,
                r2.flows[i].finish_t
            );
        }
        Ok(())
    });
}

/// Pipeline monotonicity: more bytes never finish earlier; more
/// credits never finish later.
#[test]
fn prop_pipeline_monotone_in_bytes_and_credits() {
    let topo = Topology::paper();
    check_seeded(0xFAB5, 40, |g| {
        let cands = candidates(&topo, 1, 6, true);
        let path = g.pick(&cands).clone();
        let b1 = g.f64(1.0, 64.0) * MB;
        let b2 = b1 * g.f64(1.1, 4.0);
        let m = PipelineModel::new(&topo, FabricParams::default());
        let t1 = m.transfer(&path, b1, XferMode::Kernel).finish_s;
        let t2 = m.transfer(&path, b2, XferMode::Kernel).finish_s;
        prop_assert!(t2 >= t1, "more bytes finished earlier: {t1} vs {t2}");

        let defaults = FabricParams::default();
        let small = FabricParams {
            p2p_buf_bytes: defaults.chunk_bytes * g.f64(1.0, 3.0),
            ..defaults
        };
        let m_small = PipelineModel::new(&topo, small);
        let t_small = m_small.transfer(&path, b2, XferMode::Kernel).finish_s;
        prop_assert!(
            t_small >= t2 - 1e-12,
            "fewer credits finished earlier: {t2} vs {t_small}"
        );
        Ok(())
    });
}

/// The incremental water-filler is the from-scratch solver, bit for
/// bit: same finish times, same link bytes, same event count — across
/// epoch-sliced runs with randomized mid-flight `preempt`/`add_flows`
/// sequences (the execution-time re-planning mechanism).
#[test]
fn prop_incremental_waterfill_matches_reference() {
    let topo = Topology::paper();

    // replay one schedule of flows + preempt/re-issue actions under a
    // given solver
    fn drive(
        topo: &Topology,
        flows: &[Flow],
        actions: &[(usize, f64, usize)],
        solver: SolverKind,
    ) -> (nimble::fabric::fluid::SimResult, u64) {
        let mut e = SimEngine::new(topo, FabricParams::default(), flows);
        e.set_solver(solver);
        let mut epoch = 0.0003;
        let mut step = 0;
        while !e.is_done() {
            e.advance_to(epoch);
            epoch += 0.0003;
            if let Some(&(victim, frac, alt)) = actions.get(step) {
                step += 1;
                if victim < flows.len() && e.is_live(victim) {
                    let residual = e.preempt(victim);
                    if residual > 1.0 {
                        let f = e.flow(victim).clone();
                        let cands = candidates(topo, f.path.src, f.path.dst, true);
                        let a = cands[alt % cands.len()].clone();
                        let b = cands[(alt + 1) % cands.len()].clone();
                        let now = e.now();
                        e.add_flows(&[
                            Flow::new(a, residual * frac).at(now),
                            Flow::new(b, residual * (1.0 - frac)).at(now),
                        ]);
                    }
                }
            }
            assert!(epoch < 10.0, "runaway simulation");
        }
        (e.result(), e.events())
    }

    check_seeded(0x17C5, 30, |g| {
        let flows = random_flows(g, &topo, 16);
        let n_act = g.usize(0, 3);
        let actions: Vec<(usize, f64, usize)> = (0..n_act)
            .map(|_| (g.usize(0, flows.len() - 1), g.f64(0.3, 0.7), g.usize(0, 5)))
            .collect();
        let (ra, ea) = drive(&topo, &flows, &actions, SolverKind::Incremental);
        let (rb, eb) = drive(&topo, &flows, &actions, SolverKind::Reference);
        prop_assert!(ea == eb, "event counts diverged: {ea} vs {eb}");
        prop_assert!(
            ra.makespan.to_bits() == rb.makespan.to_bits(),
            "makespan diverged: {} vs {}",
            ra.makespan,
            rb.makespan
        );
        for (i, (a, b)) in ra.flows.iter().zip(&rb.flows).enumerate() {
            let same = (a.finish_t.is_nan() && b.finish_t.is_nan())
                || a.finish_t.to_bits() == b.finish_t.to_bits();
            prop_assert!(same, "flow {i} finish diverged");
            prop_assert!(a.bytes.to_bits() == b.bytes.to_bits(), "flow {i} bytes diverged");
        }
        prop_assert!(ra.link_bytes == rb.link_bytes, "link bytes diverged");
        Ok(())
    });
}

/// Smaller flow sets for the packet backend (cells × hops × events):
/// same shape as [`random_flows`], tighter byte range.
fn random_packet_flows(g: &mut Gen, topo: &Topology, max_flows: usize) -> Vec<Flow> {
    let n = g.usize(1, max_flows);
    let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
    (0..n)
        .map(|_| {
            let s = rng.below(topo.num_gpus() as u64) as usize;
            let mut d = rng.below(topo.num_gpus() as u64) as usize;
            if d == s {
                d = (d + 1) % topo.num_gpus();
            }
            let cands = candidates(topo, s, d, true);
            let path = rng.choose(&cands).clone();
            let bytes = g.size_log(256 * 1024, 24 * 1024 * 1024) as f64;
            Flow::new(path, bytes).at(g.f64(0.0, 1e-3))
        })
        .collect()
}

/// Packet backend conserves bytes end-to-end: every flow finishes and
/// deposits exactly `bytes` on every hop of its path — store-and-
/// forward serialization re-sends the full payload per hop, nothing is
/// lost in a queue and nothing is duplicated.
#[test]
fn prop_packet_conserves_bytes_end_to_end() {
    let topo = Topology::paper();
    check_seeded(0x9AC1, 25, |g| {
        let flows = random_packet_flows(g, &topo, 12);
        let mut sim = PacketSim::new(&topo, FabricParams::default(), &flows);
        sim.run_to_completion().expect("fault-free run cannot stall");
        let r = sim.result();
        for (i, fr) in r.flows.iter().enumerate() {
            prop_assert!(fr.finish_t.is_finite(), "flow {i} never delivered");
            prop_assert!(
                (sim.moved_bytes(i) - flows[i].bytes).abs()
                    <= flows[i].bytes * 1e-9 + 1.0,
                "flow {i} delivered {} of {}",
                sim.moved_bytes(i),
                flows[i].bytes
            );
        }
        let mut expect = vec![0.0f64; topo.links.len()];
        for f in &flows {
            for &h in &f.path.hops {
                expect[h] += f.bytes;
            }
        }
        for (i, (&got, &want)) in r.link_bytes.iter().zip(&expect).enumerate() {
            prop_assert!(
                (got - want).abs() <= want.max(1.0) * 1e-6 + 16.0,
                "link {i}: carried {got}, expected {want}"
            );
        }
        Ok(())
    });
}

/// Per-pair chunk sequence numbers survive multi-path delivery: with
/// each pair's payload split across candidate paths (contiguous seq
/// blocks per path, the executor's layout), every path delivers its
/// own seqs in ascending order, and pushing the arrivals into the real
/// [`ReassemblyTable`] in delivery order reassembles every stream
/// completely, with no duplicate/stale rejections.
#[test]
fn prop_packet_chunk_streams_reassemble() {
    let topo = Topology::paper();
    check_seeded(0x9AC2, 15, |g| {
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let mut flows: Vec<Flow> = Vec::new();
        let mut pair_of_flow: Vec<(usize, usize)> = Vec::new();
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        for _ in 0..g.usize(1, 4) {
            let s = rng.below(topo.num_gpus() as u64) as usize;
            let mut d = rng.below(topo.num_gpus() as u64) as usize;
            if d == s {
                d = (d + 1) % topo.num_gpus();
            }
            if pairs.contains(&(s, d)) {
                continue;
            }
            pairs.push((s, d));
            let cands = candidates(&topo, s, d, true);
            let k = g.usize(1, cands.len().min(3));
            for path in cands.into_iter().take(k) {
                flows.push(Flow::new(path, g.f64(2.0, 10.0) * MB));
                pair_of_flow.push((s, d));
            }
        }
        let mut sim = PacketSim::new(&topo, FabricParams::default(), &flows);
        sim.set_trace(true);
        sim.run_to_completion().expect("fault-free run cannot stall");
        // contiguous seq block per flow, concatenated in flow order
        // within each pair (the replan executor's chunk layout)
        let mut next_base: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        let mut flow_base: Vec<u64> = Vec::new();
        let mut pair_chunks: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        for (i, &pair) in pair_of_flow.iter().enumerate() {
            let base = next_base.entry(pair).or_insert(0);
            flow_base.push(*base);
            *base += sim.cells_of(i) as u64;
            *pair_chunks.entry(pair).or_insert(0) = *base;
        }
        let mut reass = ReassemblyTable::default();
        let mut last_idx: Vec<Option<u32>> = vec![None; flows.len()];
        for &(_, code, f, idx) in sim.trace() {
            if code != TRACE_DELIVER {
                continue;
            }
            let f = f as usize;
            // per-path in-order delivery (the §IV ordering promise)
            if let Some(prev) = last_idx[f] {
                prop_assert!(idx == prev + 1, "flow {f} delivered {idx} after {prev}");
            } else {
                prop_assert!(idx == 0, "flow {f} started at chunk {idx}");
            }
            last_idx[f] = Some(idx);
            let (s, d) = pair_of_flow[f];
            reass
                .push(s, d, ChunkArrival { seq: flow_base[f] + idx as u64, bytes: 1 })
                .map_err(|e| format!("reassembly rejected a chunk: {e}"))?;
        }
        prop_assert!(reass.all_drained(), "a stream never fully reassembled");
        for (&(s, d), &chunks) in &pair_chunks {
            let q = reass.stream(s, d).expect("stream exists");
            prop_assert!(
                q.delivered_bytes() == chunks,
                "pair ({s},{d}) delivered {} of {chunks} chunks",
                q.delivered_bytes()
            );
        }
        Ok(())
    });
}

/// Identical seeds ⇒ byte-identical event traces (and therefore
/// bit-identical results) on randomized flow sets — the packet
/// backend's determinism contract.
#[test]
fn prop_packet_identical_seeds_identical_traces() {
    let topo = Topology::paper();
    check_seeded(0x9AC3, 12, |g| {
        let flows = random_packet_flows(g, &topo, 8);
        let seed = g.u64(0, u64::MAX - 1);
        let drive = |seed: u64| {
            let mut params = FabricParams::default();
            params.packet.seed = seed;
            let mut sim = PacketSim::new(&topo, params, &flows);
            sim.set_trace(true);
            sim.run_to_completion().expect("fault-free run cannot stall");
            (sim.trace().to_vec(), sim.result(), sim.events())
        };
        let (ta, ra, ea) = drive(seed);
        let (tb, rb, eb) = drive(seed);
        prop_assert!(ta == tb, "same seed produced different event traces");
        prop_assert!(ea == eb, "event counts diverged");
        prop_assert!(
            ra.makespan.to_bits() == rb.makespan.to_bits(),
            "makespan diverged"
        );
        for (a, b) in ra.flows.iter().zip(&rb.flows) {
            prop_assert!(
                a.finish_t.to_bits() == b.finish_t.to_bits(),
                "finish times diverged"
            );
        }
        prop_assert!(ra.link_bytes == rb.link_bytes, "link bytes diverged");
        Ok(())
    });
}

/// The timing wheel IS the binary heap, bit for bit: identical event
/// traces, event counts, results and tail statistics on randomized
/// flow sets. The heap arm is retained purely as this equivalence
/// oracle (same playbook as `SolverKind::Reference` for the fluid
/// water-filler).
#[test]
fn prop_wheel_matches_heap_bitwise() {
    let topo = Topology::paper();
    check_seeded(0x9AC5, 12, |g| {
        let flows = random_packet_flows(g, &topo, 8);
        let drive = |kind: SchedulerKind| {
            let mut params = FabricParams::default();
            params.packet.scheduler = kind;
            let mut sim = PacketSim::new(&topo, params, &flows);
            sim.set_trace(true);
            sim.run_to_completion().expect("fault-free run cannot stall");
            (sim.trace().to_vec(), sim.result(), sim.events(), sim.tail())
        };
        let (tw, rw, ew, sw) = drive(SchedulerKind::Wheel);
        let (th, rh, eh, sh) = drive(SchedulerKind::Heap);
        prop_assert!(tw == th, "event traces diverged between wheel and heap");
        prop_assert!(ew == eh, "event counts diverged: {ew} vs {eh}");
        prop_assert!(
            rw.makespan.to_bits() == rh.makespan.to_bits(),
            "makespan diverged"
        );
        for (a, b) in rw.flows.iter().zip(&rh.flows) {
            prop_assert!(
                a.finish_t.to_bits() == b.finish_t.to_bits(),
                "finish times diverged"
            );
        }
        prop_assert!(rw.link_bytes == rh.link_bytes, "link bytes diverged");
        prop_assert!(sw.sojourn == sh.sojourn, "sojourn histograms diverged");
        prop_assert!(sw.transit == sh.transit, "transit histograms diverged");
        prop_assert!(
            sw.per_pair_sojourn == sh.per_pair_sojourn,
            "per-pair tails diverged"
        );
        prop_assert!(
            sw.per_tag_sojourn == sh.per_tag_sojourn,
            "per-tag tails diverged"
        );
        prop_assert!(
            sw.peak_queue_bytes == sh.peak_queue_bytes,
            "peak queue depths diverged"
        );
        prop_assert!(
            sw.peak_recv_queue_bytes == sh.peak_recv_queue_bytes,
            "peak receive depths diverged"
        );
        Ok(())
    });
}

/// Wheel == heap also under mid-run fault injection (link down/up plus
/// a straggler node): restore kicks go through `schedule()`, which the
/// wheel must land at the exact same `(t, seq)` key the heap does.
#[test]
fn prop_wheel_matches_heap_under_faults() {
    let topo = Topology::paper();
    check_seeded(0x9AC6, 10, |g| {
        let flows = random_packet_flows(g, &topo, 6);
        let link = g.usize(0, topo.links.len() - 1);
        let node = g.usize(0, topo.nodes - 1);
        let t_down = g.f64(1e-4, 6e-4);
        let t_up = t_down + g.f64(1e-4, 5e-4);
        let drive = |kind: SchedulerKind| {
            let mut params = FabricParams::default();
            params.packet.scheduler = kind;
            let mut sim = PacketSim::new(&topo, params, &flows);
            sim.set_trace(true);
            sim.advance_to(t_down).expect("bounded advance cannot stall");
            sim.apply_fault(&Fault::LinkDown { link });
            sim.advance_to(t_up).expect("bounded advance cannot stall");
            sim.apply_fault(&Fault::LinkUp { link });
            sim.apply_fault(&Fault::StragglerNode { node, inject_factor: 0.5 });
            sim.run_to_completion().expect("restored fabric cannot stall");
            (sim.trace().to_vec(), sim.result(), sim.events())
        };
        let (tw, rw, ew) = drive(SchedulerKind::Wheel);
        let (th, rh, eh) = drive(SchedulerKind::Heap);
        prop_assert!(tw == th, "faulted traces diverged between wheel and heap");
        prop_assert!(ew == eh, "faulted event counts diverged");
        prop_assert!(
            rw.makespan.to_bits() == rh.makespan.to_bits(),
            "faulted makespan diverged"
        );
        prop_assert!(rw.link_bytes == rh.link_bytes, "faulted link bytes diverged");
        Ok(())
    });
}

/// Epoch-sliced `advance_to` is the unbounded `run` on the wheel: the
/// cursor/overflow bookkeeping must not depend on where the epoch
/// boundaries fall (randomized slice widths).
#[test]
fn prop_wheel_epoch_sliced_equals_unbounded() {
    let topo = Topology::paper();
    check_seeded(0x9AC7, 10, |g| {
        let flows = random_packet_flows(g, &topo, 8);
        let mut whole = PacketSim::new(&topo, FabricParams::default(), &flows);
        whole.set_trace(true);
        whole.run_to_completion().expect("fault-free run cannot stall");

        let mut sliced = PacketSim::new(&topo, FabricParams::default(), &flows);
        sliced.set_trace(true);
        let mut epoch = 0.0;
        while !sliced.is_done() {
            epoch += g.f64(5e-5, 6e-4);
            sliced.advance_to(epoch).expect("bounded advance cannot stall");
            prop_assert!(epoch < 10.0, "runaway simulation");
        }
        prop_assert!(
            whole.trace() == sliced.trace(),
            "epoch slicing changed the event trace"
        );
        prop_assert!(whole.events() == sliced.events(), "event counts diverged");
        let (rw, rs) = (whole.result(), sliced.result());
        prop_assert!(
            rw.makespan.to_bits() == rs.makespan.to_bits(),
            "makespan diverged"
        );
        prop_assert!(rw.link_bytes == rs.link_bytes, "link bytes diverged");
        Ok(())
    });
}

/// The partitioned event loop is byte-identical for every thread
/// count: partition structure is input-determined and every merged
/// observable assembles in canonical component order.
#[test]
fn prop_partitioned_thread_count_invariance() {
    let topo = Topology::paper();
    check_seeded(0x9AC8, 8, |g| {
        let flows = random_packet_flows(g, &topo, 10);
        let drive = |threads: usize| {
            let mut params = FabricParams::default();
            params.packet.threads = threads;
            let mut par = PartitionedPacket::new(&topo, params, &flows);
            par.set_trace(true);
            par.run_to_completion().expect("fault-free run cannot stall");
            (par.trace(), par.result(), par.events(), par.tail())
        };
        let (t1, r1, e1, s1) = drive(1);
        for threads in [2usize, 8] {
            let (t, r, e, s) = drive(threads);
            prop_assert!(t1 == t, "trace diverged at threads={threads}");
            prop_assert!(e1 == e, "event count diverged at threads={threads}");
            prop_assert!(
                r1.makespan.to_bits() == r.makespan.to_bits(),
                "makespan diverged at threads={threads}"
            );
            for (a, b) in r1.flows.iter().zip(&r.flows) {
                prop_assert!(
                    a.finish_t.to_bits() == b.finish_t.to_bits(),
                    "finish times diverged at threads={threads}"
                );
            }
            prop_assert!(
                r1.link_bytes == r.link_bytes,
                "link bytes diverged at threads={threads}"
            );
            prop_assert!(
                s1.sojourn == s.sojourn,
                "sojourn histograms diverged at threads={threads}"
            );
            prop_assert!(
                s1.per_pair_sojourn == s.per_pair_sojourn,
                "per-pair tails diverged at threads={threads}"
            );
        }
        Ok(())
    });
}

/// Determinism: identical inputs give bit-identical results (the
/// paper's "preserving ordering, determinism" claim at the sim layer).
#[test]
fn prop_simulators_deterministic() {
    let topo = Topology::paper();
    let sim = FluidSim::new(&topo, FabricParams::default());
    check_seeded(0xFAB6, 20, |g| {
        let flows = random_flows(g, &topo, 12);
        let a = sim.run(&flows);
        let b = sim.run(&flows);
        prop_assert!(a.makespan == b.makespan, "nondeterministic makespan");
        for (x, y) in a.flows.iter().zip(&b.flows) {
            prop_assert!(x.finish_t == y.finish_t, "nondeterministic finish");
        }
        Ok(())
    });
}
