//! Telemetry property tests (DESIGN.md §15): the trace subsystem is a
//! pure observer. Attaching an enabled [`Recorder`] must change no
//! plan or simulation bytes — on either fabric backend, under either
//! packet-event scheduler, at any thread count, with or without a
//! fault schedule, replan loop on or off. And `nimble report --check`
//! must reproduce the headline numbers of `faults` and `serve` runs
//! bit-exactly from the trace alone.

use nimble::coordinator::ReplanExecutor;
use nimble::exp::faults::scenario_rows_traced;
use nimble::exp::scale::plan_flows;
use nimble::exp::serve::run_arm_traced;
use nimble::fabric::faults::scenario_schedule;
use nimble::fabric::{
    make_backend, BackendKind, FabricParams, Scenario, ScenarioParams,
    SchedulerKind,
};
use nimble::orchestrator::{job_stream, MultiTenantExecutor, TenancyCfg};
use nimble::planner::{Planner, PlannerCfg, ReplanCfg};
use nimble::telemetry::{report, Recorder, TraceRecord};
use nimble::topology::Topology;
use nimble::util::hist::{bucket_bounds, bucket_of, bucket_width_ns};
use nimble::util::stats::percentile_nearest_rank;
use nimble::workloads::skew::hotspot_alltoallv;

const MB: f64 = 1024.0 * 1024.0;

fn rcfg(enable: bool) -> ReplanCfg {
    ReplanCfg { enable, cadence_s: 2.0e-4, margin: 0.1, ..ReplanCfg::default() }
}

/// A meta record like the CLI stamps (check() fails closed without one).
fn meta() -> TraceRecord {
    TraceRecord::Meta {
        subcommand: "test".into(),
        backend: "fluid".into(),
        scheduler: "wheel".into(),
        threads: 1,
        topo: "flat".into(),
        nodes: 2,
        links: 0,
        gpus: 8,
    }
}

/// The observer-purity contract on the single-job executor, over the
/// full matrix the issue names: fluid plus packet × {wheel, heap} ×
/// {1, 8 threads}, fault-free and under the flap schedule, replan loop
/// off and on. Trace-on and trace-off runs must agree to the bit on
/// makespan, per-link byte counters, the whole epoch goodput series
/// and the replan/preempt counts — while the enabled recorder actually
/// captures records (a silent no-op would pass vacuously).
#[test]
fn trace_is_a_pure_observer_on_the_replan_executor() {
    let topo = Topology::paper();
    let demands = hotspot_alltoallv(&topo, 48.0 * MB, 0.7, topo.gpu(1, 0));
    let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    let flap = scenario_schedule(
        &topo,
        Scenario::Flap,
        &ScenarioParams::default(),
        Some(&plan.link_load),
    );

    let mut cases = vec![FabricParams::default()];
    for scheduler in [SchedulerKind::Wheel, SchedulerKind::Heap] {
        for threads in [1usize, 8] {
            let mut p = FabricParams { backend: BackendKind::Packet, ..FabricParams::default() };
            p.packet.scheduler = scheduler;
            p.packet.threads = threads;
            cases.push(p);
        }
    }

    for params in &cases {
        for faulted in [false, true] {
            for enable in [false, true] {
                let fly = |rec: Recorder| {
                    let mut ex = ReplanExecutor::new(
                        &topo,
                        params.clone(),
                        PlannerCfg::default(),
                        rcfg(enable),
                    )
                    .with_recorder(rec);
                    if faulted {
                        ex = ex.with_faults(flap.clone());
                    }
                    ex.execute(&plan, &demands)
                };
                let tag = format!(
                    "{:?}/{:?}/t{} faulted={faulted} enable={enable}",
                    params.backend, params.packet.scheduler, params.packet.threads
                );
                let off = fly(Recorder::disabled());
                let rec = Recorder::enabled();
                let on = fly(rec.clone());
                assert!(!rec.is_empty(), "{tag}: enabled recorder captured nothing");
                assert_eq!(
                    off.report.makespan_s.to_bits(),
                    on.report.makespan_s.to_bits(),
                    "{tag}: makespan diverged under tracing"
                );
                assert_eq!(off.replans, on.replans, "{tag}: replans diverged");
                assert_eq!(off.preemptions, on.preemptions, "{tag}: preemptions diverged");
                assert_eq!(off.epochs.len(), on.epochs.len(), "{tag}: epoch count diverged");
                for (a, b) in off.epochs.iter().zip(&on.epochs) {
                    assert_eq!(
                        a.goodput_gbps.to_bits(),
                        b.goodput_gbps.to_bits(),
                        "{tag}: epoch goodput diverged"
                    );
                    assert_eq!(a.replanned, b.replanned, "{tag}: replan epoch moved");
                }
                for (a, b) in off.sim.link_bytes.iter().zip(&on.sim.link_bytes) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{tag}: link bytes diverged");
                }
            }
        }
    }
}

/// The same contract on the multi-tenant orchestrator: joint and
/// independent modes, clean and under the flap schedule. Per-tenant
/// goodput and finish times are part of the bit-identity surface.
#[test]
fn trace_is_a_pure_observer_on_the_orchestrator() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    for joint in [true, false] {
        let tcfg = TenancyCfg { jobs: 6, joint, ..TenancyCfg::default() };
        for faulted in [false, true] {
            let fly = |rec: Recorder| {
                let mut ex = MultiTenantExecutor::new(
                    &topo,
                    params.clone(),
                    PlannerCfg::default(),
                    rcfg(true),
                    tcfg.clone(),
                )
                .with_recorder(rec);
                if faulted {
                    ex = ex.with_faults(scenario_schedule(
                        &topo,
                        Scenario::Flap,
                        &ScenarioParams::default(),
                        None,
                    ));
                }
                ex.execute(job_stream(&topo, &tcfg))
            };
            let tag = format!("joint={joint} faulted={faulted}");
            let off = fly(Recorder::disabled());
            let rec = Recorder::enabled();
            let on = fly(rec.clone());
            assert!(!rec.is_empty(), "{tag}: enabled recorder captured nothing");
            assert_eq!(
                off.makespan_s.to_bits(),
                on.makespan_s.to_bits(),
                "{tag}: makespan diverged under tracing"
            );
            assert_eq!(off.replans, on.replans, "{tag}: replans diverged");
            assert_eq!(off.preemptions, on.preemptions, "{tag}: preemptions diverged");
            assert_eq!(off.epochs.len(), on.epochs.len(), "{tag}: epoch count diverged");
            assert_eq!(off.tenants.len(), on.tenants.len(), "{tag}: tenant count diverged");
            for (a, b) in off.tenants.iter().zip(&on.tenants) {
                assert_eq!(
                    a.goodput_gbps.to_bits(),
                    b.goodput_gbps.to_bits(),
                    "{tag}: tenant goodput diverged"
                );
                assert_eq!(
                    a.finish_s.to_bits(),
                    b.finish_s.to_bits(),
                    "{tag}: tenant finish diverged"
                );
            }
        }
    }
}

/// The conservation invariant of DESIGN.md §16, across the full
/// backend matrix the issue names (fluid plus packet × {wheel, heap} ×
/// {1, 8 threads}): twin engines fly identical multi-tenant flow sets
/// epoch by epoch, one sampling `take_window`, the other
/// `take_window_attr`. Per link and per epoch, (a) the attribution
/// totals are bit-identical to the plain window bytes, and (b) summing
/// the link's blame entries in listed (ascending-key) order reproduces
/// the total bit-exactly. Keys must arrive strictly sorted — the order
/// the conservation sum is defined over.
#[test]
fn blame_decomposition_conserves_window_bytes_bit_exactly() {
    let topo = Topology::paper();
    let demands = hotspot_alltoallv(&topo, 24.0 * MB, 0.7, topo.gpu(1, 0));
    let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    let mut flows = plan_flows(&plan);
    for (i, f) in flows.iter_mut().enumerate() {
        f.tag = (i % 3) as u64 + 1; // several tenants share each hot link
    }

    let mut cases = vec![FabricParams::default()];
    for scheduler in [SchedulerKind::Wheel, SchedulerKind::Heap] {
        for threads in [1usize, 8] {
            let mut p =
                FabricParams { backend: BackendKind::Packet, ..FabricParams::default() };
            p.packet.scheduler = scheduler;
            p.packet.threads = threads;
            cases.push(p);
        }
    }

    for params in &cases {
        let tag = format!(
            "{:?}/{:?}/t{}",
            params.backend, params.packet.scheduler, params.packet.threads
        );
        let mut plain = make_backend(&topo, params.clone(), &flows);
        let mut attr = make_backend(&topo, params.clone(), &flows);
        let mut epoch = 0.0f64;
        let mut shared_link = false;
        while !plain.is_done() {
            epoch += 2.0e-4;
            assert!(epoch < 10.0, "{tag}: runaway simulation");
            plain.advance_to(epoch).expect("bounded advance cannot stall");
            attr.advance_to(epoch).expect("bounded advance cannot stall");
            let w = plain.take_window();
            let a = attr.take_window_attr();
            assert_eq!(w.len(), a.totals.len(), "{tag}: window width diverged");
            assert_eq!(a.blame.len(), a.totals.len(), "{tag}: blame rows missing");
            for (l, x) in w.iter().enumerate() {
                assert_eq!(
                    x.to_bits(),
                    a.totals[l].to_bits(),
                    "{tag}: link {l} window bytes diverged under attribution"
                );
                let entries = &a.blame[l];
                let mut sum = 0.0f64;
                for &(_, b) in entries {
                    sum += b;
                }
                assert_eq!(
                    sum.to_bits(),
                    a.totals[l].to_bits(),
                    "{tag}: link {l} blame sum not conserved"
                );
                for pair in entries.windows(2) {
                    assert!(pair[0].0 < pair[1].0, "{tag}: blame keys out of order");
                }
                if entries.len() > 1 {
                    shared_link = true;
                }
            }
        }
        assert!(
            shared_link,
            "{tag}: vacuous — no link ever had multiple blame contributors"
        );
        attr.run_to_completion().expect("twin finishes too");
        assert_eq!(
            plain.result().makespan.to_bits(),
            attr.result().makespan.to_bits(),
            "{tag}: attribution sampling perturbed the trajectory"
        );
    }
}

/// The histogram error-bound contract under the hard cases: faulted
/// and preempted (replan loop on) packet runs with the `exact_tail`
/// oracle enabled. Each headline quantile must be the lower boundary
/// of exactly the bucket holding the exact nearest-rank sample —
/// i.e. within one bucket width (≤ 3.2% relative) of the truth.
#[test]
fn histogram_quantiles_match_exact_oracle_under_faults_and_preemption() {
    let topo = Topology::paper();
    let demands = hotspot_alltoallv(&topo, 48.0 * MB, 0.7, topo.gpu(1, 0));
    let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    let flap = scenario_schedule(
        &topo,
        Scenario::Flap,
        &ScenarioParams::default(),
        Some(&plan.link_load),
    );
    let mut params =
        FabricParams { backend: BackendKind::Packet, ..FabricParams::default() };
    params.packet.exact_tail = true;

    let mut saw_preemption = false;
    for faulted in [false, true] {
        let mut ex = ReplanExecutor::new(
            &topo,
            params.clone(),
            PlannerCfg::default(),
            rcfg(true),
        );
        if faulted {
            ex = ex.with_faults(flap.clone());
        }
        let out = ex.execute(&plan, &demands);
        saw_preemption |= out.preemptions > 0;
        let tail = out.tail.expect("packet backend records tails");
        assert_eq!(
            tail.sojourn_exact_s.len() as u64,
            tail.sojourn.total(),
            "faulted={faulted}: oracle sample count != histogram total"
        );
        assert_eq!(
            tail.transit_exact_s.len() as u64,
            tail.transit.total(),
            "faulted={faulted}: transit oracle count != histogram total"
        );
        for (name, hist, exact) in [
            ("sojourn", &tail.sojourn, &tail.sojourn_exact_s),
            ("transit", &tail.transit, &tail.transit_exact_s),
        ] {
            for q in [50.0, 95.0, 99.0] {
                let truth_ns =
                    (percentile_nearest_rank(exact, q) * 1e9).round() as u64;
                let got = hist.quantile_ns(q);
                assert_eq!(
                    got,
                    bucket_bounds(bucket_of(truth_ns)).0,
                    "faulted={faulted} {name} p{q}: {got} vs exact {truth_ns}"
                );
                assert!(
                    got <= truth_ns && truth_ns - got <= bucket_width_ns(truth_ns),
                    "faulted={faulted} {name} p{q}: outside one bucket width"
                );
            }
            let max_ns = (exact
                .iter()
                .cloned()
                .fold(0.0f64, f64::max)
                * 1e9)
                .round() as u64;
            assert_eq!(hist.max_ns(), max_ns, "faulted={faulted} {name}: max drifted");
        }
    }
    assert!(
        saw_preemption,
        "vacuous — the flap schedule never forced a preemption"
    );
}

/// Drain an enabled recorder into JSONL text exactly as
/// `Recorder::write_jsonl` would lay it down on disk.
fn jsonl(rec: &Recorder) -> String {
    rec.lines().iter().map(|l| l.to_string_compact()).collect::<Vec<_>>().join("\n")
}

/// `nimble report --check` on a faults trace: every retention and
/// time-to-recover headline recomputes bit-exactly from the raw
/// ingredients the trace records (clean goodput, per-arm goodput, the
/// per-epoch goodput series), and the rendered report reproduces the
/// faults headline table.
#[test]
fn report_check_reproduces_faults_headlines_bit_exactly() {
    let rec = Recorder::enabled();
    rec.emit(meta);
    let topo = Topology::paper();
    let (_clean, rows) = scenario_rows_traced(
        "flat",
        &topo,
        48.0 * MB,
        &FabricParams::default(),
        &PlannerCfg::default(),
        &ScenarioParams::default(),
        &[Scenario::Flap, Scenario::Degrade],
        true,
        &rec,
    );
    assert_eq!(rows.len(), 2 * 3, "two scenarios x (static | replan | ecmp)");

    let text = jsonl(&rec);
    let trace = report::Trace::parse(&text).expect("traced faults run must parse");
    let rendered = report::render(&trace);
    assert!(
        rendered.contains("faults headline (reproduced from trace)"),
        "report did not reproduce the faults table:\n{rendered}"
    );
    let out = report::check(&trace);
    assert!(
        out.ok(),
        "check failed ({} checks): {:?}",
        out.checks,
        out.errors
    );
    // the ttr recomputation path actually ran: the trace holds fault
    // rows bound to runs with a fault epoch and a goodput series
    assert!(
        out.checks > trace_lines(&text),
        "no derived-headline recomputation beyond the per-line schema pass"
    );
}

fn trace_lines(text: &str) -> usize {
    text.lines().filter(|l| !l.trim().is_empty()).count()
}

/// The same closed loop on a serve trace: per-tenant goodput and the
/// aggregate summary recompute bit-exactly from admit/finish times and
/// payload bytes.
#[test]
fn report_check_reproduces_serve_headlines_bit_exactly() {
    let rec = Recorder::enabled();
    rec.emit(meta);
    let topo = Topology::paper();
    let tcfg = TenancyCfg { jobs: 6, ..TenancyCfg::default() };
    let run = run_arm_traced(
        &topo,
        &FabricParams::default(),
        &PlannerCfg::default(),
        &ReplanCfg::default(),
        &tcfg,
        &rec,
        "joint",
    );
    assert_eq!(run.tenants.len(), tcfg.jobs);

    let text = jsonl(&rec);
    assert!(text.contains("\"kind\":\"tenant\""), "serve trace lost its tenant rows");
    let trace = report::Trace::parse(&text).expect("traced serve run must parse");
    let out = report::check(&trace);
    assert!(
        out.ok(),
        "check failed ({} checks): {:?}",
        out.checks,
        out.errors
    );
    assert!(!report::render(&trace).is_empty());
}
