//! Cross-module integration + property tests: planner ↔ fabric ↔
//! coordinator invariants over randomized workloads, fluid ↔ pipeline
//! model agreement, and bound checks against Dinic max-flow.

use nimble::baselines::{run_round, MpiLike, NcclLike, Router, SinglePath};
use nimble::coordinator::{NimbleRouter, Orchestrator, ReplanExecutor};
use nimble::fabric::fluid::{Flow, FluidSim};
use nimble::fabric::pipeline::PipelineModel;
use nimble::fabric::{FabricParams, XferMode};
use nimble::planner::maxflow::max_rate_to_destination;
use nimble::planner::{lower_bound_norm_load, Demand, Planner, PlannerCfg, ReplanCfg};
use nimble::prop_assert;
use nimble::topology::path::candidates;
use nimble::topology::Topology;
use nimble::util::quickcheck::{check_seeded, Gen};
use nimble::util::rng::Rng;
use nimble::workloads::dynamic::PhasedHotRows;
use nimble::workloads::skew::hotspot_alltoallv_jittered;

const MB: f64 = 1024.0 * 1024.0;

/// Random demand set generator over the paper topology.
fn random_demands(g: &mut Gen, topo: &Topology) -> Vec<Demand> {
    let n = g.usize(1, 20);
    let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
    (0..n)
        .map(|_| {
            let s = rng.below(topo.num_gpus() as u64) as usize;
            let mut d = rng.below(topo.num_gpus() as u64) as usize;
            if d == s {
                d = (d + 1) % topo.num_gpus();
            }
            Demand::new(s, d, g.size_log(64 * 1024, 512 * 1024 * 1024) as f64)
        })
        .collect()
}

/// Property: every plan over random demand sets validates (demand
/// conservation, path validity, consistent link loads) and respects
/// the analytic lower bound.
#[test]
fn prop_plans_always_valid_and_bounded() {
    let topo = Topology::paper();
    check_seeded(0xA11D, 60, |g| {
        let demands = random_demands(g, &topo);
        let mut planner = Planner::new(&topo, PlannerCfg::default());
        let plan = planner.plan(&demands);
        plan.validate(&topo, &demands)?;
        let z = plan.max_norm_load(&topo);
        let lb = lower_bound_norm_load(&topo, &demands);
        prop_assert!(z >= lb - 1e-9, "plan beat the lower bound: z={z} lb={lb}");
        prop_assert!(z <= lb * 3.0 + 1e-3, "plan too far from bound: z={z} lb={lb}");
        Ok(())
    });
}

/// Property: NIMBLE never loses to the single-path baseline by more
/// than simulator noise, on any random hotspot workload.
#[test]
fn prop_nimble_never_regresses_vs_single_path() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    check_seeded(77, 20, |g| {
        let ratio = g.f64(0.125, 0.95);
        let payload = g.f64(4.0, 128.0) * MB;
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let (_, demands) = hotspot_alltoallv_jittered(&topo, payload, ratio, &mut rng);
        let base = run_round(&topo, &params, &mut SinglePath::new(), &demands);
        let nim =
            run_round(&topo, &params, &mut NimbleRouter::default_for(&topo), &demands);
        // NIMBLE may give back a few % in endpoint-bound moderate-skew
        // cases (the paper's own "enable rule" §V-D recommends the
        // baseline for mild skew); it must never collapse.
        prop_assert!(
            nim.makespan_s <= base.makespan_s * 1.12,
            "regression at ratio {ratio:.2}, payload {:.0} MB: {} vs {}",
            payload / MB,
            nim.makespan_s,
            base.makespan_s
        );
        Ok(())
    });
}

/// Property: the goodput any engine achieves toward a single hot
/// destination never exceeds the Dinic max-flow ceiling.
#[test]
fn prop_goodput_within_maxflow_ceiling() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    check_seeded(99, 12, |g| {
        let hot = g.usize(0, topo.num_gpus() - 1);
        let payload = g.f64(32.0, 256.0) * MB;
        let sources: Vec<usize> =
            (0..topo.num_gpus()).filter(|&s| s != hot).collect();
        let demands: Vec<Demand> =
            sources.iter().map(|&s| Demand::new(s, hot, payload)).collect();
        let ceiling_gbps = max_rate_to_destination(&topo, &sources, hot);
        for router in [
            &mut NimbleRouter::default_for(&topo) as &mut dyn Router,
            &mut NcclLike::new(),
            &mut MpiLike::new(),
        ] {
            let rep = run_round(&topo, &params, router, &demands);
            let goodput = rep.goodput_gbps();
            prop_assert!(
                goodput <= ceiling_gbps * 1.01,
                "{} exceeded max-flow ceiling: {goodput:.1} > {ceiling_gbps:.1} GB/s",
                rep.engine
            );
        }
        Ok(())
    });
}

/// Fluid and chunk-pipeline models agree on single-flow steady state
/// (same bottleneck physics, independent implementations).
#[test]
fn fluid_and_pipeline_models_agree() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    let fluid = FluidSim::new(&topo, params.clone());
    let pipe = PipelineModel::new(&topo, params.clone());
    for (s, d) in [(0usize, 1usize), (0, 4), (1, 6)] {
        for path in candidates(&topo, s, d, true) {
            let bytes = 256.0 * MB;
            let f = fluid.run(&[Flow::new(path.clone(), bytes)]);
            let bw_fluid = bytes / f.makespan / 1e9;
            let bw_pipe = pipe.bandwidth_gbps(&path, bytes, XferMode::Kernel);
            let ratio = bw_pipe / bw_fluid;
            assert!(
                (0.8..1.25).contains(&ratio),
                "models disagree on {:?}: fluid {bw_fluid:.1} vs pipe {bw_pipe:.1}",
                path.kind
            );
        }
    }
}

/// Multi-round adaptive soak: orchestrator handles 20 rounds of
/// shifting hotspots without violating ordering/channel invariants,
/// and its makespans stay within the static planner's ballpark.
#[test]
fn adaptive_soak_over_shifting_hotspots() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    let mut orch = Orchestrator::new(&topo, params.clone());
    let mut rng = Rng::new(2026);
    let mut buffers = Vec::new();
    for round in 0..20 {
        let (_, demands) =
            hotspot_alltoallv_jittered(&topo, 48.0 * MB, 0.5 + 0.4 * rng.f64(), &mut rng);
        let out = orch.run_round(&demands);
        assert!(out.report.makespan_s > 0.0, "round {round} produced nothing");
        buffers.push(out.channel_buffer_bytes);
    }
    // staging memory must plateau (peer-exclusive channels)
    let last = *buffers.last().unwrap();
    assert_eq!(buffers[buffers.len() - 2], last);
    assert_eq!(buffers[buffers.len() - 5], last);
}

/// The monitor-driven adaptive path beats cold planning when a
/// persistent background flow occupies the direct link.
#[test]
fn adaptation_beats_cold_planning_under_background_load() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    // background: a permanent (2→1) stream loading nvlink(2,1); the
    // (0→1) pair's best 2-hop detour via 2 is then worse than via 3.
    let bg_link = topo.nvlink(2, 1).unwrap();
    let mut router = NimbleRouter::adaptive_for(&topo);
    let mut bg = vec![0.0; topo.links.len()];
    bg[bg_link] = 2e9;
    for _ in 0..6 {
        router.monitor.observe(&bg);
    }
    let demands = vec![Demand::new(0, 1, 256.0 * MB)];
    let flows = router.route(&topo, &demands);
    let via2: f64 = flows
        .iter()
        .filter(|(p, _)| p.hops.contains(&bg_link))
        .map(|(_, b)| b)
        .sum();
    let via3: f64 = flows
        .iter()
        .filter(|(p, _)| {
            matches!(p.kind, nimble::topology::PathKind::IntraTwoHop { via: 3 })
        })
        .map(|(_, b)| b)
        .sum();
    assert!(
        via3 > via2,
        "adaptive plan should prefer the unloaded relay: via3={via3} via2={via2}"
    );
}

/// Regression: same topology + demand set + seed ⇒ byte-identical
/// `Plan` (assignments AND link loads), for the cold `plan()` path,
/// for a reused planner (warm candidate cache), and for the
/// `plan_with_initial` warm-start path used by `Orchestrator` and
/// `exp::interference`. Guards the paper's determinism claim at the
/// planner layer (the simulator twin lives in fabric_props.rs).
#[test]
fn planner_is_deterministic_cold_and_warm() {
    let topo = Topology::paper();
    let mut rng = Rng::new(0xD17E);
    let (_, demands) = hotspot_alltoallv_jittered(&topo, 96.0 * MB, 0.7, &mut rng);

    fn assert_identical(a: &nimble::planner::Plan, b: &nimble::planner::Plan) {
        assert_eq!(a.link_load, b.link_load, "link loads differ");
        assert_eq!(a.assignments.len(), b.assignments.len(), "pair sets differ");
        for ((ka, aa), (kb, ab)) in a.assignments.iter().zip(b.assignments.iter()) {
            assert_eq!(ka, kb, "pair keys diverge");
            assert_eq!(aa.parts.len(), ab.parts.len(), "part counts differ on {ka:?}");
            for ((pa, ba), (pb, bb)) in aa.parts.iter().zip(ab.parts.iter()) {
                assert_eq!(pa, pb, "paths differ on {ka:?}");
                assert_eq!(
                    ba.to_bits(),
                    bb.to_bits(),
                    "bytes not bit-identical on {ka:?}: {ba} vs {bb}"
                );
            }
        }
    }

    // cold: two fresh planners
    let p1 = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    let p2 = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    assert_identical(&p1, &p2);

    // reused planner (warm candidate cache, the re-planning hot path)
    let mut reused = Planner::new(&topo, PlannerCfg::default());
    let _ = reused.plan(&demands);
    let p3 = reused.plan(&demands);
    assert_identical(&p1, &p3);

    // warm-started from observed link loads (execution-time adaptation)
    let mut initial = vec![0.0; topo.links.len()];
    initial[topo.nvlink(0, 1).unwrap()] = 3e9;
    initial[topo.rail(0, 1, 2).unwrap()] = 1.5e9;
    let w1 = Planner::new(&topo, PlannerCfg::default())
        .plan_with_initial(&demands, Some(&initial));
    let w2 = Planner::new(&topo, PlannerCfg::default())
        .plan_with_initial(&demands, Some(&initial));
    assert_identical(&w1, &w2);
    w1.validate(&topo, &demands).unwrap();
    // sanity: the warm start actually steers routing, so the two legs
    // of this test exercise distinct planner paths
    assert_ne!(w1.link_load, p1.link_load, "warm start had no effect");
}

/// The parallel-sweep contract, end to end: serializing the `Plan`
/// produced at thread counts {1, 2, 8} yields byte-identical strings —
/// on a seeded skewed workload (one fully-coupled component), on a
/// decomposable multi-component workload, and on the warm-started
/// challenger path the replan loop uses.
#[test]
fn planner_output_byte_identical_across_thread_counts() {
    let topo = Topology::paper();
    let mut rng = Rng::new(0xBEEF);
    let (_, skewed) = hotspot_alltoallv_jittered(&topo, 96.0 * MB, 0.7, &mut rng);
    let decomposable = vec![
        Demand::new(0, 1, 512.0 * MB),
        Demand::new(2, 3, 300.0 * MB),
        Demand::new(4, 5, 512.0 * MB),
        Demand::new(6, 7, 96.0 * MB),
        Demand::new(1, 6, 256.0 * MB),
    ];
    let mut initial = vec![0.0; topo.links.len()];
    initial[topo.nvlink(0, 1).unwrap()] = 2.0e9;

    for demands in [&skewed, &decomposable] {
        let with_threads = |t: usize| {
            let cfg = PlannerCfg { threads: t, ..PlannerCfg::default() };
            let mut planner = Planner::new(&topo, cfg);
            let cold = planner.plan(demands).canonical_string();
            let warm = planner
                .plan_with_initial(demands, Some(&initial))
                .canonical_string();
            (cold, warm)
        };
        let (cold1, warm1) = with_threads(1);
        for t in [2, 8] {
            let (cold, warm) = with_threads(t);
            assert_eq!(cold, cold1, "cold plan diverged at {t} threads");
            assert_eq!(warm, warm1, "warm plan diverged at {t} threads");
        }
    }
}

/// `configs/paper.toml` keeps `[planner] threads = 1` and therefore
/// reproduces the pre-threads seeded plans bitwise: the loaded config
/// must plan exactly like the built-in defaults (the serial code path).
#[test]
fn paper_config_reproduces_seeded_plans_bitwise() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/paper.toml");
    let cfg = nimble::config::Config::load(path).unwrap();
    assert_eq!(cfg.planner.threads, 1, "paper config must stay on the serial sweep");
    let topo = Topology::paper();
    let mut rng = Rng::new(0xD17E);
    let (_, demands) = hotspot_alltoallv_jittered(&topo, 96.0 * MB, 0.7, &mut rng);
    let from_file = Planner::new(&topo, cfg.planner.clone())
        .plan(&demands)
        .canonical_string();
    let builtin = Planner::new(&topo, PlannerCfg::default())
        .plan(&demands)
        .canonical_string();
    assert_eq!(from_file, builtin, "paper.toml drifted from the reference planner");
}

/// Execution-time loop soak: many rounds of jittered, phase-shifting
/// hot rows through the monitor → replan → reroute path. The executor
/// itself asserts the reassembly ordering invariant on every round
/// (including across mid-flight reroutes); here we additionally check
/// that re-planning fires on shifted rounds and never loses to the
/// static stale plan by more than simulator noise.
#[test]
fn replan_loop_soak_over_shifting_hot_rows() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    let mut sched = PhasedHotRows::paper_default(&topo, 48.0 * MB);
    sched.period = 1;
    let rcfg =
        ReplanCfg { enable: true, cadence_s: 4.0e-4, margin: 0.1, ..ReplanCfg::default() };
    let mut stale = Planner::new(&topo, PlannerCfg::default())
        .plan(&sched.demands_at(&topo, 0));
    let mut rng = Rng::new(0x5EED);
    let mut replans_total = 0usize;
    let mut exec =
        ReplanExecutor::new(&topo, params.clone(), PlannerCfg::default(), rcfg.clone());
    let mut static_exec = ReplanExecutor::new(
        &topo,
        params.clone(),
        PlannerCfg::default(),
        ReplanCfg { enable: false, ..rcfg },
    );
    for round in 0..8 {
        let demands = sched.demands_at_jittered(&topo, round, &mut rng);
        let dynamic = exec.execute(&stale, &demands);
        let static_run = static_exec.execute(&stale, &demands);
        replans_total += dynamic.replans;
        assert!(
            dynamic.report.makespan_s <= static_run.report.makespan_s * 1.05,
            "round {round}: loop regressed {} vs {}",
            dynamic.report.makespan_s,
            static_run.report.makespan_s
        );
        stale = dynamic.final_plan.clone();
    }
    assert!(replans_total >= 4, "loop barely fired: {replans_total} replans");
}

/// PR-5 anchor: a 1-job stream with joint planning disabled is
/// bit-identical to the PR-2 `ReplanExecutor` — with the per-tenant
/// replan loop ENABLED as well as on the static (disabled) path. The
/// orchestrator generalizes the single-job executor; this pins that it
/// never diverges from it.
#[test]
fn single_tenant_stream_matches_replan_executor_bitwise() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    let tcfg = nimble::orchestrator::TenancyCfg {
        jobs: 1,
        joint: false,
        ..nimble::orchestrator::TenancyCfg::default()
    };
    for enable in [false, true] {
        let rcfg = ReplanCfg { enable, cadence_s: 5.0e-4, ..ReplanCfg::default() };
        let jobs = nimble::orchestrator::job_stream(&topo, &tcfg);
        let run = nimble::orchestrator::MultiTenantExecutor::new(
            &topo,
            params.clone(),
            PlannerCfg::default(),
            rcfg.clone(),
            tcfg.clone(),
        )
        .execute(jobs.clone());
        let demands = jobs[0].demands(&topo);
        let incumbent = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
        let reference =
            ReplanExecutor::new(&topo, params.clone(), PlannerCfg::default(), rcfg)
                .execute(&incumbent, &demands);
        assert_eq!(
            run.makespan_s.to_bits(),
            reference.report.makespan_s.to_bits(),
            "makespan diverged (enable={enable})"
        );
        assert_eq!(run.sim.link_bytes.len(), reference.sim.link_bytes.len());
        for (i, (a, b)) in
            run.sim.link_bytes.iter().zip(&reference.sim.link_bytes).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "link {i} bytes (enable={enable})");
        }
        assert_eq!(run.sim.flows.len(), reference.sim.flows.len());
        for (a, b) in run.sim.flows.iter().zip(&reference.sim.flows) {
            assert_eq!(a.start_t.to_bits(), b.start_t.to_bits());
            assert_eq!(a.finish_t.to_bits(), b.finish_t.to_bits());
            assert_eq!(a.bytes.to_bits(), b.bytes.to_bits());
        }
        assert_eq!(run.replans, reference.replans, "replans (enable={enable})");
        assert_eq!(run.preemptions, reference.preemptions);
    }
}

/// PR-5 determinism: the full 8-job serve stream is byte-identical run
/// to run AND across planner thread counts {1, 8}, in both joint and
/// independent modes (the acceptance criterion's thread clause).
#[test]
fn serve_stream_byte_identical_across_runs_and_threads() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    for joint in [true, false] {
        let tcfg = nimble::orchestrator::TenancyCfg {
            joint,
            ..nimble::orchestrator::TenancyCfg::default()
        };
        let rcfg = ReplanCfg { enable: true, ..ReplanCfg::default() };
        let run = |threads: usize| {
            let pcfg = PlannerCfg { threads, ..PlannerCfg::default() };
            let jobs = nimble::orchestrator::job_stream(&topo, &tcfg);
            nimble::orchestrator::MultiTenantExecutor::new(
                &topo,
                params.clone(),
                pcfg,
                rcfg.clone(),
                tcfg.clone(),
            )
            .execute(jobs)
        };
        let a = run(1);
        let b = run(1);
        let c = run(8);
        for (name, other) in [("rerun", &b), ("threads=8", &c)] {
            assert_eq!(
                a.makespan_s.to_bits(),
                other.makespan_s.to_bits(),
                "{name} makespan diverged (joint={joint})"
            );
            assert_eq!(a.replans, other.replans, "{name} (joint={joint})");
            assert_eq!(a.preemptions, other.preemptions);
            for (x, y) in a.sim.link_bytes.iter().zip(&other.sim.link_bytes) {
                assert_eq!(x.to_bits(), y.to_bits(), "{name} link bytes");
            }
            assert_eq!(a.tenants.len(), other.tenants.len());
            for (x, y) in a.tenants.iter().zip(&other.tenants) {
                assert_eq!(x.goodput_gbps.to_bits(), y.goodput_gbps.to_bits());
                assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
                assert_eq!(x.p99_lat_s.to_bits(), y.p99_lat_s.to_bits());
                assert_eq!(x.peak_reassembly, y.peak_reassembly);
            }
        }
    }
}

/// PR-5 reroute-under-contention: the default joint stream preempts
/// mid-flight (the executor asserts every tenant's reassembly ordering
/// on each push — reaching the end IS the invariant check), buffers
/// out-of-order chunks, and every tenant's stream drains completely.
#[test]
fn serve_reroutes_under_contention_keep_tenant_ordering() {
    let topo = Topology::paper();
    let tcfg = nimble::orchestrator::TenancyCfg::default();
    let jobs = nimble::orchestrator::job_stream(&topo, &tcfg);
    let run = nimble::orchestrator::MultiTenantExecutor::new(
        &topo,
        FabricParams::default(),
        PlannerCfg::default(),
        ReplanCfg::default(),
        tcfg,
    )
    .execute(jobs);
    assert!(run.replans >= 1, "joint rebalance never fired");
    assert!(run.preemptions >= 1, "no flow was preempted");
    assert!(run.peak_reassembly >= 1, "no out-of-order buffering observed");
    for t in &run.tenants {
        assert!(t.goodput_gbps > 0.0, "tenant {} starved", t.id);
        assert!(t.finish_s > t.admit_s, "tenant {} never flew", t.id);
    }
    // payload conservation across the shared fabric: every tenant's
    // delivered flow bytes sum to its payload (reassembly already
    // asserted chunk-exactness inside execute)
    let delivered: f64 = run.sim.flows.iter().map(|f| f.bytes).sum();
    assert!(
        (delivered - run.payload_bytes).abs() < 64.0,
        "delivered {delivered} vs payload {}",
        run.payload_bytes
    );
}

/// PR-7 recovery ordering: kill the hottest planned link mid-round (a
/// flap on the link the static plan leans on hardest). The replan loop
/// preempts the frozen flows and re-routes their residuals; the
/// executor replays every rerouted chunk through the real
/// `ReassemblyTable` and asserts in-order delivery plus per-stream
/// chunk exactness on completion — reaching the end IS the ordering
/// check; `peak_reassembly` proves chunks really arrived out of order
/// across the reroute. Recovery must not lose goodput to the static
/// plan, which can only wait out the outage.
#[test]
fn fault_flap_recovery_preserves_ordering_and_goodput() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    let mut rng = Rng::new(0xFA171);
    let (_, demands) = hotspot_alltoallv_jittered(&topo, 64.0 * MB, 0.7, &mut rng);
    let payload: f64 = demands.iter().map(|d| d.bytes).sum();
    let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    let sched = nimble::fabric::faults::scenario_schedule(
        &topo,
        nimble::fabric::Scenario::Flap,
        &nimble::fabric::ScenarioParams::default(),
        Some(&plan.link_load),
    );
    let replan_run = ReplanExecutor::new(
        &topo,
        params.clone(),
        PlannerCfg::default(),
        ReplanCfg { enable: true, cadence_s: 2.0e-4, margin: 0.1, ..ReplanCfg::default() },
    )
    .with_faults(sched.clone())
    .execute(&plan, &demands);
    let static_run = ReplanExecutor::new(
        &topo,
        params.clone(),
        PlannerCfg::default(),
        ReplanCfg { enable: false, cadence_s: 2.0e-4, ..ReplanCfg::default() },
    )
    .with_faults(sched)
    .execute(&plan, &demands);

    assert!(replan_run.replans >= 1, "dead link did not force a replan");
    assert!(replan_run.preemptions >= 1, "no frozen flow was preempted");
    assert!(
        replan_run.peak_reassembly >= 1,
        "no out-of-order buffering across the recovery reroute"
    );
    for (arm, run) in [("replan", &replan_run), ("static", &static_run)] {
        let delivered: f64 = run.sim.flows.iter().map(|f| f.bytes).sum();
        assert!(
            (delivered - payload).abs() < 64.0,
            "{arm} lost bytes across the flap: {delivered} vs {payload}"
        );
    }
    assert!(
        replan_run.report.makespan_s <= static_run.report.makespan_s,
        "recovery lost to waiting out the outage: {} vs {}",
        replan_run.report.makespan_s,
        static_run.report.makespan_s
    );
}

/// Balanced-parity integration check across all engines (paper
/// abstract: "matching baseline performance under balanced traffic").
#[test]
fn balanced_alltoall_parity_all_engines() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    let demands = nimble::workloads::skew::uniform_alltoall(&topo, 56.0 * MB);
    let nccl = run_round(&topo, &params, &mut NcclLike::new(), &demands).makespan_s;
    let nim =
        run_round(&topo, &params, &mut NimbleRouter::default_for(&topo), &demands)
            .makespan_s;
    let ratio = nccl / nim;
    assert!(
        (0.95..1.35).contains(&ratio),
        "balanced parity violated: nimble {ratio:.3}× vs nccl"
    );
}
