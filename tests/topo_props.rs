//! Property suite over the topology layer's tier-walk refactor and the
//! ECMP hash-striping adversary: the flat-identity anchor (the tier
//! walk must reproduce the pre-tier candidate enumeration byte for
//! byte on every flat fabric) and the determinism/coverage contracts
//! of the hash-based baseline.

use nimble::baselines::{EcmpHash, Router};
use nimble::planner::Demand;
use nimble::prop_assert;
use nimble::topology::path::{candidates, Path, PathKind};
use nimble::topology::Topology;
use nimble::util::quickcheck::check_seeded;

const MB: f64 = 1024.0 * 1024.0;

/// The candidate enumeration exactly as it existed before the tier
/// walk: intra-node direct + two-hop relays, inter-node one
/// rail-matched path per rail over the single flat NIC edge. Kept here
/// verbatim as the reference the refactored [`candidates`] must
/// reproduce bit for bit on flat topologies.
fn legacy_flat_candidates(
    topo: &Topology,
    s: usize,
    d: usize,
    allow_multipath: bool,
) -> Vec<Path> {
    assert!(topo.tier.is_none(), "legacy enumeration is flat-only");
    let mut out = Vec::new();
    if topo.same_node(s, d) {
        let direct = topo.nvlink(s, d).expect("all-to-all NVLink mesh");
        out.push(Path { src: s, dst: d, kind: PathKind::IntraDirect, hops: vec![direct] });
        if allow_multipath && !topo.nvswitch {
            let node = topo.node_of(s);
            for local in 0..topo.gpus_per_node {
                let i = topo.gpu(node, local);
                if i == s || i == d {
                    continue;
                }
                out.push(Path {
                    src: s,
                    dst: d,
                    kind: PathKind::IntraTwoHop { via: i },
                    hops: vec![topo.nvlink(s, i).unwrap(), topo.nvlink(i, d).unwrap()],
                });
            }
        }
    } else {
        let (na, nb) = (topo.node_of(s), topo.node_of(d));
        let rails: Vec<usize> = if allow_multipath {
            (0..topo.nics_per_node).collect()
        } else {
            vec![topo.home_rail(s)]
        };
        for r in rails {
            let g_ra = topo.gpu(na, r);
            let g_rb = topo.gpu(nb, r);
            let mut hops = Vec::new();
            if g_ra != s {
                hops.push(topo.nvlink(s, g_ra).unwrap());
            }
            hops.push(topo.rail(na, nb, r).expect("flat inter-node rail"));
            if g_rb != d {
                hops.push(topo.nvlink(g_rb, d).unwrap());
            }
            out.push(Path { src: s, dst: d, kind: PathKind::InterRail { rail: r }, hops });
        }
    }
    out
}

fn assert_same_paths(topo: &Topology, s: usize, d: usize, mp: bool) -> Result<(), String> {
    let new = candidates(topo, s, d, mp);
    let old = legacy_flat_candidates(topo, s, d, mp);
    prop_assert!(
        new == old,
        "tier-walk diverged from legacy flat enumeration for ({s},{d}) mp={mp}:\n  new {new:?}\n  old {old:?}"
    );
    Ok(())
}

/// Flat-identity anchor, exhaustively on the paper topology: every
/// ordered pair, both multipath modes, full struct equality (kind AND
/// hop list, in order).
#[test]
fn prop_tier_walk_flat_identity_paper_exhaustive() {
    let topo = Topology::paper();
    for s in 0..topo.num_gpus() {
        for d in 0..topo.num_gpus() {
            if s == d {
                continue;
            }
            for mp in [false, true] {
                assert_same_paths(&topo, s, d, mp).unwrap();
            }
        }
    }
}

/// Flat-identity anchor on wide clusters: seeded (s, d) sweeps over
/// random `cluster(N)` sizes must match the legacy enumeration byte
/// for byte — this is the guarantee that lets every pre-tier plan /
/// serve / xcheck anchor stay bit-identical after the refactor.
#[test]
fn prop_tier_walk_flat_identity_clusters() {
    check_seeded(0x70_9071, 60, |g| {
        let nodes = g.usize(2, 12);
        let topo = Topology::cluster(nodes);
        for _ in 0..16 {
            let s = g.usize(0, topo.num_gpus() - 1);
            let mut d = g.usize(0, topo.num_gpus() - 1);
            if d == s {
                d = (d + 1) % topo.num_gpus();
            }
            assert_same_paths(&topo, s, d, g.bool())?;
        }
        Ok(())
    });
}

/// Tiered enumeration invariants at random sizes: every candidate is a
/// connected chain, per-rail coverage is complete, cross-pod pairs get
/// one candidate per (rail, spine), and no candidate ever uses a flat
/// NIC edge (those links don't exist on tiered fabrics).
#[test]
fn prop_tiered_candidates_valid_and_cover_rails() {
    check_seeded(0x70_9072, 40, |g| {
        let nodes = *g.pick(&[2usize, 4, 6, 8, 12, 16]);
        let oversub = *g.pick(&[1.0f64, 2.0, 4.0]);
        let topo = Topology::fat_tree(nodes, oversub);
        let tier = topo.tier.as_ref().expect("tiered fabric");
        let spines = tier.spines_per_rail;
        for _ in 0..12 {
            let s = g.usize(0, topo.num_gpus() - 1);
            let mut d = g.usize(0, topo.num_gpus() - 1);
            if d == s {
                d = (d + 1) % topo.num_gpus();
            }
            let cands = candidates(&topo, s, d, true);
            for p in &cands {
                prop_assert!(p.is_valid(&topo), "invalid path {:?}", p.kind);
            }
            if topo.same_node(s, d) {
                continue;
            }
            let cross_pod = topo.pod_of(topo.node_of(s)) != topo.pod_of(topo.node_of(d));
            let expect = if cross_pod {
                topo.nics_per_node * spines
            } else {
                topo.nics_per_node
            };
            prop_assert!(
                cands.len() == expect,
                "({s},{d}) cross_pod={cross_pod}: {} candidates, expected {expect}",
                cands.len()
            );
            for rail in 0..topo.nics_per_node {
                let n_rail = cands
                    .iter()
                    .filter(|p| match p.kind {
                        PathKind::InterLeaf { rail: r } => r == rail,
                        PathKind::InterSpine { rail: r, .. } => r == rail,
                        _ => false,
                    })
                    .count();
                let want = if cross_pod { spines } else { 1 };
                prop_assert!(
                    n_rail == want,
                    "rail {rail} has {n_rail} candidates, expected {want}"
                );
            }
        }
        Ok(())
    });
}

/// ECMP determinism: for any topology (flat or tiered), any demand set
/// and any hash seed, two routers with the same seed produce identical
/// stripes — same paths, same byte shares, same order.
#[test]
fn prop_ecmp_deterministic_for_fixed_seed() {
    check_seeded(0xEC_3901, 40, |g| {
        let topo = if g.bool() {
            Topology::fat_tree(*g.pick(&[4usize, 8, 12]), 2.0)
        } else {
            Topology::cluster(g.usize(2, 6))
        };
        let seed = g.u64(0, u64::MAX - 1);
        let n = g.usize(1, 12);
        let demands: Vec<Demand> = (0..n)
            .map(|_| {
                let s = g.usize(0, topo.num_gpus() - 1);
                let mut d = g.usize(0, topo.num_gpus() - 1);
                if d == s {
                    d = (d + 1) % topo.num_gpus();
                }
                Demand::new(s, d, g.f64(0.5, 64.0) * MB)
            })
            .collect();
        let a = EcmpHash::with_seed(seed).route(&topo, &demands);
        let b = EcmpHash::with_seed(seed).route(&topo, &demands);
        prop_assert!(a.len() == b.len(), "stripe counts diverged");
        for (i, ((pa, ba), (pb, bb))) in a.iter().zip(&b).enumerate() {
            prop_assert!(pa == pb, "stripe {i} path diverged");
            prop_assert!(ba.to_bits() == bb.to_bits(), "stripe {i} bytes diverged");
        }
        Ok(())
    });
}

/// ECMP's equal-share invariant: every inter-node demand splits into
/// exactly `nics_per_node` stripes of bytes/R each, regardless of skew
/// — the capacity-blindness the planner's comparison exploits.
#[test]
fn prop_ecmp_equal_share_invariant() {
    check_seeded(0xEC_3902, 30, |g| {
        let topo = if g.bool() {
            Topology::fat_tree(8, 2.0)
        } else {
            Topology::cluster(4)
        };
        let s = g.usize(0, topo.num_gpus() - 1);
        let mut d = g.usize(0, topo.num_gpus() - 1);
        if d == s {
            d = (d + 1) % topo.num_gpus();
        }
        let bytes = g.f64(1.0, 128.0) * MB;
        let stripes = EcmpHash::with_seed(g.u64(0, 1 << 48)).route(
            &topo,
            &[Demand::new(s, d, bytes)],
        );
        if topo.same_node(s, d) {
            prop_assert!(stripes.len() == 1, "intra-node must be one direct stripe");
            return Ok(());
        }
        prop_assert!(
            stripes.len() == topo.nics_per_node,
            "{} stripes for {} rails",
            stripes.len(),
            topo.nics_per_node
        );
        let share = bytes / topo.nics_per_node as f64;
        for (p, b) in &stripes {
            prop_assert!((b - share).abs() < 1e-6, "unequal stripe {b} vs {share}");
            prop_assert!(p.is_valid(&topo), "invalid stripe path");
        }
        Ok(())
    });
}
