//! Fault-injection property tests (DESIGN.md §13): byte conservation
//! under every fault scenario on both fabric backends, seeded
//! determinism of schedules and faulted runs, the bit-identity anchor
//! (an empty `FaultSchedule` must be indistinguishable from a build
//! without the fault layer), and fail-closed schedule validation.

use nimble::coordinator::ReplanExecutor;
use nimble::fabric::faults::scenario_schedule;
use nimble::fabric::{
    BackendKind, FabricParams, Fault, FaultEvent, FaultSchedule, Scenario, ScenarioParams,
};
use nimble::orchestrator::{job_stream, MultiTenantExecutor, TenancyCfg};
use nimble::planner::{Demand, Planner, PlannerCfg, ReplanCfg};
use nimble::topology::Topology;
use nimble::workloads::skew::hotspot_alltoallv;

const MB: f64 = 1024.0 * 1024.0;

fn enabled(cadence_s: f64) -> ReplanCfg {
    ReplanCfg { enable: true, cadence_s, margin: 0.1, ..ReplanCfg::default() }
}

fn disabled(cadence_s: f64) -> ReplanCfg {
    ReplanCfg { enable: false, cadence_s, ..ReplanCfg::default() }
}

/// Every scenario, both backends, both arms: the payload arrives in
/// full across link death, throttling and restoration — no bytes are
/// lost or duplicated by the fault hooks or the recovery reroutes (the
/// executor additionally asserts per-stream chunk exactness through
/// the reassembly table on every run).
#[test]
fn bytes_conserved_under_every_scenario_on_both_backends() {
    let topo = Topology::paper();
    let demands = hotspot_alltoallv(&topo, 64.0 * MB, 0.7, topo.gpu(1, 0));
    let payload: f64 = demands.iter().map(|d| d.bytes).sum();
    let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    for backend in [BackendKind::Fluid, BackendKind::Packet] {
        let params = FabricParams { backend, ..FabricParams::default() };
        for sc in Scenario::all() {
            let sched = scenario_schedule(
                &topo,
                sc,
                &ScenarioParams::default(),
                Some(&plan.link_load),
            );
            for enable in [false, true] {
                let rcfg = if enable { enabled(2.0e-4) } else { disabled(2.0e-4) };
                let run =
                    ReplanExecutor::new(&topo, params.clone(), PlannerCfg::default(), rcfg)
                        .with_faults(sched.clone())
                        .execute(&plan, &demands);
                let delivered: f64 = run.sim.flows.iter().map(|f| f.bytes).sum();
                assert!(
                    (delivered - payload).abs() < 64.0,
                    "{backend:?} {} enable={enable}: delivered {delivered} vs {payload}",
                    sc.label()
                );
                // a frozen plan cannot finish a flap before the link
                // restores — proof the fault actually bit
                if matches!(sc, Scenario::Flap) && !enable {
                    assert!(
                        run.report.makespan_s >= 3.0e-3,
                        "{backend:?} flap static finished during the outage: {}",
                        run.report.makespan_s
                    );
                }
            }
        }
    }
}

/// Identical seeds ⇒ byte-identical fault event traces, and
/// byte-identical faulted runs end to end (goodput series included).
/// A different seed still validates against the topology.
#[test]
fn same_seed_byte_identical_traces_and_runs() {
    let topo = Topology::paper();
    let fp = ScenarioParams::default();
    for sc in Scenario::all() {
        let a = scenario_schedule(&topo, sc, &fp, None);
        let b = scenario_schedule(&topo, sc, &fp, None);
        assert_eq!(a.trace(), b.trace(), "{} trace diverged", sc.label());
        assert!(!a.trace().is_empty());
    }
    let params = FabricParams::default();
    let demands = vec![Demand::new(0, 4, 256.0 * MB)];
    let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    let sched = scenario_schedule(&topo, Scenario::Mixed, &fp, Some(&plan.link_load));
    let fly = || {
        ReplanExecutor::new(&topo, params.clone(), PlannerCfg::default(), enabled(2.0e-4))
            .with_faults(sched.clone())
            .execute(&plan, &demands)
    };
    let r1 = fly();
    let r2 = fly();
    assert_eq!(r1.report.makespan_s.to_bits(), r2.report.makespan_s.to_bits());
    assert_eq!(r1.replans, r2.replans);
    assert_eq!(r1.preemptions, r2.preemptions);
    for (a, b) in r1.sim.link_bytes.iter().zip(&r2.sim.link_bytes) {
        assert_eq!(a.to_bits(), b.to_bits(), "link bytes diverged");
    }
    assert_eq!(r1.epochs.len(), r2.epochs.len());
    for (a, b) in r1.epochs.iter().zip(&r2.epochs) {
        assert_eq!(a.goodput_gbps.to_bits(), b.goodput_gbps.to_bits());
        assert_eq!(a.replanned, b.replanned);
    }
    // a different seed may move the fallback target, never the validity
    scenario_schedule(&topo, Scenario::Flap, &ScenarioParams { seed: 7, ..fp }, None)
        .validate(&topo)
        .expect("reseeded schedule must stay valid");
}

/// The bit-identity anchor: attaching an *empty* schedule changes
/// nothing, bitwise, on either backend, with the replan loop on or
/// off, and under the multi-tenant orchestrator. This is what keeps
/// every pre-fault experiment reproducible with the fault layer
/// compiled in.
#[test]
fn empty_schedule_is_bitwise_inert() {
    let topo = Topology::paper();
    let demands = vec![Demand::new(0, 4, 128.0 * MB), Demand::new(2, 5, 48.0 * MB)];
    let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    for backend in [BackendKind::Fluid, BackendKind::Packet] {
        let params = FabricParams { backend, ..FabricParams::default() };
        for enable in [false, true] {
            let rcfg = if enable { enabled(2.0e-4) } else { disabled(2.0e-4) };
            let bare = ReplanExecutor::new(
                &topo,
                params.clone(),
                PlannerCfg::default(),
                rcfg.clone(),
            )
            .execute(&plan, &demands);
            let empty =
                ReplanExecutor::new(&topo, params.clone(), PlannerCfg::default(), rcfg)
                    .with_faults(FaultSchedule::default())
                    .execute(&plan, &demands);
            assert_eq!(
                bare.report.makespan_s.to_bits(),
                empty.report.makespan_s.to_bits(),
                "{backend:?} enable={enable}: makespan diverged"
            );
            assert_eq!(bare.replans, empty.replans);
            assert_eq!(bare.preemptions, empty.preemptions);
            assert_eq!(bare.epochs.len(), empty.epochs.len());
            for (a, b) in bare.sim.link_bytes.iter().zip(&empty.sim.link_bytes) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
    // the orchestrator path (joint mode exercises the shared-constraint
    // and admission plumbing the fault layer threads through)
    let tcfg = TenancyCfg::default();
    let params = FabricParams::default();
    let serve = |faults: Option<FaultSchedule>| {
        let ex = MultiTenantExecutor::new(
            &topo,
            params.clone(),
            PlannerCfg::default(),
            ReplanCfg::default(),
            tcfg.clone(),
        );
        let mut ex = match faults {
            Some(f) => ex.with_faults(f),
            None => ex,
        };
        ex.execute(job_stream(&topo, &tcfg))
    };
    let bare = serve(None);
    let empty = serve(Some(FaultSchedule::default()));
    assert_eq!(bare.makespan_s.to_bits(), empty.makespan_s.to_bits());
    assert_eq!(bare.replans, empty.replans);
    assert_eq!(bare.preemptions, empty.preemptions);
    assert_eq!(bare.epochs.len(), empty.epochs.len());
    assert_eq!(bare.tenants.len(), empty.tenants.len());
    for (a, b) in bare.tenants.iter().zip(&empty.tenants) {
        assert_eq!(a.goodput_gbps.to_bits(), b.goodput_gbps.to_bits());
        assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
    }
}

/// Fail-closed validation: schedules referencing nonexistent links,
/// rails or nodes — or carrying out-of-range factors — are rejected,
/// while every generated scenario validates on flat and tiered
/// topologies.
#[test]
fn schedule_validation_is_fail_closed() {
    let topo = Topology::paper();
    let at = |fault: Fault| FaultSchedule::new(vec![FaultEvent { t_s: 1.0e-3, fault }]);
    assert!(at(Fault::LinkDown { link: topo.links.len() }).validate(&topo).is_err());
    assert!(at(Fault::LinkUp { link: usize::MAX }).validate(&topo).is_err());
    assert!(at(Fault::RailDegraded { rail: topo.nics_per_node, factor: 0.5 })
        .validate(&topo)
        .is_err());
    assert!(at(Fault::RailDegraded { rail: 0, factor: 0.0 }).validate(&topo).is_err());
    assert!(at(Fault::RailDegraded { rail: 0, factor: f64::NAN })
        .validate(&topo)
        .is_err());
    assert!(at(Fault::StragglerNode { node: topo.nodes, inject_factor: 0.5 })
        .validate(&topo)
        .is_err());
    assert!(at(Fault::StragglerNode { node: 0, inject_factor: 1.5 })
        .validate(&topo)
        .is_err());
    assert!(FaultSchedule::new(vec![FaultEvent {
        t_s: -1.0,
        fault: Fault::LinkDown { link: 0 },
    }])
    .validate(&topo)
    .is_err());
    for t in [Topology::paper(), Topology::fat_tree(4, 2.0)] {
        for sc in Scenario::all() {
            scenario_schedule(&t, sc, &ScenarioParams::default(), None)
                .validate(&t)
                .unwrap_or_else(|e| panic!("{} invalid on {} nodes: {e}", sc.label(), t.nodes));
        }
    }
}
