# NIMBLE reproduction — convenience targets.
#
# `make artifacts` needs a Python with jax installed (build-time only;
# nothing on the rust execution path imports Python). `make test` tries
# to build the artifacts first but tolerates their absence — the
# artifact-dependent tests skip cleanly.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all build test bench artifacts clean

all: build

build:
	$(CARGO) build --release --all-targets

test:
	-$(MAKE) artifacts
	$(CARGO) build --release && $(CARGO) test -q

bench: build
	$(CARGO) bench

artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf artifacts
