//! MoE expert-parallel step driver (paper §V-D / Fig 8).
//!
//! One EP step = **dispatch** (All-to-Allv of tokens to their experts)
//! → **compute** (each GPU's expert FFN over its received tokens) →
//! **combine** (transpose All-to-Allv returning results to owners).
//!
//! Dispatch/combine timing comes from the fabric simulator under the
//! router being tested (NIMBLE vs baselines). Compute timing uses the
//! H100 roofline model — identical between methods, as the paper notes
//! ("Compute is identical across methods; gains come from slimmer
//! dispatch/combine") — while the *real* FFN kernel runs through the
//! PJRT runtime in examples/moe_e2e.rs to prove the stack composes.

use crate::baselines::{run_round, Router};
use crate::fabric::FabricParams;
use crate::planner::Demand;
use crate::runtime::ComputeModel;
use crate::topology::Topology;
use crate::workloads::moe_traffic::{
    combine_demands, dispatch_demands, expert_token_counts, MoeConfig,
};

/// Latency breakdown for one EP step.
#[derive(Clone, Copy, Debug)]
pub struct MoeStep {
    pub dispatch_s: f64,
    pub compute_s: f64,
    pub combine_s: f64,
}

impl MoeStep {
    pub fn total_s(&self) -> f64 {
        self.dispatch_s + self.compute_s + self.combine_s
    }
}

/// Run one MoE step under `router`; `d_ff` defaults to 4×d_model
/// (paper: "expert compute is a two-layer FFN with 4× expansion").
pub fn run_moe_step(
    topo: &Topology,
    params: &FabricParams,
    compute: &ComputeModel,
    router: &mut dyn Router,
    cfg: &MoeConfig,
) -> MoeStep {
    let disp: Vec<Demand> = dispatch_demands(topo, cfg);
    let comb: Vec<Demand> = combine_demands(topo, cfg);
    let dispatch_s = run_round(topo, params, router, &disp).makespan_s;
    let combine_s = run_round(topo, params, router, &comb).makespan_s;
    // experts run in parallel on their GPUs: the step waits for the
    // most loaded (hot) expert
    let d_ff = (cfg.d_model * 4) as f64;
    let compute_s = expert_token_counts(topo, cfg)
        .into_iter()
        .map(|t| compute.expert_ffn_s(t, cfg.d_model as f64, d_ff))
        .fold(0.0, f64::max);
    MoeStep { dispatch_s, compute_s, combine_s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::NcclLike;
    use crate::coordinator::NimbleRouter;

    #[test]
    fn compute_identical_between_routers() {
        let t = Topology::paper();
        let params = FabricParams::default();
        let cm = ComputeModel::default();
        let cfg = MoeConfig::paper(16_384, 0.8);
        let mut nccl = NcclLike::new();
        let mut nim = NimbleRouter::default_for(&t);
        let a = run_moe_step(&t, &params, &cm, &mut nccl, &cfg);
        let b = run_moe_step(&t, &params, &cm, &mut nim, &cfg);
        assert!((a.compute_s - b.compute_s).abs() < 1e-12);
        // and NIMBLE's comm phases are no slower (small tolerance:
        // combine is already rail-balanced under PXN, so NIMBLE can
        // only match it modulo chunk quantization)
        assert!(b.dispatch_s <= a.dispatch_s * 1.05);
        assert!(b.combine_s <= a.combine_s * 1.05);
    }

    /// Fig 8 trend: end-to-end speedup grows with token count (comm
    /// fraction grows) and with hotspot ratio.
    #[test]
    fn speedup_trends_match_paper() {
        let t = Topology::paper();
        let params = FabricParams::default();
        let cm = ComputeModel::default();
        let speedup = |tokens: usize, ratio: f64| {
            let cfg = MoeConfig::paper(tokens, ratio);
            let mut nccl = NcclLike::new();
            let mut nim = NimbleRouter::default_for(&t);
            let a = run_moe_step(&t, &params, &cm, &mut nccl, &cfg).total_s();
            let b = run_moe_step(&t, &params, &cm, &mut nim, &cfg).total_s();
            a / b
        };
        let s_small = speedup(2048, 0.9);
        let s_big = speedup(65_536, 0.9);
        assert!(s_big > s_small, "more tokens should help: {s_small} vs {s_big}");
        let s_mild = speedup(16_384, 0.4);
        let s_hot = speedup(16_384, 0.9);
        assert!(s_hot > s_mild, "hotter should help: {s_mild} vs {s_hot}");
        // paper's "enable" region shows >1.16×; our compute model is
        // more generous to the baseline (see DESIGN.md §2), so the
        // bound here is the direction + a floor
        assert!(s_hot > 1.05, "16K/0.9 speedup too small: {s_hot}");
    }
}
