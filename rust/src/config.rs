//! Experiment configuration: load topology, fabric calibration and
//! planner parameters from a TOML file so deployments other than the
//! paper's 2×(4 GPU + 4 NIC) testbed are first-class (see
//! `configs/paper.toml` for the reference file).

use crate::fabric::faults::{scenario_schedule, FaultsCfg, Scenario};
use crate::fabric::{BackendKind, FabricParams, SchedulerKind};
use crate::orchestrator::TenancyCfg;
use crate::planner::{CostModel, PlannerCfg, ReplanCfg};
use crate::telemetry::TelemetryCfg;
use crate::topology::Topology;
use crate::util::toml::TomlDoc;
use std::path::Path;

/// Fully-resolved experiment configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub topology: Topology,
    pub fabric: FabricParams,
    pub planner: PlannerCfg,
    /// Execution-time re-planning loop (`[replan]`): disabled by
    /// default so every static experiment reproduces bit-identically.
    pub replan: ReplanCfg,
    /// Multi-tenant serving (`[tenancy]`): only `nimble serve` / the
    /// orchestrator consume it, so the section is inert for every
    /// other experiment.
    pub tenancy: TenancyCfg,
    /// Fault injection (`[faults]`): only `nimble faults` consumes it;
    /// scenario `"none"` (the default) builds no schedule, so the
    /// section is inert for every other experiment.
    pub faults: FaultsCfg,
    /// Telemetry (`[telemetry]`): off by default — the CLI holds a
    /// disabled [`crate::telemetry::Recorder`], which is bitwise inert
    /// (DESIGN.md §15). `--trace <path>` overrides this section.
    pub telemetry: TelemetryCfg,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            topology: Topology::paper(),
            fabric: FabricParams::default(),
            planner: PlannerCfg::default(),
            replan: ReplanCfg::default(),
            tenancy: TenancyCfg::default(),
            faults: FaultsCfg::default(),
            telemetry: TelemetryCfg::default(),
        }
    }
}

impl Config {
    /// Load from a TOML file; unspecified keys keep their defaults.
    pub fn load(path: impl AsRef<Path>) -> Result<Config, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("reading {:?}: {e}", path.as_ref()))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<Config, String> {
        let doc = TomlDoc::parse(text).map_err(|e| e.to_string())?;
        let mut cfg = Config::default();

        // [topology]
        let nodes = doc.get_usize("topology", "nodes").unwrap_or(2);
        let gpus = doc.get_usize("topology", "gpus_per_node").unwrap_or(4);
        let nics = doc.get_usize("topology", "nics_per_node").unwrap_or(gpus);
        let nvlink = doc
            .get_f64("topology", "nvlink_gbps")
            .unwrap_or(crate::topology::NVLINK_GBPS);
        let rail =
            doc.get_f64("topology", "rail_gbps").unwrap_or(crate::topology::RAIL_GBPS);
        if nodes == 0 || gpus == 0 || nics == 0 {
            return Err(format!(
                "topology: nodes ({nodes}), gpus_per_node ({gpus}) and nics_per_node \
                 ({nics}) must all be positive"
            ));
        }
        if nics > gpus || gpus % nics != 0 {
            return Err(format!(
                "topology: nics_per_node ({nics}) must divide gpus_per_node ({gpus}) \
                 (NIC r attaches to GPU r)"
            ));
        }
        let kind = doc
            .get("topology", "kind")
            .map(|v| v.as_str().map(str::to_string))
            .unwrap_or(Some("flat".to_string()));
        let oversub = doc.get_f64("topology", "oversubscription").unwrap_or(2.0);
        let spines = doc
            .get_usize("topology", "spines_per_rail")
            .unwrap_or(crate::topology::SPINES_PER_RAIL);
        let mut topo = match kind.as_deref() {
            Some("flat") => Topology::build(nodes, gpus, nics, nvlink, rail, true),
            Some("fat-tree") => {
                if !(oversub.is_finite() && oversub >= 1.0) {
                    return Err(format!(
                        "topology.oversubscription must be a finite ratio >= 1.0: {oversub}"
                    ));
                }
                if spines == 0 || spines > 64 {
                    return Err(format!(
                        "topology.spines_per_rail out of [1,64]: {spines}"
                    ));
                }
                Topology::build_fat_tree(nodes, gpus, nics, nvlink, rail, oversub, spines)
            }
            _ => {
                return Err(format!(
                    "topology.kind must be \"flat\" or \"fat-tree\", got {:?}",
                    doc.get("topology", "kind")
                ))
            }
        };
        if doc.get_bool("topology", "nvswitch").unwrap_or(false) {
            topo.nvswitch = true;
        }
        cfg.topology = topo;

        // [fabric]
        let f = &mut cfg.fabric;
        let g = |k: &str, d: f64| doc.get_f64("fabric", k).unwrap_or(d);
        f.relay_rho = g("relay_rho", f.relay_rho);
        f.inject_cap_gbps = g("inject_cap_gbps", f.inject_cap_gbps);
        f.recv_cap_gbps = g("recv_cap_gbps", f.recv_cap_gbps);
        f.node_net_cap_gbps = g("node_net_cap_gbps", f.node_net_cap_gbps);
        f.s_half_intra = g("s_half_intra_bytes", f.s_half_intra);
        f.s_half_inter = g("s_half_inter_bytes", f.s_half_inter);
        f.alpha_kernel_us = g("alpha_kernel_us", f.alpha_kernel_us);
        f.alpha_copy_engine_us = g("alpha_copy_engine_us", f.alpha_copy_engine_us);
        f.p2p_buf_bytes = g("p2p_buf_bytes", f.p2p_buf_bytes);
        f.chunk_bytes = g("chunk_bytes", f.chunk_bytes);

        // [fabric.packet] — backend selector + packet-sim calibration.
        // Defaults to the fluid backend so every pre-existing experiment
        // and plan output stays bit-identical.
        let ps = "fabric.packet";
        if let Some(v) = doc.get(ps, "backend") {
            f.backend = match v.as_str() {
                Some("fluid") => BackendKind::Fluid,
                Some("packet") => BackendKind::Packet,
                _ => {
                    return Err(format!(
                        "fabric.packet.backend must be \"fluid\" or \"packet\", got {v:?}"
                    ))
                }
            };
        }
        let pk = &mut f.packet;
        pk.cell_bytes = doc.get_f64(ps, "cell_bytes").unwrap_or(pk.cell_bytes);
        pk.buffer_bytes = doc.get_f64(ps, "buffer_bytes").unwrap_or(pk.buffer_bytes);
        if let Some(l) = doc.get_usize(ps, "latency_ns") {
            pk.latency_ns = l as u64;
        }
        if let Some(s) = doc.get_usize(ps, "seed") {
            pk.seed = s as u64;
        }
        if let Some(v) = doc.get(ps, "scheduler") {
            pk.scheduler = match v.as_str() {
                Some("wheel") => SchedulerKind::Wheel,
                Some("heap") => SchedulerKind::Heap,
                _ => {
                    return Err(format!(
                        "fabric.packet.scheduler must be \"wheel\" or \"heap\", got {v:?}"
                    ))
                }
            };
        }
        pk.threads = doc.get_usize(ps, "threads").unwrap_or(pk.threads);

        // [planner]
        let p = &mut cfg.planner;
        p.lambda = doc.get_f64("planner", "lambda").unwrap_or(p.lambda);
        p.epsilon_bytes =
            doc.get_f64("planner", "epsilon_bytes").unwrap_or(p.epsilon_bytes);
        p.multipath = doc.get_bool("planner", "multipath").unwrap_or(p.multipath);
        p.threads = doc.get_usize("planner", "threads").unwrap_or(p.threads);
        let c: &mut CostModel = &mut p.cost;
        c.multipath_min_bytes =
            doc.get_f64("planner", "multipath_min_bytes").unwrap_or(c.multipath_min_bytes);
        c.amortize_bytes =
            doc.get_f64("planner", "amortize_bytes").unwrap_or(c.amortize_bytes);
        c.penalty_scale =
            doc.get_f64("planner", "penalty_scale").unwrap_or(c.penalty_scale);
        c.hysteresis = doc.get_f64("planner", "hysteresis").unwrap_or(c.hysteresis);

        // [replan] (endpoint anchors follow the [fabric] calibration)
        let r = &mut cfg.replan;
        r.enable = doc.get_bool("replan", "enable").unwrap_or(r.enable);
        r.cadence_s = doc
            .get_f64("replan", "cadence_ms")
            .map(|ms| ms * 1e-3)
            .unwrap_or(r.cadence_s);
        r.margin = doc.get_f64("replan", "margin").unwrap_or(r.margin);
        r.caps = crate::planner::DrainCaps::from(&cfg.fabric);

        // [tenancy] (consumed only by `nimble serve`; inert otherwise)
        let t = &mut cfg.tenancy;
        t.jobs = doc.get_usize("tenancy", "jobs").unwrap_or(t.jobs);
        if let Some(s) = doc.get_usize("tenancy", "seed") {
            t.seed = s as u64;
        }
        t.max_live = doc.get_usize("tenancy", "max_live").unwrap_or(t.max_live);
        t.mean_gap_ms =
            doc.get_f64("tenancy", "mean_gap_ms").unwrap_or(t.mean_gap_ms);
        t.joint = doc.get_bool("tenancy", "joint").unwrap_or(t.joint);
        if let Some(v) = doc.get("tenancy", "weights") {
            let Some(s) = v.as_str() else {
                return Err(format!(
                    "tenancy.weights must be a comma-separated string, got {v:?}"
                ));
            };
            let mut weights = Vec::new();
            for part in s.split(',').filter(|p| !p.trim().is_empty()) {
                let w: f64 = part
                    .trim()
                    .parse()
                    .map_err(|_| format!("tenancy.weights: bad number '{part}'"))?;
                weights.push(w);
            }
            t.weights = weights;
        }
        cfg.tenancy.validate()?;

        // [faults] (consumed only by `nimble faults`; inert otherwise)
        if let Some(v) = doc.get("faults", "scenario") {
            let Some(s) = v.as_str() else {
                return Err(format!("faults.scenario must be a string, got {v:?}"));
            };
            cfg.faults.scenario = match s {
                "none" => None,
                other => Some(Scenario::parse(other).ok_or_else(|| {
                    format!(
                        "faults.scenario must be none|flap|degrade|straggler|mixed, \
                         got \"{other}\""
                    )
                })?),
            };
        }
        let sp = &mut cfg.faults.params;
        if let Some(s) = doc.get_usize("faults", "seed") {
            sp.seed = s as u64;
        }
        sp.t0_s = doc.get_f64("faults", "t0_ms").map(|ms| ms * 1e-3).unwrap_or(sp.t0_s);
        sp.flap_period_s = doc
            .get_f64("faults", "flap_period_ms")
            .map(|ms| ms * 1e-3)
            .unwrap_or(sp.flap_period_s);
        sp.degrade_factor =
            doc.get_f64("faults", "degrade_factor").unwrap_or(sp.degrade_factor);
        sp.straggler_factor =
            doc.get_f64("faults", "straggler_factor").unwrap_or(sp.straggler_factor);

        // [telemetry] (pure observer: never touches plan/sim bytes)
        let tl = &mut cfg.telemetry;
        tl.enable = doc.get_bool("telemetry", "enable").unwrap_or(tl.enable);
        if let Some(v) = doc.get("telemetry", "path") {
            let Some(s) = v.as_str() else {
                return Err(format!("telemetry.path must be a string, got {v:?}"));
            };
            tl.path = s.to_string();
        }
        if tl.path.is_empty() {
            return Err("telemetry.path must not be empty".to_string());
        }

        // sanity
        if cfg.planner.lambda <= 0.0 || cfg.planner.lambda > 1.0 {
            return Err(format!("planner.lambda out of (0,1]: {}", cfg.planner.lambda));
        }
        if cfg.fabric.relay_rho <= 0.0 || cfg.fabric.relay_rho > 1.0 {
            return Err(format!("fabric.relay_rho out of (0,1]: {}", cfg.fabric.relay_rho));
        }
        if cfg.planner.threads == 0 || cfg.planner.threads > 256 {
            return Err(format!(
                "planner.threads out of [1,256]: {}",
                cfg.planner.threads
            ));
        }
        let pk = &cfg.fabric.packet;
        // range-contains form so NaN (which the TOML float parser
        // accepts) fails closed instead of sailing past `<` checks
        if !(1.0..=64.0 * 1024.0 * 1024.0).contains(&pk.cell_bytes) {
            return Err(format!(
                "fabric.packet.cell_bytes out of [1, 64 MiB]: {}",
                pk.cell_bytes
            ));
        }
        if !pk.buffer_bytes.is_finite() || pk.buffer_bytes < pk.cell_bytes {
            return Err(format!(
                "fabric.packet.buffer_bytes ({}) must hold at least one cell ({})",
                pk.buffer_bytes, pk.cell_bytes
            ));
        }
        if pk.latency_ns > 1_000_000_000 {
            return Err(format!(
                "fabric.packet.latency_ns out of [0, 1e9]: {}",
                pk.latency_ns
            ));
        }
        if pk.threads == 0 || pk.threads > 256 {
            return Err(format!(
                "fabric.packet.threads out of [1,256]: {}",
                pk.threads
            ));
        }
        if cfg.replan.cadence_s <= 0.0 {
            return Err(format!(
                "replan.cadence_ms must be positive: {}",
                cfg.replan.cadence_s * 1e3
            ));
        }
        if !(0.0..1.0).contains(&cfg.replan.margin) {
            return Err(format!("replan.margin out of [0,1): {}", cfg.replan.margin));
        }
        // [faults] ranges (negated-compare form so NaN fails closed)
        let sp = &cfg.faults.params;
        if !(sp.t0_s.is_finite() && sp.t0_s >= 0.0) {
            return Err(format!("faults.t0_ms must be finite and >= 0: {}", sp.t0_s * 1e3));
        }
        if !(sp.flap_period_s.is_finite() && sp.flap_period_s > 0.0) {
            return Err(format!(
                "faults.flap_period_ms must be positive: {}",
                sp.flap_period_s * 1e3
            ));
        }
        if !(sp.degrade_factor > 0.0 && sp.degrade_factor <= 1.0) {
            return Err(format!(
                "faults.degrade_factor out of (0,1]: {}",
                sp.degrade_factor
            ));
        }
        if !(sp.straggler_factor > 0.0 && sp.straggler_factor <= 1.0) {
            return Err(format!(
                "faults.straggler_factor out of (0,1]: {}",
                sp.straggler_factor
            ));
        }
        // a configured scenario must generate a schedule whose every
        // link/rail/node reference exists on the configured topology
        if let Some(sc) = cfg.faults.scenario {
            scenario_schedule(&cfg.topology, sc, &cfg.faults.params, None)
                .validate(&cfg.topology)?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.topology.num_gpus(), 8);
        assert!((c.fabric.relay_rho - 0.776).abs() < 1e-9);
    }

    #[test]
    fn toml_overrides_apply() {
        let c = Config::from_toml(
            r#"
            [topology]
            nodes = 4
            gpus_per_node = 8
            nics_per_node = 8
            nvlink_gbps = 150.0
            [fabric]
            node_net_cap_gbps = 300.0
            [planner]
            lambda = 0.5
            hysteresis = 0.1
            "#,
        )
        .unwrap();
        assert_eq!(c.topology.num_gpus(), 32);
        assert_eq!(c.topology.nvlink_gbps, 150.0);
        assert_eq!(c.fabric.node_net_cap_gbps, 300.0);
        assert_eq!(c.planner.lambda, 0.5);
        assert_eq!(c.planner.cost.hysteresis, 0.1);
        // untouched keys keep defaults
        assert!((c.fabric.relay_rho - 0.776).abs() < 1e-9);
    }

    #[test]
    fn nvswitch_flag_respected() {
        let c = Config::from_toml("[topology]\nnvswitch = true\n").unwrap();
        assert!(c.topology.nvswitch);
        assert_eq!(
            crate::topology::path::candidates(&c.topology, 0, 1, true).len(),
            1
        );
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(Config::from_toml("[planner]\nlambda = 1.5\n").is_err());
        assert!(Config::from_toml("[fabric]\nrelay_rho = 0.0\n").is_err());
        assert!(Config::from_toml("garbage without equals\n").is_err());
        assert!(Config::from_toml("[replan]\ncadence_ms = 0.0\n").is_err());
        assert!(Config::from_toml("[replan]\nmargin = 1.0\n").is_err());
        assert!(Config::from_toml("[planner]\nthreads = 0\n").is_err());
        // NIC count must divide the GPU count (NIC r ↔ GPU r)
        assert!(Config::from_toml(
            "[topology]\ngpus_per_node = 8\nnics_per_node = 3\n"
        )
        .is_err());
    }

    /// The `nimble scale` cluster axis loads from TOML: wide nodes with
    /// fewer NICs than GPUs, and a parallel planner.
    #[test]
    fn scale_axis_config_loads() {
        let c = Config::from_toml(
            "[topology]\nnodes = 4\ngpus_per_node = 8\nnics_per_node = 4\n\
             [planner]\nthreads = 8\n",
        )
        .unwrap();
        assert_eq!(c.topology.num_gpus(), 32);
        assert_eq!(c.topology.nics_per_node, 4);
        assert_eq!(c.planner.threads, 8);
        // default stays serial (the pre-threads code path)
        assert_eq!(Config::default().planner.threads, 1);
    }

    /// `[topology] kind` selects the fabric shape; flat stays the
    /// inert default so every existing config replays bit-identically.
    #[test]
    fn topology_kind_section() {
        // default + explicit flat: no tier, no switches
        for text in ["", "[topology]\nkind = \"flat\"\n"] {
            let c = Config::from_toml(text).unwrap();
            assert!(c.topology.tier.is_none());
            assert_eq!(c.topology.num_switches(), 0);
        }
        let c = Config::from_toml(
            "[topology]\nkind = \"fat-tree\"\nnodes = 8\ngpus_per_node = 8\n\
             nics_per_node = 4\noversubscription = 2.0\nspines_per_rail = 2\n",
        )
        .unwrap();
        let tier = c.topology.tier.as_ref().expect("tiered");
        assert_eq!(tier.pods, 2);
        assert_eq!(tier.spines_per_rail, 2);
        assert!((tier.oversub - 2.0).abs() < 1e-12);
        assert_eq!(c.topology.num_gpus(), 64);
    }

    #[test]
    fn topology_kind_invalid_values_rejected() {
        assert!(Config::from_toml("[topology]\nkind = \"torus\"\n").is_err());
        assert!(Config::from_toml(
            "[topology]\nkind = \"fat-tree\"\noversubscription = 0.5\n"
        )
        .is_err());
        assert!(Config::from_toml(
            "[topology]\nkind = \"fat-tree\"\noversubscription = nan\n"
        )
        .is_err());
        assert!(Config::from_toml(
            "[topology]\nkind = \"fat-tree\"\nspines_per_rail = 0\n"
        )
        .is_err());
    }

    #[test]
    fn replan_section_defaults_off_and_overrides() {
        // no section ⇒ disabled with library defaults
        let c = Config::from_toml("").unwrap();
        assert!(!c.replan.enable);
        assert!((c.replan.cadence_s - 5.0e-4).abs() < 1e-12);
        assert!((c.replan.margin - 0.1).abs() < 1e-12);
        // explicit section overrides every knob
        let c = Config::from_toml(
            "[replan]\nenable = true\ncadence_ms = 2.0\nmargin = 0.25\n",
        )
        .unwrap();
        assert!(c.replan.enable);
        assert!((c.replan.cadence_s - 2.0e-3).abs() < 1e-12);
        assert!((c.replan.margin - 0.25).abs() < 1e-12);
    }

    /// `[tenancy]` defaults mirror the built-ins, every knob
    /// overrides, and invalid values fail closed. The section is only
    /// consumed by `nimble serve`, so defaults are inert elsewhere.
    #[test]
    fn tenancy_section_defaults_and_overrides() {
        let c = Config::from_toml("").unwrap();
        assert_eq!(c.tenancy.jobs, 8);
        assert_eq!(c.tenancy.seed, 3);
        assert_eq!(c.tenancy.weights, vec![1.0, 2.0, 1.0, 4.0]);
        assert_eq!(c.tenancy.max_live, 6);
        assert!((c.tenancy.mean_gap_ms - 0.5).abs() < 1e-12);
        assert!(c.tenancy.joint);
        let c = Config::from_toml(
            "[tenancy]\njobs = 12\nseed = 99\nweights = \"2, 3\"\n\
             max_live = 3\nmean_gap_ms = 1.25\njoint = false\n",
        )
        .unwrap();
        assert_eq!(c.tenancy.jobs, 12);
        assert_eq!(c.tenancy.seed, 99);
        assert_eq!(c.tenancy.weights, vec![2.0, 3.0]);
        assert_eq!(c.tenancy.max_live, 3);
        assert!((c.tenancy.mean_gap_ms - 1.25).abs() < 1e-12);
        assert!(!c.tenancy.joint);
    }

    #[test]
    fn tenancy_invalid_values_rejected() {
        // job count must be >= 1
        assert!(Config::from_toml("[tenancy]\njobs = 0\n").is_err());
        // weights must be finite and positive
        assert!(Config::from_toml("[tenancy]\nweights = \"1, -2\"\n").is_err());
        assert!(Config::from_toml("[tenancy]\nweights = \"nan\"\n").is_err());
        assert!(Config::from_toml("[tenancy]\nweights = \"\"\n").is_err());
        assert!(Config::from_toml("[tenancy]\nweights = \"1, oops\"\n").is_err());
        // weights must be the comma-string form (no TOML arrays here)
        assert!(Config::from_toml("[tenancy]\nweights = 2\n").is_err());
        // admission cap and arrival gap must be positive
        assert!(Config::from_toml("[tenancy]\nmax_live = 0\n").is_err());
        assert!(Config::from_toml("[tenancy]\nmean_gap_ms = 0.0\n").is_err());
    }

    /// `[faults]` defaults to the inert "none" scenario with the
    /// built-in knobs; every knob overrides; invalid values fail closed.
    #[test]
    fn faults_section_defaults_and_overrides() {
        let c = Config::from_toml("").unwrap();
        assert!(c.faults.scenario.is_none());
        assert_eq!(c.faults.params.seed, 0xFA17_5EED);
        assert!((c.faults.params.t0_s - 1.0e-3).abs() < 1e-12);
        assert!((c.faults.params.flap_period_s - 2.0e-3).abs() < 1e-12);
        assert!((c.faults.params.degrade_factor - 0.25).abs() < 1e-12);
        assert!((c.faults.params.straggler_factor - 0.25).abs() < 1e-12);
        let c = Config::from_toml(
            "[faults]\nscenario = \"degrade\"\nseed = 7\nt0_ms = 0.5\n\
             flap_period_ms = 4.0\ndegrade_factor = 0.5\nstraggler_factor = 0.75\n",
        )
        .unwrap();
        assert_eq!(c.faults.scenario, Some(Scenario::Degrade));
        assert_eq!(c.faults.params.seed, 7);
        assert!((c.faults.params.t0_s - 0.5e-3).abs() < 1e-12);
        assert!((c.faults.params.flap_period_s - 4.0e-3).abs() < 1e-12);
        assert!((c.faults.params.degrade_factor - 0.5).abs() < 1e-12);
        assert!((c.faults.params.straggler_factor - 0.75).abs() < 1e-12);
        // explicit "none" stays inert
        assert!(Config::from_toml("[faults]\nscenario = \"none\"\n")
            .unwrap()
            .faults
            .scenario
            .is_none());
    }

    #[test]
    fn faults_invalid_values_rejected() {
        // unknown scenario name
        assert!(Config::from_toml("[faults]\nscenario = \"meteor\"\n").is_err());
        assert!(Config::from_toml("[faults]\nscenario = 3\n").is_err());
        // flap period must be positive; NaN fails closed
        assert!(Config::from_toml("[faults]\nflap_period_ms = 0.0\n").is_err());
        assert!(Config::from_toml("[faults]\nflap_period_ms = nan\n").is_err());
        // factors confined to (0, 1]
        assert!(Config::from_toml("[faults]\ndegrade_factor = 0.0\n").is_err());
        assert!(Config::from_toml("[faults]\ndegrade_factor = 1.5\n").is_err());
        assert!(Config::from_toml("[faults]\nstraggler_factor = -0.5\n").is_err());
        assert!(Config::from_toml("[faults]\nstraggler_factor = nan\n").is_err());
        // first fire time must be finite and non-negative
        assert!(Config::from_toml("[faults]\nt0_ms = -1.0\n").is_err());
    }

    /// A configured scenario is validated against the configured
    /// topology — every generated reference must exist on it.
    #[test]
    fn faults_scenario_validates_against_topology() {
        for sc in ["flap", "degrade", "straggler", "mixed"] {
            let c = Config::from_toml(&format!("[faults]\nscenario = \"{sc}\"\n"))
                .unwrap();
            assert!(c.faults.scenario.is_some());
            let c = Config::from_toml(&format!(
                "[topology]\nkind = \"fat-tree\"\nnodes = 8\ngpus_per_node = 8\n\
                 nics_per_node = 4\n[faults]\nscenario = \"{sc}\"\n"
            ))
            .unwrap();
            assert!(c.faults.scenario.is_some());
        }
    }

    #[test]
    fn reference_config_file_parses() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/paper.toml");
        let c = Config::load(path).unwrap();
        assert_eq!(c.topology.num_gpus(), 8);
        // [replan] ships disabled so paper experiments replay verbatim
        assert!(!c.replan.enable);
        // the backend selector ships on fluid for the same reason, and
        // the packet section mirrors the built-in defaults exactly
        assert_eq!(c.fabric.backend, BackendKind::Fluid);
        let d = FabricParams::default().packet;
        assert_eq!(c.fabric.packet.cell_bytes, d.cell_bytes);
        assert_eq!(c.fabric.packet.buffer_bytes, d.buffer_bytes);
        assert_eq!(c.fabric.packet.latency_ns, d.latency_ns);
        assert_eq!(c.fabric.packet.seed, d.seed);
        assert_eq!(c.fabric.packet.scheduler, d.scheduler);
        assert_eq!(c.fabric.packet.threads, d.threads);
        // [tenancy] mirrors the built-in defaults exactly (inert
        // unless `nimble serve` is invoked)
        let td = TenancyCfg::default();
        assert_eq!(c.tenancy.jobs, td.jobs);
        assert_eq!(c.tenancy.seed, td.seed);
        assert_eq!(c.tenancy.weights, td.weights);
        assert_eq!(c.tenancy.max_live, td.max_live);
        assert_eq!(c.tenancy.mean_gap_ms, td.mean_gap_ms);
        assert_eq!(c.tenancy.joint, td.joint);
        // [faults] ships inert ("none") with the built-in knobs
        let fd = FaultsCfg::default();
        assert!(c.faults.scenario.is_none());
        assert_eq!(c.faults.params.seed, fd.params.seed);
        assert_eq!(c.faults.params.t0_s, fd.params.t0_s);
        assert_eq!(c.faults.params.flap_period_s, fd.params.flap_period_s);
        assert_eq!(c.faults.params.degrade_factor, fd.params.degrade_factor);
        assert_eq!(c.faults.params.straggler_factor, fd.params.straggler_factor);
        // [telemetry] ships disabled with the default path
        let tld = TelemetryCfg::default();
        assert_eq!(c.telemetry.enable, tld.enable);
        assert_eq!(c.telemetry.path, tld.path);
    }

    /// `[telemetry]` ships disabled (the CLI then holds a bitwise-inert
    /// disabled recorder); `enable`/`path` override; empty or
    /// non-string paths fail closed.
    #[test]
    fn telemetry_section_defaults_and_overrides() {
        let c = Config::from_toml("").unwrap();
        assert!(!c.telemetry.enable);
        assert_eq!(c.telemetry.path, "nimble-trace.jsonl");
        let c = Config::from_toml(
            "[telemetry]\nenable = true\npath = \"/tmp/run.jsonl\"\n",
        )
        .unwrap();
        assert!(c.telemetry.enable);
        assert_eq!(c.telemetry.path, "/tmp/run.jsonl");
        assert!(Config::from_toml("[telemetry]\npath = \"\"\n").is_err());
        assert!(Config::from_toml("[telemetry]\npath = 3\n").is_err());
    }

    /// `[fabric.packet]` defaults to the fluid backend (bit-identical
    /// pre-existing experiments) and every knob overrides.
    #[test]
    fn packet_section_defaults_and_overrides() {
        let c = Config::from_toml("").unwrap();
        assert_eq!(c.fabric.backend, BackendKind::Fluid);
        assert_eq!(c.fabric.packet.cell_bytes, 256.0 * 1024.0);
        assert_eq!(c.fabric.packet.buffer_bytes, 10.0 * 1024.0 * 1024.0);
        assert_eq!(c.fabric.packet.latency_ns, 3_000);
        assert_eq!(c.fabric.packet.scheduler, SchedulerKind::Wheel);
        assert_eq!(c.fabric.packet.threads, 1);
        let c = Config::from_toml(
            "[fabric.packet]\nbackend = \"packet\"\ncell_bytes = 65_536\n\
             buffer_bytes = 1_048_576\nlatency_ns = 500\nseed = 42\n\
             scheduler = \"heap\"\nthreads = 8\n",
        )
        .unwrap();
        assert_eq!(c.fabric.backend, BackendKind::Packet);
        assert_eq!(c.fabric.packet.cell_bytes, 65_536.0);
        assert_eq!(c.fabric.packet.buffer_bytes, 1_048_576.0);
        assert_eq!(c.fabric.packet.latency_ns, 500);
        assert_eq!(c.fabric.packet.seed, 42);
        assert_eq!(c.fabric.packet.scheduler, SchedulerKind::Heap);
        assert_eq!(c.fabric.packet.threads, 8);
        let c = Config::from_toml("[fabric.packet]\nscheduler = \"wheel\"\n").unwrap();
        assert_eq!(c.fabric.packet.scheduler, SchedulerKind::Wheel);
    }

    #[test]
    fn packet_section_invalid_values_rejected() {
        // unknown backend name
        assert!(Config::from_toml("[fabric.packet]\nbackend = \"quantum\"\n").is_err());
        // cell outside [1, 64 MiB]
        assert!(Config::from_toml("[fabric.packet]\ncell_bytes = 0\n").is_err());
        assert!(
            Config::from_toml("[fabric.packet]\ncell_bytes = 134_217_728\n").is_err()
        );
        // NaN parses as a float but must fail closed
        assert!(Config::from_toml("[fabric.packet]\ncell_bytes = nan\n").is_err());
        assert!(Config::from_toml("[fabric.packet]\nbuffer_bytes = nan\n").is_err());
        // window smaller than one cell starves the injector
        assert!(Config::from_toml(
            "[fabric.packet]\ncell_bytes = 65_536\nbuffer_bytes = 1024\n"
        )
        .is_err());
        // absurd propagation latency
        assert!(Config::from_toml(
            "[fabric.packet]\nlatency_ns = 2_000_000_000\n"
        )
        .is_err());
        // unknown scheduler name fails closed
        assert!(
            Config::from_toml("[fabric.packet]\nscheduler = \"fifo\"\n").is_err()
        );
        // thread count bounds
        assert!(Config::from_toml("[fabric.packet]\nthreads = 0\n").is_err());
        assert!(Config::from_toml("[fabric.packet]\nthreads = 512\n").is_err());
    }
}
