//! The closed execution-time loop (paper §I: "NIMBLE performs
//! execution-time planning … redistributing traffic when runtime load
//! deviates from the plan"): **monitor → incremental replan →
//! mid-flight reroute**.
//!
//! [`ReplanExecutor`] flies one round of demands on a fabric backend —
//! any [`FabricBackend`]: the fluid engine by default, the packet-level
//! discrete-event simulator when `[fabric.packet] backend = "packet"`
//! (the loop itself is backend-agnostic) — and, every
//! [`ReplanCfg::cadence_s`] of virtual time,
//!
//! 1. samples the engine's per-link byte window into a
//!    [`WindowedMonitor`],
//! 2. derives the residual demands and the residual routing actually in
//!    flight,
//! 3. asks [`Planner::replan`] whether a challenger plan beats the
//!    incumbent by the hysteresis margin,
//! 4. if so, **preempts** the changed pairs' flows
//!    ([`FabricBackend::preempt`]) and re-issues their residual bytes on
//!    the new paths.
//!
//! Ordering across a reroute is preserved exactly as §IV promises: a
//! pair's chunks keep their original sequence numbers; a preempted
//! path's undelivered sequence numbers are redistributed over the new
//! paths; every path still delivers its own chunks in ascending order;
//! and the receiver's per-pair [`ReassemblyTable`] queue releases data
//! strictly in sequence. The executor simulates the worst-case
//! round-robin arrival interleave and panics if the reassembly
//! invariant is ever violated.
//!
//! With `enable == false` the engine runs the round in one shot — the
//! result is byte-identical to the static plan (see
//! `static_path_bit_identical_when_disabled`).

use super::monitor::WindowedMonitor;
use super::reassembly::{ChunkArrival, ReassemblyTable};
use super::reroute::{
    attach_reissues, pool_split_counts, preempt_and_pool, residual_routing, PartState, Reissue,
};
use crate::fabric::backend::{make_backend, FabricBackend, TailStats};
use crate::fabric::faults::{self, FaultSchedule};
use crate::fabric::fluid::{Flow, SimResult};
use crate::fabric::FabricParams;
use crate::metrics::CommReport;
use crate::planner::replan::{carry_plan, DrainCaps};
use crate::planner::{Demand, Plan, Planner, PlannerCfg, ReplanCfg};
use crate::telemetry::{
    emit_tail_histograms, DecisionCandidate, LinkBlame, Recorder, TraceRecord, ATTR_TOP_LINKS,
};
use crate::topology::{GpuId, Topology};
use std::collections::BTreeMap;

/// One replan epoch's bookkeeping.
#[derive(Clone, Copy, Debug)]
pub struct EpochStat {
    /// Virtual time at the epoch boundary.
    pub t_s: f64,
    /// Traffic-drift indicator: shape gap between the monitor's
    /// (rate-proportional) window estimates and the residual routing's
    /// byte shape. Nonzero whenever links drain at different speeds
    /// than their backlog share — a diagnostic, not the accept signal
    /// (the decision uses the drain-time metric in
    /// [`crate::planner::Planner::replan`]).
    pub deviation: f64,
    /// Whether a challenger plan was adopted.
    pub replanned: bool,
    /// Flows preempted at this epoch.
    pub preempted: usize,
    /// Payload bytes delivered over the epoch, as a rate (GB/s) — the
    /// time series `nimble faults` derives time-to-recover and goodput
    /// retention from.
    pub goodput_gbps: f64,
}

/// Outcome of one round under the execution-time loop.
pub struct ReplanRun {
    pub report: CommReport,
    pub sim: SimResult,
    /// The routing in force when the round finished (next round's
    /// incumbent).
    pub final_plan: Plan,
    pub epochs: Vec<EpochStat>,
    /// Epochs at which a challenger was adopted.
    pub replans: usize,
    /// Total flows preempted mid-transfer.
    pub preemptions: usize,
    /// Peak out-of-order chunks buffered in any reassembly queue.
    pub peak_reassembly: usize,
    /// Rate solves the fluid engine performed over the round — the
    /// hot-path volume the round generated. Preemption + re-issue grows
    /// this relative to the static arm; `nimble replan` reports both
    /// totals. (On the packet backend: discrete events processed.)
    pub sim_events: u64,
    /// Tail-latency / queue-depth observations, when the backend
    /// records them (packet backend only; `None` on the fluid engine).
    pub tail: Option<TailStats>,
}

/// Drives rounds of demands through the monitor → replan → reroute
/// loop. With `rcfg.enable == false` it degenerates to the static
/// plan-once path (one uninterrupted fluid run).
pub struct ReplanExecutor<'a> {
    pub topo: &'a Topology,
    pub params: FabricParams,
    pub planner_cfg: PlannerCfg,
    pub rcfg: ReplanCfg,
    /// Fault events injected at epoch boundaries (empty by default —
    /// and then completely inert: the fault-free code paths are
    /// bit-identical to builds without the fault layer). A non-empty
    /// schedule forces epoch-driven execution even with `rcfg.enable ==
    /// false`, so a *static* plan still experiences the faults — it
    /// just has no recovery lever.
    pub faults: FaultSchedule,
    /// Telemetry sink ([`Recorder::disabled`] by default — bitwise
    /// inert; see `crate::telemetry` for the observer-purity contract).
    pub rec: Recorder,
}

impl<'a> ReplanExecutor<'a> {
    pub fn new(
        topo: &'a Topology,
        params: FabricParams,
        planner_cfg: PlannerCfg,
        mut rcfg: ReplanCfg,
    ) -> Self {
        // planner and dataplane must agree on what is endpoint-bound
        rcfg.caps = DrainCaps::from(&params);
        ReplanExecutor {
            topo,
            params,
            planner_cfg,
            rcfg,
            faults: FaultSchedule::default(),
            rec: Recorder::disabled(),
        }
    }

    /// Attach a fault schedule (replayed from its start each round).
    pub fn with_faults(mut self, faults: FaultSchedule) -> Self {
        self.faults = faults;
        self
    }

    /// Attach a telemetry sink (cloned recorders share one trace).
    pub fn with_recorder(mut self, rec: Recorder) -> Self {
        self.rec = rec;
        self
    }

    /// Fly one round of `demands`, initially routed by scaling
    /// `incumbent`'s splits onto them (the execution-time situation: the
    /// plan predates the traffic). Returns timings plus the plan in
    /// force at the end, which becomes the next round's incumbent.
    pub fn execute(&mut self, incumbent: &Plan, demands: &[Demand]) -> ReplanRun {
        let topo = self.topo;
        let chunk = self.params.chunk_bytes.max(1.0);
        let plan0 = carry_plan(topo, incumbent, demands);

        // initial flows + chunk-sequence layout per pair
        let mut init_flows: Vec<Flow> = Vec::new();
        let mut streams: BTreeMap<(GpuId, GpuId), Vec<PartState>> = BTreeMap::new();
        let mut chunks_per_pair: BTreeMap<(GpuId, GpuId), u64> = BTreeMap::new();
        for (&pair, a) in &plan0.assignments {
            let mut base = 0u64;
            let mut parts = Vec::new();
            for (path, bytes) in &a.parts {
                let n = (bytes / chunk).ceil().max(1.0) as u64;
                parts.push(PartState {
                    flow: init_flows.len(),
                    seqs: (base..base + n).collect(),
                    delivered: 0,
                });
                init_flows.push(Flow::new(path.clone(), *bytes));
                base += n;
            }
            streams.insert(pair, parts);
            chunks_per_pair.insert(pair, base);
        }

        // the engine owns the flow list from here on; parts reference
        // flows by engine index only. `params.backend` selects the
        // implementation; the loop below is identical either way.
        let mut engine = make_backend(topo, self.params.clone(), &init_flows);
        let mut total_flows = init_flows.len();
        drop(init_flows);
        let mut reass = ReassemblyTable::default();
        let mut planner = Planner::new(topo, self.planner_cfg.clone());
        let cadence = self.rcfg.cadence_s.max(1e-6);
        let mut monitor = WindowedMonitor::new(topo, cadence);
        let mut epochs: Vec<EpochStat> = Vec::new();
        let mut replans = 0usize;
        let mut preemptions = 0usize;
        let mut final_plan = plan0.clone();

        // wall-clock self-profiling for the `profile` trace record;
        // the disabled recorder takes no timestamps at all
        let mut plan_wall_s = 0.0f64;
        let mut sim_wall_s = 0.0f64;

        if !self.rcfg.enable && self.faults.is_empty() {
            let t_wall = self.rec.on().then(std::time::Instant::now);
            engine
                .run_to_completion()
                .expect("fault-free run cannot stall: every link keeps capacity");
            if let Some(t) = t_wall {
                sim_wall_s += t.elapsed().as_secs_f64();
            }
        } else {
            // faults replay from the schedule start each round; a
            // per-link scale vector mirrors the backend's state for the
            // planner ([`Planner::set_link_health`]). All of this is
            // no-op bookkeeping when the schedule is empty.
            let mut faults = self.faults.clone();
            faults.reset();
            let mut fault_scale = vec![1.0f64; topo.links.len()];
            let mut any_dead = false;
            let mut moved_prev = 0.0f64;
            let mut stalled = 0usize;
            let mut t_next = cadence;
            let mut attr_epoch = 0u64;
            while !engine.is_done() {
                let t_wall = self.rec.on().then(std::time::Instant::now);
                engine
                    .advance_to(t_next)
                    .expect("bounded epoch advance cannot stall");
                if let Some(t) = t_wall {
                    sim_wall_s += t.elapsed().as_secs_f64();
                }
                let t_epoch = t_next;
                t_next += cadence;

                // fault events take effect at the first epoch boundary
                // at or after their fire time
                let due: Vec<crate::fabric::FaultEvent> = faults.due(t_epoch).to_vec();
                if !due.is_empty() {
                    for ev in &due {
                        engine.apply_fault(&ev.fault);
                        faults::apply_to_scale(&mut fault_scale, topo, &ev.fault);
                        self.rec.emit(|| TraceRecord::Fault {
                            t_s: t_epoch,
                            desc: format!("{:?}", ev.fault),
                        });
                    }
                    any_dead = fault_scale.iter().any(|&s| s <= 0.0);
                    let healthy = fault_scale.iter().all(|&s| s >= 1.0);
                    planner.set_link_health(if healthy {
                        None
                    } else {
                        Some(fault_scale.clone())
                    });
                }

                // per-epoch goodput: the recovery time series. A long
                // stall means a permanently dead link with no recovery
                // path (static plan + no restore) — fail loudly rather
                // than spin forever.
                let moved: f64 = (0..total_flows).map(|i| engine.moved_bytes(i)).sum();
                let goodput_gbps = (moved - moved_prev) / cadence / 1e9;
                stalled = if moved > moved_prev { 0 } else { stalled + 1 };
                moved_prev = moved;
                assert!(
                    stalled < 100_000,
                    "no progress for 100k epochs — dead link with no recovery path?"
                );

                if engine.is_done() {
                    if !self.faults.is_empty() {
                        epochs.push(EpochStat {
                            t_s: engine.now(),
                            deviation: 0.0,
                            replanned: false,
                            preempted: 0,
                            goodput_gbps,
                        });
                        // final partial epoch: the engine drained before
                        // the boundary, so the window was never sampled —
                        // the snapshot reports the last observed window
                        self.rec.emit(|| {
                            let snap = monitor.snapshot();
                            TraceRecord::Epoch {
                                epoch: (epochs.len() - 1) as u64,
                                t_s: engine.now(),
                                goodput_gbps,
                                congestion: snap.congestion,
                                deviation: 0.0,
                                replanned: false,
                                preempted: 0,
                                util: snap.util,
                            }
                        });
                    }
                    break;
                }
                // sample the engine's window; with the recorder live,
                // take the attributed form — its `totals` are produced
                // by the same canonical per-link summation, so the
                // monitor sees bit-identical bytes either way — and
                // emit the blame decomposition of the hottest links
                if self.rec.on() {
                    let attr = engine.take_window_attr();
                    let links = LinkBlame::hottest(&attr, ATTR_TOP_LINKS);
                    let epoch = attr_epoch;
                    self.rec.emit(|| TraceRecord::Attribution { t_s: t_epoch, epoch, links });
                    attr_epoch += 1;
                    monitor.observe(&attr.totals);
                } else {
                    monitor.observe(&engine.take_window());
                }

                // residual demands + the residual routing in flight
                // (shared extraction — [`residual_routing`]); pairs with
                // a live part crossing a dead link are *forced* replan
                // targets (their drain time is infinite)
                let res = residual_routing(
                    &streams,
                    engine.as_ref(),
                    topo.links.len(),
                    if any_dead { Some(fault_scale.as_slice()) } else { None },
                );
                if res.demands.is_empty() {
                    continue;
                }
                let residual_demands = res.demands;
                let forced = res.forced;
                let in_flight = Plan {
                    assignments: res.assignments,
                    link_load: res.link_load,
                    plan_time_s: 0.0,
                };

                let t_wall = self.rec.on().then(std::time::Instant::now);
                let out = planner.replan_forced(
                    &in_flight,
                    monitor.load_estimates(),
                    &residual_demands,
                    &self.rcfg,
                    &forced,
                );
                if let Some(t) = t_wall {
                    plan_wall_s += t.elapsed().as_secs_f64();
                }
                if let Some(a) = out.audit {
                    self.rec.emit(|| TraceRecord::Decision {
                        t_s: t_epoch,
                        tenant: -1,
                        accepted: out.replanned,
                        forced: a.forced,
                        z_carry: a.z_carry,
                        z_challenger: a.z_challenger,
                        margin: a.margin,
                        mwu_visits: a.mwu_visits,
                        changed_pairs: out.changed_pairs.len(),
                        candidates: a
                            .candidates
                            .iter()
                            .map(|c| DecisionCandidate {
                                name: c.name.to_string(),
                                z_s: c.z_s,
                                delta_s: c.delta_s,
                                binding: c.binding.clone(),
                            })
                            .collect(),
                    });
                }
                let mut preempted_here = 0usize;
                if out.replanned {
                    replans += 1;
                    let now = engine.now();
                    // one engine registration per epoch: accumulate every
                    // changed pair's re-issued flows, then add_flows once
                    // (each call rebuilds the full constraint structure)
                    let mut epoch_batch: Vec<Flow> = Vec::new();
                    let mut reissues: Vec<Reissue> = Vec::new();
                    for &pair in &out.changed_pairs {
                        let Some(newa) = out.plan.assignments.get(&pair) else {
                            continue;
                        };
                        let Some(parts) = streams.get_mut(&pair) else { continue };
                        // preempt live parts; release their completed
                        // chunk prefixes; pool the undelivered seqs
                        let (pool, n_pre) = preempt_and_pool(
                            engine.as_mut(),
                            &mut reass,
                            pair,
                            parts,
                            chunk,
                            &mut |_| {},
                        );
                        preempted_here += n_pre;
                        // stage the residual on the new paths; the pooled
                        // seqs are split across them by byte share
                        let total_new = newa.total_bytes().max(1.0);
                        let batch_off = epoch_batch.len();
                        let mut shares: Vec<f64> = Vec::new();
                        for (path, bytes) in &newa.parts {
                            epoch_batch.push(Flow::new(path.clone(), *bytes).at(now));
                            shares.push(*bytes);
                        }
                        let counts = pool_split_counts(&shares, total_new, pool.len());
                        reissues.push(Reissue { pair, batch_off, counts, pool });
                    }
                    total_flows += epoch_batch.len();
                    let first = engine.add_flows(&epoch_batch);
                    attach_reissues(&mut streams, first, reissues);
                    preemptions += preempted_here;
                    // merge the adopted splits into the full-round plan:
                    // pairs that already drained keep their original
                    // routing as next round's incumbent preference
                    for (pair, a) in &out.plan.assignments {
                        final_plan.assignments.insert(*pair, a.clone());
                    }
                    let mut merged_load = vec![0.0f64; topo.links.len()];
                    for a in final_plan.assignments.values() {
                        for (p, b) in &a.parts {
                            for &h in &p.hops {
                                merged_load[h] += *b;
                            }
                        }
                    }
                    final_plan.link_load = merged_load;
                }
                epochs.push(EpochStat {
                    t_s: engine.now(),
                    deviation: out.deviation,
                    replanned: out.replanned,
                    preempted: preempted_here,
                    goodput_gbps,
                });
                self.rec.emit(|| {
                    let snap = monitor.snapshot();
                    TraceRecord::Epoch {
                        epoch: (epochs.len() - 1) as u64,
                        t_s: engine.now(),
                        goodput_gbps,
                        congestion: snap.congestion,
                        deviation: out.deviation,
                        replanned: out.replanned,
                        preempted: preempted_here,
                        util: snap.util,
                    }
                });
            }
        }

        // deliver every remaining chunk, worst-case interleaved
        // round-robin across each pair's paths, through reassembly
        for (&pair, parts) in streams.iter_mut() {
            let mut live = true;
            while live {
                live = false;
                for ps in parts.iter_mut() {
                    if ps.delivered < ps.seqs.len() {
                        reass
                            .push(
                                pair.0,
                                pair.1,
                                ChunkArrival {
                                    seq: ps.seqs[ps.delivered],
                                    bytes: chunk as u64,
                                },
                            )
                            .expect("ordering invariant violated");
                        ps.delivered += 1;
                        live = true;
                    }
                }
            }
            let q = reass.stream(pair.0, pair.1).expect("stream exists");
            assert!(q.is_drained(), "stream {pair:?} not fully reassembled");
            assert_eq!(
                q.delivered_bytes(),
                chunks_per_pair[&pair] * chunk as u64,
                "stream {pair:?} lost chunks across reroutes"
            );
        }

        let sim_events = engine.events();
        let tail = engine.tail();
        if let Some(t) = &tail {
            emit_tail_histograms(&self.rec, t);
        }
        let sim = engine.result();
        let payload: f64 = demands.iter().map(|d| d.bytes).sum();
        let name = if self.rcfg.enable { "nimble-replan" } else { "nimble-static" };
        let report = CommReport::from_sim(name, topo, &sim, payload);
        self.rec.emit(|| TraceRecord::Summary {
            makespan_s: report.makespan_s,
            payload_bytes: report.payload_bytes,
            goodput_gbps: report.goodput_gbps(),
            replans: replans as u64,
            preemptions: preemptions as u64,
            sim_events,
        });
        self.rec.emit(|| TraceRecord::Profile {
            engine: engine.profile(),
            mwu_plans: planner.mwu_plans(),
            mwu_visits: planner.mwu_total_visits(),
            plan_wall_s,
            sim_wall_s,
        });
        let peak_reassembly = streams
            .keys()
            .filter_map(|&(s, d)| reass.stream(s, d).map(|q| q.peak_pending))
            .max()
            .unwrap_or(0);
        ReplanRun {
            report,
            sim,
            final_plan,
            epochs,
            replans,
            preemptions,
            peak_reassembly,
            sim_events,
            tail,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: f64 = 1024.0 * 1024.0;

    fn enabled(cadence_s: f64) -> ReplanCfg {
        ReplanCfg { enable: true, cadence_s, margin: 0.1, ..ReplanCfg::default() }
    }

    /// A stale single-path plan for a now-heavy pair gets rerouted
    /// mid-flight, beats the static execution, and the receiver still
    /// sees every chunk exactly once, in order.
    #[test]
    fn midflight_reroute_beats_static_and_keeps_ordering() {
        let topo = Topology::paper();
        let params = FabricParams::default();
        // incumbent planned when (2,1) was tiny: direct path only
        let mut planner = Planner::new(&topo, PlannerCfg::default());
        let incumbent = planner.plan(&[Demand::new(2, 1, 2.0 * MB)]);
        let demands = vec![Demand::new(2, 1, 512.0 * MB)];

        let mut stat = ReplanExecutor::new(
            &topo,
            params.clone(),
            PlannerCfg::default(),
            ReplanCfg::default(),
        );
        let static_run = stat.execute(&incumbent, &demands);

        let mut dyn_ = ReplanExecutor::new(
            &topo,
            params,
            PlannerCfg::default(),
            enabled(2.0e-4),
        );
        let replan_run = dyn_.execute(&incumbent, &demands);

        assert!(replan_run.replans >= 1, "no replan fired");
        assert!(replan_run.preemptions >= 1, "no flow was preempted");
        // multi-path reroute buffers out-of-order chunks at the receiver
        assert!(replan_run.peak_reassembly >= 1);
        assert!(
            replan_run.report.makespan_s < static_run.report.makespan_s * 0.75,
            "reroute gained too little: {} vs {}",
            replan_run.report.makespan_s,
            static_run.report.makespan_s
        );
    }

    /// The loop is genuinely backend-agnostic: the same stale-plan
    /// scenario flies on the packet backend, replans mid-flight, keeps
    /// the reassembly ordering invariant (asserted inside `execute`
    /// on every round), conserves the stream payload across the
    /// reroute, and reports the tail stats only that backend records.
    #[test]
    fn packet_backend_reroutes_and_reports_tails() {
        let topo = Topology::paper();
        let params = FabricParams {
            backend: crate::fabric::BackendKind::Packet,
            ..FabricParams::default()
        };
        let mut planner = Planner::new(&topo, PlannerCfg::default());
        let incumbent = planner.plan(&[Demand::new(2, 1, 2.0 * MB)]);
        let payload = 256.0 * MB;
        let demands = vec![Demand::new(2, 1, payload)];
        let mut ex =
            ReplanExecutor::new(&topo, params, PlannerCfg::default(), enabled(2.0e-4));
        let run = ex.execute(&incumbent, &demands);
        assert!(run.replans >= 1, "no replan fired on the packet backend");
        assert!(run.preemptions >= 1, "no flow was preempted");
        let tail = run.tail.expect("packet backend records tails");
        assert!(tail.delivered_chunks > 0);
        assert_eq!(tail.sojourn.total(), tail.transit.total());
        // the stream arrived in full across the mid-flight reroute
        let delivered: f64 = run.sim.flows.iter().map(|f| f.bytes).sum();
        assert!((delivered - payload).abs() < 16.0, "delivered {delivered}");
    }

    /// Disabled replanning is the static path, bit for bit.
    #[test]
    fn static_path_bit_identical_when_disabled() {
        let topo = Topology::paper();
        let params = FabricParams::default();
        let demands = vec![
            Demand::new(0, 1, 256.0 * MB),
            Demand::new(4, 1, 96.0 * MB),
            Demand::new(2, 3, 64.0 * MB),
        ];
        let mut planner = Planner::new(&topo, PlannerCfg::default());
        let plan = planner.plan(&demands);

        let run = |rcfg: ReplanCfg| {
            ReplanExecutor::new(&topo, params.clone(), PlannerCfg::default(), rcfg)
                .execute(&plan, &demands)
        };
        let a = run(ReplanCfg::default());
        let b = run(ReplanCfg::default());
        assert_eq!(a.report.makespan_s.to_bits(), b.report.makespan_s.to_bits());
        assert_eq!(a.sim.link_bytes, b.sim.link_bytes);
        assert_eq!(a.replans, 0);
        assert_eq!(a.preemptions, 0);

        // and identical to a plain one-shot fluid run of the same plan
        let flows: Vec<Flow> = plan
            .assignments
            .values()
            .flat_map(|asg| asg.parts.iter().cloned())
            .map(|(p, bytes)| Flow::new(p, bytes))
            .collect();
        let direct = crate::fabric::fluid::FluidSim::new(&topo, params).run(&flows);
        assert_eq!(a.report.makespan_s.to_bits(), direct.makespan.to_bits());
    }

    /// A mid-flight link flap: the replan loop preempts the flows
    /// frozen on the dead rail and re-routes their residuals, finishing
    /// well before the static plan (which must wait out the outage).
    /// Byte conservation and reassembly ordering are asserted inside
    /// `execute` either way.
    #[test]
    fn fault_flap_recovers_via_replan_and_beats_static() {
        let topo = Topology::paper();
        let params = FabricParams::default();
        let payload = 512.0 * MB;
        let demands = vec![Demand::new(0, 4, payload)];
        let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
        let dead = topo.rail(0, 1, 0).unwrap();
        let sched = FaultSchedule::new(vec![
            crate::fabric::FaultEvent {
                t_s: 1.0e-3,
                fault: crate::fabric::Fault::LinkDown { link: dead },
            },
            crate::fabric::FaultEvent {
                t_s: 3.0e-3,
                fault: crate::fabric::Fault::LinkUp { link: dead },
            },
        ]);

        let static_run = ReplanExecutor::new(
            &topo,
            params.clone(),
            PlannerCfg::default(),
            ReplanCfg::default(),
        )
        .with_faults(sched.clone())
        .execute(&plan, &demands);
        let replan_run =
            ReplanExecutor::new(&topo, params, PlannerCfg::default(), enabled(2.0e-4))
                .with_faults(sched)
                .execute(&plan, &demands);

        assert!(replan_run.replans >= 1, "flap did not force a replan");
        assert!(replan_run.preemptions >= 1, "no frozen flow was preempted");
        for run in [&static_run, &replan_run] {
            let delivered: f64 = run.sim.flows.iter().map(|f| f.bytes).sum();
            assert!((delivered - payload).abs() < 16.0, "lost bytes: {delivered}");
        }
        assert!(
            replan_run.report.makespan_s < static_run.report.makespan_s * 0.99,
            "replan {} did not beat static {} on a flap",
            replan_run.report.makespan_s,
            static_run.report.makespan_s
        );
        // the static plan cannot finish before the link restores
        assert!(static_run.report.makespan_s >= 3.0e-3);
    }

    /// A degraded rail (no dead links, so no forced pairs): recovery
    /// must come from the scaled drain-time acceptance — the planner
    /// re-prices the throttled rail and the challenger wins on z alone.
    #[test]
    fn fault_degrade_recovers_via_repricing() {
        let topo = Topology::paper();
        let params = FabricParams::default();
        let demands = vec![Demand::new(0, 4, 512.0 * MB)];
        let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
        let sched = FaultSchedule::new(vec![crate::fabric::FaultEvent {
            t_s: 1.0e-3,
            fault: crate::fabric::Fault::RailDegraded { rail: 0, factor: 0.25 },
        }]);

        let static_run = ReplanExecutor::new(
            &topo,
            params.clone(),
            PlannerCfg::default(),
            ReplanCfg::default(),
        )
        .with_faults(sched.clone())
        .execute(&plan, &demands);
        let replan_run =
            ReplanExecutor::new(&topo, params, PlannerCfg::default(), enabled(2.0e-4))
                .with_faults(sched)
                .execute(&plan, &demands);

        assert!(replan_run.replans >= 1, "degrade did not trigger a replan");
        assert!(
            replan_run.report.makespan_s < static_run.report.makespan_s * 0.9,
            "repricing gained too little: {} vs {}",
            replan_run.report.makespan_s,
            static_run.report.makespan_s
        );
    }

    /// A balanced, well-matched round is left alone entirely (no
    /// churn), and on endpoint-bound heavy pairs the loop only fires
    /// when re-leveling the residuals genuinely pays — it never loses
    /// to leaving the plan alone.
    #[test]
    fn matched_plan_never_hurt_by_loop() {
        let topo = Topology::paper();
        let params = FabricParams::default();

        // balanced hot-row round 0: plan matches traffic ⇒ zero replans
        let sched = crate::workloads::dynamic::PhasedHotRows::paper_default(
            &topo,
            64.0 * MB,
        );
        let demands = sched.demands_at(&topo, 0);
        let mut planner = Planner::new(&topo, PlannerCfg::default());
        let plan = planner.plan(&demands);
        let mut ex = ReplanExecutor::new(
            &topo,
            params.clone(),
            PlannerCfg::default(),
            enabled(2.0e-4),
        );
        let run = ex.execute(&plan, &demands);
        assert_eq!(run.replans, 0, "churned a matched balanced plan");
        assert_eq!(run.preemptions, 0);
        assert!(!run.epochs.is_empty(), "loop never sampled");

        // endpoint-bound heavy pairs: residual drain deviates from the
        // plan's split (the recv cap equalizes flow rates), so the loop
        // may re-level — but adoption must strictly pay for itself
        let demands = vec![
            Demand::new(0, 1, 256.0 * MB),
            Demand::new(2, 1, 128.0 * MB),
        ];
        let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
        let static_run = ReplanExecutor::new(
            &topo,
            params.clone(),
            PlannerCfg::default(),
            ReplanCfg::default(),
        )
        .execute(&plan, &demands);
        let looped = ReplanExecutor::new(
            &topo,
            params,
            PlannerCfg::default(),
            enabled(2.0e-4),
        )
        .execute(&plan, &demands);
        assert!(
            looped.report.makespan_s <= static_run.report.makespan_s * 1.001,
            "loop hurt a matched plan: {} vs {}",
            looped.report.makespan_s,
            static_run.report.makespan_s
        );
    }
}
