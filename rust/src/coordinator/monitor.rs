//! Lightweight runtime monitoring (paper §IV-A component 1): per-link
//! load tracking with exponential decay and hysteresis so the
//! orchestration engine sees a stable view of live link pressure and
//! path selection does not oscillate between near-equal alternatives.
//!
//! Two monitors, two cadences:
//!
//! * [`LinkMonitor`] — per-*round* EWMA estimates feeding
//!   [`crate::coordinator::NimbleRouter`]'s warm start between rounds;
//! * [`WindowedMonitor`] — per-*epoch* utilization/backlog estimates
//!   sampled from the fluid engine at a configurable cadence, feeding
//!   the mid-flight [`crate::planner::Planner::replan`] loop.

use crate::topology::Topology;

/// EWMA link-load monitor with hysteresis gating.
#[derive(Clone, Debug)]
pub struct LinkMonitor {
    /// Smoothed byte-load estimate per link.
    ewma: Vec<f64>,
    /// Last value actually *published* to the planner per link.
    published: Vec<f64>,
    /// EWMA smoothing factor (weight of the newest observation).
    pub alpha: f64,
    /// Relative change required before a new estimate is published
    /// (hysteresis; avoids plan churn on noise).
    pub publish_threshold: f64,
    /// How many times publication was suppressed (oscillation metric).
    pub suppressed: u64,
    /// How many times a new value was published.
    pub published_count: u64,
}

impl LinkMonitor {
    pub fn new(links: usize) -> Self {
        LinkMonitor {
            ewma: vec![0.0; links],
            published: vec![0.0; links],
            alpha: 0.5,
            publish_threshold: 0.1,
            suppressed: 0,
            published_count: 0,
        }
    }

    /// Fold one round's observed per-link byte counts into the EWMA.
    pub fn observe(&mut self, link_bytes: &[f64]) {
        assert_eq!(link_bytes.len(), self.ewma.len());
        for (e, &o) in self.ewma.iter_mut().zip(link_bytes) {
            *e = (1.0 - self.alpha) * *e + self.alpha * o;
        }
        // hysteresis: publish a link's estimate only on meaningful change
        for i in 0..self.ewma.len() {
            let old = self.published[i];
            let new = self.ewma[i];
            let denom = old.abs().max(1.0);
            if (new - old).abs() / denom > self.publish_threshold {
                self.published[i] = new;
                self.published_count += 1;
            } else if (new - old).abs() > 0.0 {
                self.suppressed += 1;
            }
        }
    }

    /// Estimates the planner warm-starts from (hysteresis-stabilized).
    pub fn load_estimates(&self) -> &[f64] {
        &self.published
    }

    /// Raw EWMA (no hysteresis) — used by the ablation.
    pub fn raw_estimates(&self) -> &[f64] {
        &self.ewma
    }

    /// Decay all estimates (e.g. idle periods between phases).
    pub fn decay(&mut self, factor: f64) {
        for e in self.ewma.iter_mut() {
            *e *= factor;
        }
    }

    pub fn reset(&mut self) {
        self.ewma.iter_mut().for_each(|e| *e = 0.0);
        self.published.iter_mut().for_each(|e| *e = 0.0);
    }
}

/// One epoch's link-utilization picture, sampled from a
/// [`WindowedMonitor`] right after [`WindowedMonitor::observe`]. This
/// is the shared per-epoch observability surface consumed by both the
/// single-job and multi-tenant executors (telemetry `epoch` records)
/// — previously each derived its own view inline.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MonitorSnapshot {
    /// Capacity-normalized per-link utilization over the last window,
    /// **unclamped**: a transient value above 1.0 marks a link that
    /// moved more bytes than its nominal capacity·window allows (burst
    /// drain after a stall), which the planner-facing clamped
    /// [`WindowedMonitor::utilization`] view hides.
    pub util: Vec<f64>,
    /// Max over `util` — the capacity-normalized max-congestion of the
    /// last window (the execution-time analogue of the planner's Z).
    pub congestion: f64,
}

/// Windowed per-link utilization/backlog monitor for the execution-time
/// re-planning loop: every `cadence_s` of virtual time the coordinator
/// feeds it the bytes each link moved during the window (from
/// [`crate::fabric::fluid::SimEngine::take_window`]) and reads back
///
/// * instantaneous **utilization** (window bytes / capacity·window),
/// * an **EWMA byte-load estimate** per link (what
///   [`crate::planner::Planner::replan`] consumes as `observed_loads`),
/// * cumulative delivered bytes, from which per-link **backlog**
///   against a plan's expected loads is derived.
#[derive(Clone, Debug)]
pub struct WindowedMonitor {
    caps_bps: Vec<f64>,
    /// Sampling cadence in virtual seconds.
    pub cadence_s: f64,
    /// EWMA smoothing factor (weight of the newest window).
    pub alpha: f64,
    ewma_bytes: Vec<f64>,
    last_util: Vec<f64>,
    last_raw_util: Vec<f64>,
    cum_bytes: Vec<f64>,
    /// Number of windows observed so far.
    pub windows: u64,
}

impl WindowedMonitor {
    pub fn new(topo: &Topology, cadence_s: f64) -> Self {
        let links = topo.links.len();
        WindowedMonitor {
            caps_bps: topo.links.iter().map(|l| l.cap_gbps * 1e9).collect(),
            cadence_s,
            alpha: 0.5,
            ewma_bytes: vec![0.0; links],
            last_util: vec![0.0; links],
            last_raw_util: vec![0.0; links],
            cum_bytes: vec![0.0; links],
            windows: 0,
        }
    }

    /// Fold one sampling window taken at the configured cadence.
    pub fn observe(&mut self, window_bytes: &[f64]) {
        self.observe_window(window_bytes, self.cadence_s);
    }

    /// Fold one sampling window (per-link bytes over `dt` seconds) —
    /// the explicit-duration form for irregular windows.
    pub fn observe_window(&mut self, window_bytes: &[f64], dt: f64) {
        assert_eq!(window_bytes.len(), self.ewma_bytes.len());
        let dt = dt.max(1e-12);
        self.windows += 1;
        // first window seeds the EWMA directly (no zero-bias ramp-up)
        let alpha = if self.windows == 1 { 1.0 } else { self.alpha };
        for i in 0..window_bytes.len() {
            let w = window_bytes[i];
            self.cum_bytes[i] += w;
            let u = w / (self.caps_bps[i] * dt);
            self.last_raw_util[i] = u;
            self.last_util[i] = u.min(1.0);
            self.ewma_bytes[i] = (1.0 - alpha) * self.ewma_bytes[i] + alpha * w;
        }
    }

    /// Smoothed per-link byte loads (the replan loop's `observed_loads`).
    pub fn load_estimates(&self) -> &[f64] {
        &self.ewma_bytes
    }

    /// Utilization (0..1) of each link over the last window.
    pub fn utilization(&self) -> &[f64] {
        &self.last_util
    }

    /// The last window's utilization picture as one value: unclamped
    /// per-link utilization plus its max (capacity-normalized
    /// max-congestion). Pure read — sampling never perturbs the
    /// monitor's planner-facing estimates.
    pub fn snapshot(&self) -> MonitorSnapshot {
        let congestion = self.last_raw_util.iter().cloned().fold(0.0f64, f64::max);
        MonitorSnapshot { util: self.last_raw_util.clone(), congestion }
    }

    /// Total bytes each link carried since construction/reset.
    pub fn cumulative_bytes(&self) -> &[f64] {
        &self.cum_bytes
    }

    /// Per-link backlog against a plan: expected bytes not yet seen on
    /// the wire (clamped at zero where execution ran ahead).
    pub fn backlog(&self, planned_bytes: &[f64]) -> Vec<f64> {
        planned_bytes
            .iter()
            .zip(&self.cum_bytes)
            .map(|(&p, &c)| (p - c).max(0.0))
            .collect()
    }

    pub fn reset(&mut self) {
        self.ewma_bytes.iter_mut().for_each(|x| *x = 0.0);
        self.last_util.iter_mut().for_each(|x| *x = 0.0);
        self.last_raw_util.iter_mut().for_each(|x| *x = 0.0);
        self.cum_bytes.iter_mut().for_each(|x| *x = 0.0);
        self.windows = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges_to_steady_load() {
        let mut m = LinkMonitor::new(2);
        for _ in 0..20 {
            m.observe(&[100.0, 0.0]);
        }
        assert!((m.raw_estimates()[0] - 100.0).abs() < 1e-3);
        assert_eq!(m.raw_estimates()[1], 0.0);
    }

    #[test]
    fn hysteresis_suppresses_noise() {
        let mut m = LinkMonitor::new(1);
        m.observe(&[1000.0]);
        let published_after_first = m.load_estimates()[0];
        assert!(published_after_first > 0.0);
        let count0 = m.published_count;
        // ±2% noise around the steady state: published value must not
        // chase it
        for i in 0..50 {
            let noise = if i % 2 == 0 { 1020.0 } else { 980.0 };
            m.observe(&[noise]);
        }
        assert!(m.suppressed > 20, "suppressed={}", m.suppressed);
        // few publications beyond the initial convergence
        assert!(m.published_count - count0 <= 4);
    }

    #[test]
    fn big_shift_publishes() {
        let mut m = LinkMonitor::new(1);
        for _ in 0..10 {
            m.observe(&[100.0]);
        }
        let before = m.load_estimates()[0];
        for _ in 0..10 {
            m.observe(&[10_000.0]);
        }
        assert!(m.load_estimates()[0] > before * 10.0);
    }

    #[test]
    fn decay_and_reset() {
        let mut m = LinkMonitor::new(1);
        m.observe(&[100.0]);
        m.decay(0.5);
        assert!((m.raw_estimates()[0] - 25.0).abs() < 1e-9); // 50 ewma → 25
        m.reset();
        assert_eq!(m.raw_estimates()[0], 0.0);
        assert_eq!(m.load_estimates()[0], 0.0);
    }

    #[test]
    fn windowed_utilization_and_cumulative() {
        let topo = Topology::paper();
        let mut m = WindowedMonitor::new(&topo, 1e-3);
        let link = topo.nvlink(0, 1).unwrap();
        let cap = topo.link(link).cap_gbps * 1e9;
        let mut w = vec![0.0; topo.links.len()];
        w[link] = cap * 1e-3 * 0.5; // half utilization over the window
        m.observe_window(&w, 1e-3);
        assert!((m.utilization()[link] - 0.5).abs() < 1e-12);
        // first window seeds the EWMA directly
        assert_eq!(m.load_estimates()[link], w[link]);
        // observe() uses the configured cadence as the window duration
        m.observe(&w);
        assert!((m.utilization()[link] - 0.5).abs() < 1e-12);
        assert!((m.cumulative_bytes()[link] - 2.0 * w[link]).abs() < 1e-6);
        assert_eq!(m.windows, 2);
    }

    #[test]
    fn snapshot_reports_unclamped_congestion() {
        let topo = Topology::paper();
        let mut m = WindowedMonitor::new(&topo, 1e-3);
        let link = topo.nvlink(0, 1).unwrap();
        let cap = topo.link(link).cap_gbps * 1e9;
        let mut w = vec![0.0; topo.links.len()];
        // burst drain: 1.5x the window's capacity worth of bytes
        w[link] = cap * 1e-3 * 1.5;
        m.observe_window(&w, 1e-3);
        // the planner-facing view clamps; the snapshot does not
        assert_eq!(m.utilization()[link], 1.0);
        let snap = m.snapshot();
        assert!((snap.util[link] - 1.5).abs() < 1e-12);
        assert!((snap.congestion - 1.5).abs() < 1e-12);
        assert_eq!(
            snap.congestion,
            snap.util.iter().cloned().fold(0.0f64, f64::max)
        );
        m.reset();
        assert_eq!(m.snapshot().congestion, 0.0);
    }

    #[test]
    fn windowed_backlog_tracks_plan() {
        let topo = Topology::paper();
        let mut m = WindowedMonitor::new(&topo, 1e-3);
        let link = topo.nvlink(0, 1).unwrap();
        let mut planned = vec![0.0; topo.links.len()];
        planned[link] = 100.0;
        let mut w = vec![0.0; topo.links.len()];
        w[link] = 30.0;
        m.observe_window(&w, 1e-3);
        assert_eq!(m.backlog(&planned)[link], 70.0);
        m.observe_window(&w, 1e-3);
        m.observe_window(&w, 1e-3);
        m.observe_window(&w, 1e-3);
        // execution ran ahead of the plan: clamped at zero
        assert_eq!(m.backlog(&planned)[link], 0.0);
        m.reset();
        assert_eq!(m.backlog(&planned)[link], 100.0);
        assert_eq!(m.windows, 0);
    }
}
