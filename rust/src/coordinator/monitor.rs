//! Lightweight runtime monitoring (paper §IV-A component 1): per-link
//! load tracking with exponential decay and hysteresis so the
//! orchestration engine sees a stable view of live link pressure and
//! path selection does not oscillate between near-equal alternatives.

/// EWMA link-load monitor with hysteresis gating.
#[derive(Clone, Debug)]
pub struct LinkMonitor {
    /// Smoothed byte-load estimate per link.
    ewma: Vec<f64>,
    /// Last value actually *published* to the planner per link.
    published: Vec<f64>,
    /// EWMA smoothing factor (weight of the newest observation).
    pub alpha: f64,
    /// Relative change required before a new estimate is published
    /// (hysteresis; avoids plan churn on noise).
    pub publish_threshold: f64,
    /// How many times publication was suppressed (oscillation metric).
    pub suppressed: u64,
    /// How many times a new value was published.
    pub published_count: u64,
}

impl LinkMonitor {
    pub fn new(links: usize) -> Self {
        LinkMonitor {
            ewma: vec![0.0; links],
            published: vec![0.0; links],
            alpha: 0.5,
            publish_threshold: 0.1,
            suppressed: 0,
            published_count: 0,
        }
    }

    /// Fold one round's observed per-link byte counts into the EWMA.
    pub fn observe(&mut self, link_bytes: &[f64]) {
        assert_eq!(link_bytes.len(), self.ewma.len());
        for (e, &o) in self.ewma.iter_mut().zip(link_bytes) {
            *e = (1.0 - self.alpha) * *e + self.alpha * o;
        }
        // hysteresis: publish a link's estimate only on meaningful change
        for i in 0..self.ewma.len() {
            let old = self.published[i];
            let new = self.ewma[i];
            let denom = old.abs().max(1.0);
            if (new - old).abs() / denom > self.publish_threshold {
                self.published[i] = new;
                self.published_count += 1;
            } else if (new - old).abs() > 0.0 {
                self.suppressed += 1;
            }
        }
    }

    /// Estimates the planner warm-starts from (hysteresis-stabilized).
    pub fn load_estimates(&self) -> &[f64] {
        &self.published
    }

    /// Raw EWMA (no hysteresis) — used by the ablation.
    pub fn raw_estimates(&self) -> &[f64] {
        &self.ewma
    }

    /// Decay all estimates (e.g. idle periods between phases).
    pub fn decay(&mut self, factor: f64) {
        for e in self.ewma.iter_mut() {
            *e *= factor;
        }
    }

    pub fn reset(&mut self) {
        self.ewma.iter_mut().for_each(|e| *e = 0.0);
        self.published.iter_mut().for_each(|e| *e = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges_to_steady_load() {
        let mut m = LinkMonitor::new(2);
        for _ in 0..20 {
            m.observe(&[100.0, 0.0]);
        }
        assert!((m.raw_estimates()[0] - 100.0).abs() < 1e-3);
        assert_eq!(m.raw_estimates()[1], 0.0);
    }

    #[test]
    fn hysteresis_suppresses_noise() {
        let mut m = LinkMonitor::new(1);
        m.observe(&[1000.0]);
        let published_after_first = m.load_estimates()[0];
        assert!(published_after_first > 0.0);
        let count0 = m.published_count;
        // ±2% noise around the steady state: published value must not
        // chase it
        for i in 0..50 {
            let noise = if i % 2 == 0 { 1020.0 } else { 980.0 };
            m.observe(&[noise]);
        }
        assert!(m.suppressed > 20, "suppressed={}", m.suppressed);
        // few publications beyond the initial convergence
        assert!(m.published_count - count0 <= 4);
    }

    #[test]
    fn big_shift_publishes() {
        let mut m = LinkMonitor::new(1);
        for _ in 0..10 {
            m.observe(&[100.0]);
        }
        let before = m.load_estimates()[0];
        for _ in 0..10 {
            m.observe(&[10_000.0]);
        }
        assert!(m.load_estimates()[0] > before * 10.0);
    }

    #[test]
    fn decay_and_reset() {
        let mut m = LinkMonitor::new(1);
        m.observe(&[100.0]);
        m.decay(0.5);
        assert!((m.raw_estimates()[0] - 25.0).abs() < 1e-9); // 50 ewma → 25
        m.reset();
        assert_eq!(m.raw_estimates()[0], 0.0);
        assert_eq!(m.load_estimates()[0], 0.0);
    }
}
