//! Shared mid-flight chunk-reroute bookkeeping, used by both the
//! single-job [`super::replan::ReplanExecutor`] and the multi-tenant
//! [`crate::orchestrator`] executor (which previously carried a
//! duplicated copy of this logic).
//!
//! The ordering contract across a reroute (paper §IV): a pair's chunks
//! keep their original sequence numbers; a preempted path's
//! *undelivered* sequence numbers are pooled and redistributed over
//! the new paths by byte share; every path still delivers its own
//! chunks in ascending order; the receiver's [`ReassemblyTable`]
//! releases data strictly in sequence. These helpers implement exactly
//! the three steps both executors perform — preempt-and-pool, split
//! the pool, attach the re-issued parts — with float/int arithmetic in
//! the original order so both call sites stay bit-identical.

use crate::coordinator::reassembly::{ChunkArrival, ReassemblyTable};
use crate::fabric::backend::FabricBackend;
use crate::planner::{Assignment, Demand};
use crate::topology::{GpuId, Path};
use std::collections::BTreeMap;

/// Per-path chunk-sequence bookkeeping for one (src, dst) stream part.
pub(crate) struct PartState {
    /// Engine flow index carrying this part.
    pub flow: usize,
    /// Chunk sequence numbers assigned to this path (ascending).
    pub seqs: Vec<u64>,
    /// Prefix of `seqs` already pushed into the reassembly queue.
    pub delivered: usize,
}

/// One pair's staged re-issue: where its flows sit in the shared epoch
/// batch and how the pooled sequence numbers split across them.
pub(crate) struct Reissue {
    pub pair: (GpuId, GpuId),
    /// Absolute offset of the pair's first flow in the epoch batch.
    pub batch_off: usize,
    /// Pool slice sizes per re-issued flow (sums to `pool.len()`).
    pub counts: Vec<usize>,
    pub pool: Vec<u64>,
}

/// The residual routing still in flight for one set of streams:
/// undrained demand per pair, the live path/byte assignments carrying
/// it, their link loads, and — when a fault scale is supplied — the
/// pairs whose live parts cross a dead link (*forced* replan targets:
/// their drain time is infinite, so they bypass the z-hysteresis).
pub(crate) struct ResidualRouting {
    pub demands: Vec<Demand>,
    pub assignments: BTreeMap<(GpuId, GpuId), Assignment>,
    pub link_load: Vec<f64>,
    pub forced: Vec<(GpuId, GpuId)>,
}

/// Extract the [`ResidualRouting`] of `streams` from the engine's live
/// flow state. Sub-byte residues (≤ 1 byte per part / per pair) are
/// rounding dust, not demand, and are dropped. Pass `fault_scale` only
/// when some link is actually dead (scale ≤ 0); `None` skips the
/// forced-pair scan entirely. Both executors previously carried an
/// inline copy of this loop; the iteration and float-accumulation
/// order here is exactly theirs, so extraction is bit-neutral.
pub(crate) fn residual_routing(
    streams: &BTreeMap<(GpuId, GpuId), Vec<PartState>>,
    engine: &dyn FabricBackend,
    n_links: usize,
    fault_scale: Option<&[f64]>,
) -> ResidualRouting {
    let mut demands: Vec<Demand> = Vec::new();
    let mut assignments = BTreeMap::new();
    let mut link_load = vec![0.0f64; n_links];
    let mut forced: Vec<(GpuId, GpuId)> = Vec::new();
    for (&pair, parts) in streams {
        let mut pr: Vec<(Path, f64)> = Vec::new();
        let mut total = 0.0f64;
        let mut crosses_dead = false;
        for ps in parts {
            let r = engine.residual_bytes(ps.flow);
            if r > 1.0 {
                let path = engine.flow(ps.flow).path.clone();
                if let Some(scale) = fault_scale {
                    if path.hops.iter().any(|&h| scale[h] <= 0.0) {
                        crosses_dead = true;
                    }
                }
                pr.push((path, r));
                total += r;
            }
        }
        if total > 1.0 {
            demands.push(Demand::new(pair.0, pair.1, total));
            for (p, b) in &pr {
                for &h in &p.hops {
                    link_load[h] += *b;
                }
            }
            assignments.insert(pair, Assignment { parts: pr });
            if crosses_dead {
                forced.push(pair);
            }
        }
    }
    ResidualRouting { demands, assignments, link_load, forced }
}

/// Preempt a pair's live parts: release each part's *completed* chunk
/// prefix into reassembly, pool the undelivered sequence numbers, and
/// report every preempted engine flow through `on_preempt`. Returns
/// `(pooled seqs, flows preempted)`.
pub(crate) fn preempt_and_pool(
    engine: &mut dyn FabricBackend,
    reass: &mut ReassemblyTable,
    pair: (GpuId, GpuId),
    parts: &mut [PartState],
    chunk: f64,
    on_preempt: &mut dyn FnMut(usize),
) -> (Vec<u64>, usize) {
    let mut pool: Vec<u64> = Vec::new();
    let mut preempted = 0usize;
    for ps in parts.iter_mut() {
        if !engine.is_live(ps.flow) {
            continue;
        }
        let moved = engine.moved_bytes(ps.flow);
        engine.preempt(ps.flow);
        on_preempt(ps.flow);
        preempted += 1;
        let done = ((moved / chunk).floor() as usize).clamp(ps.delivered, ps.seqs.len());
        for &s in &ps.seqs[ps.delivered..done] {
            reass
                .push(pair.0, pair.1, ChunkArrival { seq: s, bytes: chunk as u64 })
                .expect("ordering invariant violated");
        }
        pool.extend_from_slice(&ps.seqs[done..]);
        ps.seqs.truncate(done);
        ps.delivered = done;
    }
    (pool, preempted)
}

/// Split `n_pool` pooled sequence numbers across re-issued flows in
/// proportion to their byte shares (round-to-nearest, clamped to the
/// remainder; the last flow absorbs any residue so the counts always
/// sum to `n_pool`).
pub(crate) fn pool_split_counts(byte_shares: &[f64], total: f64, n_pool: usize) -> Vec<usize> {
    let mut counts: Vec<usize> = Vec::with_capacity(byte_shares.len());
    let mut allotted = 0usize;
    for bytes in byte_shares {
        let want = ((bytes / total) * n_pool as f64).round() as usize;
        let n = want.min(n_pool - allotted);
        counts.push(n);
        allotted += n;
    }
    if let Some(last) = counts.last_mut() {
        *last += n_pool - allotted;
    }
    counts
}

/// Once the epoch batch has registered with the engine at base index
/// `first`, attach each staged re-issue's parts to its stream.
pub(crate) fn attach_reissues(
    streams: &mut BTreeMap<(GpuId, GpuId), Vec<PartState>>,
    first: usize,
    reissues: Vec<Reissue>,
) {
    for r in reissues {
        let parts = streams.get_mut(&r.pair).expect("pair staged");
        let mut off = 0usize;
        for (j, &n) in r.counts.iter().enumerate() {
            parts.push(PartState {
                flow: first + r.batch_off + j,
                seqs: r.pool[off..off + n].to_vec(),
                delivered: 0,
            });
            off += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_split_matches_byte_shares() {
        // 10 seqs over shares 60/30/10 of 100 → 6/3/1
        assert_eq!(pool_split_counts(&[60.0, 30.0, 10.0], 100.0, 10), vec![6, 3, 1]);
        // rounding residue lands on the last flow
        assert_eq!(pool_split_counts(&[1.0, 1.0, 1.0], 3.0, 10), vec![3, 3, 4]);
        // empty pool → all zeros
        assert_eq!(pool_split_counts(&[5.0, 5.0], 10.0, 0), vec![0, 0]);
        // a single share takes everything
        assert_eq!(pool_split_counts(&[7.0], 7.0, 4), vec![4]);
    }

    #[test]
    fn attach_appends_in_batch_order() {
        let mut streams: BTreeMap<(GpuId, GpuId), Vec<PartState>> = BTreeMap::new();
        streams.insert((0, 1), Vec::new());
        let r = Reissue {
            pair: (0, 1),
            batch_off: 2,
            counts: vec![2, 1],
            pool: vec![7, 8, 9],
        };
        attach_reissues(&mut streams, 10, vec![r]);
        let parts = &streams[&(0, 1)];
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].flow, 12);
        assert_eq!(parts[0].seqs, vec![7, 8]);
        assert_eq!(parts[1].flow, 13);
        assert_eq!(parts[1].seqs, vec![9]);
        assert!(parts.iter().all(|p| p.delivered == 0));
    }
}
