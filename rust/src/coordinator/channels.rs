//! Peer-exclusive kernel pairing (paper §IV-D).
//!
//! Each GPU launches one persistent channel (thread-block group +
//! pre-allocated P2P staging buffer) per (peer, direction); all tasks
//! toward the same peer share that channel via a task queue. Creating
//! a second channel for the same peer would duplicate the P2P buffer
//! ("significant overhead at runtime"), so the registry enforces
//! exclusivity and tracks buffer allocation as the §IV-D invariant.

use crate::topology::GpuId;
use std::collections::{BTreeMap, VecDeque};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Direction {
    Send,
    Recv,
    /// Relay traffic being forwarded through this GPU toward `peer`.
    Forward,
}

/// One communication task enqueued on a channel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChannelTask {
    pub flow_id: usize,
    pub bytes: f64,
}

/// A persistent per-(gpu, peer, direction) channel.
#[derive(Clone, Debug)]
pub struct Channel {
    pub gpu: GpuId,
    pub peer: GpuId,
    pub dir: Direction,
    pub buf_bytes: f64,
    pub queue: VecDeque<ChannelTask>,
    /// Total tasks ever enqueued (for stats/asserts).
    pub enqueued: u64,
}

/// Registry enforcing peer-exclusive pairing.
#[derive(Debug, Default)]
pub struct ChannelRegistry {
    channels: BTreeMap<(GpuId, GpuId, Direction), Channel>,
    pub buf_per_channel: f64,
}

impl ChannelRegistry {
    pub fn new(buf_per_channel: f64) -> Self {
        ChannelRegistry { channels: BTreeMap::new(), buf_per_channel }
    }

    /// Get-or-create the unique channel for (gpu, peer, dir). A second
    /// request returns the SAME channel — no extra buffer allocation.
    pub fn channel(&mut self, gpu: GpuId, peer: GpuId, dir: Direction) -> &mut Channel {
        assert_ne!(gpu, peer, "self-channel");
        let buf = self.buf_per_channel;
        self.channels.entry((gpu, peer, dir)).or_insert_with(|| Channel {
            gpu,
            peer,
            dir,
            buf_bytes: buf,
            queue: VecDeque::new(),
            enqueued: 0,
        })
    }

    pub fn enqueue(&mut self, gpu: GpuId, peer: GpuId, dir: Direction, task: ChannelTask) {
        let ch = self.channel(gpu, peer, dir);
        ch.queue.push_back(task);
        ch.enqueued += 1;
    }

    /// Pop the next task on a channel (the dataplane drains in FIFO
    /// order — ordering semantics feed the reassembly layer).
    pub fn pop(&mut self, gpu: GpuId, peer: GpuId, dir: Direction) -> Option<ChannelTask> {
        self.channels.get_mut(&(gpu, peer, dir)).and_then(|c| c.queue.pop_front())
    }

    pub fn channel_count(&self) -> usize {
        self.channels.len()
    }

    /// Total staging memory allocated across all channels — the
    /// quantity §IV-D's design keeps minimal.
    pub fn total_buffer_bytes(&self) -> f64 {
        self.channels.len() as f64 * self.buf_per_channel
    }

    pub fn pending_tasks(&self) -> usize {
        self.channels.values().map(|c| c.queue.len()).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Channel> {
        self.channels.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_is_exclusive_per_peer() {
        let mut r = ChannelRegistry::new(10e6);
        r.enqueue(0, 1, Direction::Send, ChannelTask { flow_id: 1, bytes: 100.0 });
        r.enqueue(0, 1, Direction::Send, ChannelTask { flow_id: 2, bytes: 200.0 });
        // two tasks, ONE channel, ONE buffer
        assert_eq!(r.channel_count(), 1);
        assert_eq!(r.total_buffer_bytes(), 10e6);
        assert_eq!(r.pending_tasks(), 2);
    }

    #[test]
    fn directions_are_separate_channels() {
        let mut r = ChannelRegistry::new(10e6);
        r.channel(0, 1, Direction::Send);
        r.channel(0, 1, Direction::Recv);
        r.channel(0, 1, Direction::Forward);
        assert_eq!(r.channel_count(), 3);
    }

    #[test]
    fn fifo_draining() {
        let mut r = ChannelRegistry::new(1.0);
        for i in 0..5 {
            r.enqueue(2, 3, Direction::Send, ChannelTask { flow_id: i, bytes: 1.0 });
        }
        for i in 0..5 {
            assert_eq!(r.pop(2, 3, Direction::Send).unwrap().flow_id, i);
        }
        assert!(r.pop(2, 3, Direction::Send).is_none());
    }

    #[test]
    #[should_panic(expected = "self-channel")]
    fn rejects_self_channel() {
        let mut r = ChannelRegistry::new(1.0);
        r.channel(1, 1, Direction::Send);
    }

    #[test]
    fn buffer_accounting_scales_with_distinct_peers_only() {
        let mut r = ChannelRegistry::new(5.0);
        for peer in 1..4 {
            for _ in 0..10 {
                r.enqueue(0, peer, Direction::Send, ChannelTask { flow_id: 0, bytes: 1.0 });
            }
        }
        assert_eq!(r.total_buffer_bytes(), 15.0); // 3 peers × 5
    }
}
