//! Per-destination reassembly queues (paper §I / §IV: "per-destination
//! reassembly queues to maintain ordering semantics").
//!
//! When NIMBLE splits one logical message across multiple paths, the
//! chunks can land out of order at the receiver. Each (src → dst)
//! stream owns a reassembly queue that buffers out-of-order arrivals
//! and releases data strictly in sequence, so the application sees
//! exactly the ordering a single-path transfer would deliver.

use std::collections::BTreeMap;

/// Sequenced chunk arrival for one stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChunkArrival {
    pub seq: u64,
    pub bytes: u64,
}

/// In-order release buffer for a single (src, dst) stream.
#[derive(Debug, Default)]
pub struct ReassemblyQueue {
    next: u64,
    pending: BTreeMap<u64, u64>, // seq → bytes
    delivered_bytes: u64,
    /// Peak number of buffered out-of-order chunks (memory watermark).
    pub peak_pending: usize,
}

impl ReassemblyQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accept a chunk; returns every chunk that becomes deliverable,
    /// in order. Duplicate/stale seqs are rejected.
    pub fn push(&mut self, chunk: ChunkArrival) -> Result<Vec<ChunkArrival>, String> {
        if chunk.seq < self.next || self.pending.contains_key(&chunk.seq) {
            return Err(format!("duplicate or stale chunk seq {}", chunk.seq));
        }
        self.pending.insert(chunk.seq, chunk.bytes);
        let mut out = Vec::new();
        while let Some(bytes) = self.pending.remove(&self.next) {
            out.push(ChunkArrival { seq: self.next, bytes });
            self.delivered_bytes += bytes;
            self.next += 1;
        }
        // watermark counts chunks actually stuck waiting (after drain)
        self.peak_pending = self.peak_pending.max(self.pending.len());
        Ok(out)
    }

    pub fn delivered_bytes(&self) -> u64 {
        self.delivered_bytes
    }

    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    pub fn is_drained(&self) -> bool {
        self.pending.is_empty()
    }
}

/// All streams terminating at one destination GPU.
#[derive(Debug, Default)]
pub struct ReassemblyTable {
    streams: BTreeMap<(usize, usize), ReassemblyQueue>, // (src, dst)
}

impl ReassemblyTable {
    pub fn push(
        &mut self,
        src: usize,
        dst: usize,
        chunk: ChunkArrival,
    ) -> Result<Vec<ChunkArrival>, String> {
        self.streams.entry((src, dst)).or_default().push(chunk)
    }

    pub fn stream(&self, src: usize, dst: usize) -> Option<&ReassemblyQueue> {
        self.streams.get(&(src, dst))
    }

    pub fn all_drained(&self) -> bool {
        self.streams.values().all(|q| q.is_drained())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check_seeded, Gen};
    use crate::util::rng::Rng;

    fn arrivals(order: &[u64]) -> Vec<ChunkArrival> {
        order.iter().map(|&seq| ChunkArrival { seq, bytes: 10 + seq }).collect()
    }

    #[test]
    fn in_order_stream_passes_through() {
        let mut q = ReassemblyQueue::new();
        for c in arrivals(&[0, 1, 2]) {
            let out = q.push(c).unwrap();
            assert_eq!(out, vec![c]);
        }
        assert_eq!(q.peak_pending, 0, "in-order stream never buffers");
    }

    #[test]
    fn out_of_order_is_buffered_then_released() {
        let mut q = ReassemblyQueue::new();
        assert!(q.push(ChunkArrival { seq: 2, bytes: 1 }).unwrap().is_empty());
        assert!(q.push(ChunkArrival { seq: 1, bytes: 1 }).unwrap().is_empty());
        let out = q.push(ChunkArrival { seq: 0, bytes: 1 }).unwrap();
        assert_eq!(out.iter().map(|c| c.seq).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(q.is_drained());
        assert_eq!(q.peak_pending, 2);
    }

    #[test]
    fn duplicates_rejected() {
        let mut q = ReassemblyQueue::new();
        q.push(ChunkArrival { seq: 0, bytes: 1 }).unwrap();
        assert!(q.push(ChunkArrival { seq: 0, bytes: 1 }).is_err()); // stale
        q.push(ChunkArrival { seq: 2, bytes: 1 }).unwrap();
        assert!(q.push(ChunkArrival { seq: 2, bytes: 1 }).is_err()); // dup pending
    }

    /// Property: for ANY arrival permutation, delivery is exactly
    /// 0..n in order with all bytes accounted.
    #[test]
    fn any_permutation_delivers_in_order() {
        check_seeded(0xA55E, 200, |g: &mut Gen| {
            let n = g.usize(1, 64) as u64;
            let mut order: Vec<u64> = (0..n).collect();
            let mut rng = Rng::new(g.u64(0, u64::MAX));
            rng.shuffle(&mut order);
            let mut q = ReassemblyQueue::new();
            let mut delivered = Vec::new();
            for c in arrivals(&order) {
                delivered.extend(q.push(c)?);
            }
            crate::prop_assert!(q.is_drained(), "queue not drained");
            let seqs: Vec<u64> = delivered.iter().map(|c| c.seq).collect();
            crate::prop_assert!(
                seqs == (0..n).collect::<Vec<_>>(),
                "out of order: {seqs:?}"
            );
            let total: u64 = delivered.iter().map(|c| c.bytes).sum();
            let expect: u64 = (0..n).map(|s| 10 + s).sum();
            crate::prop_assert!(total == expect, "bytes lost");
            Ok(())
        });
    }

    #[test]
    fn table_separates_streams() {
        let mut t = ReassemblyTable::default();
        t.push(0, 4, ChunkArrival { seq: 1, bytes: 5 }).unwrap();
        t.push(1, 4, ChunkArrival { seq: 0, bytes: 7 }).unwrap();
        assert!(!t.all_drained()); // (0,4) still waiting for seq 0
        let out = t.push(0, 4, ChunkArrival { seq: 0, bytes: 5 }).unwrap();
        assert_eq!(out.len(), 2);
        assert!(t.all_drained());
        assert_eq!(t.stream(1, 4).unwrap().delivered_bytes(), 7);
    }
}
