//! The NIMBLE coordinator (paper §IV): ties the monitoring module,
//! the orchestration engine (planner) and the dataplane bookkeeping
//! (channels + reassembly) together behind the [`Router`] interface
//! used by every experiment, plus two execution-time feedback loops:
//!
//! * [`Orchestrator`] — round-granular adaptation: each round is
//!   planned warm-started from the previous round's link monitor;
//! * [`replan::ReplanExecutor`] — *mid-flight* adaptation: within a
//!   round, the monitor → [`crate::planner::Planner::replan`] →
//!   preempt/reroute loop runs at a configurable cadence (the paper's
//!   execution-time planning claim, closed end to end).

pub mod channels;
pub mod monitor;
pub mod reassembly;
pub mod replan;
pub(crate) mod reroute;

pub use replan::{ReplanExecutor, ReplanRun};

use crate::baselines::Router;
use crate::fabric::fluid::{Flow, FluidSim, SimResult};
use crate::fabric::{FabricParams, XferMode};
use crate::metrics::CommReport;
use crate::planner::{Demand, Plan, Planner, PlannerCfg};
use crate::topology::{Path, Topology};
use channels::{ChannelRegistry, ChannelTask, Direction};
use reassembly::{ChunkArrival, ReassemblyTable};

/// NIMBLE as a [`Router`]: every round runs Algorithm 1 over the
/// demand set (optionally warm-started from the link monitor).
pub struct NimbleRouter {
    pub cfg: PlannerCfg,
    pub monitor: monitor::LinkMonitor,
    /// Warm-start planning from monitor estimates.
    pub adaptive: bool,
    /// Last plan (inspectable by tests/experiments).
    pub last_plan: Option<Plan>,
}

impl NimbleRouter {
    pub fn new(topo: &Topology, cfg: PlannerCfg) -> Self {
        NimbleRouter {
            cfg,
            monitor: monitor::LinkMonitor::new(topo.links.len()),
            adaptive: false,
            last_plan: None,
        }
    }

    pub fn default_for(topo: &Topology) -> Self {
        Self::new(topo, PlannerCfg::default())
    }

    pub fn adaptive_for(topo: &Topology) -> Self {
        let mut r = Self::new(topo, PlannerCfg::default());
        r.adaptive = true;
        r
    }

    /// Produce the routing plan for a demand set.
    pub fn plan(&mut self, topo: &Topology, demands: &[Demand]) -> Plan {
        let mut planner = Planner::new(topo, self.cfg.clone());
        let plan = if self.adaptive {
            planner.plan_with_initial(demands, Some(self.monitor.load_estimates()))
        } else {
            planner.plan(demands)
        };
        self.last_plan = Some(plan.clone());
        plan
    }
}

impl Router for NimbleRouter {
    fn name(&self) -> &'static str {
        "nimble"
    }

    fn mode(&self) -> XferMode {
        XferMode::Kernel
    }

    fn route(&mut self, topo: &Topology, demands: &[Demand]) -> Vec<(Path, f64)> {
        let plan = self.plan(topo, demands);
        plan.assignments
            .values()
            .flat_map(|a| a.parts.iter().cloned())
            .collect()
    }
}

/// One executed round: timing + the dataplane bookkeeping results.
pub struct RoundOutcome {
    pub report: CommReport,
    pub sim: SimResult,
    /// Staging memory the channel registry allocated this round.
    pub channel_buffer_bytes: f64,
    /// Peak out-of-order chunks buffered in any reassembly queue.
    pub peak_reassembly: usize,
}

/// Adaptive multi-round orchestrator: plan → execute → observe →
/// re-plan, with full channel/reassembly bookkeeping each round.
pub struct Orchestrator<'a> {
    pub topo: &'a Topology,
    pub params: FabricParams,
    pub router: NimbleRouter,
    pub channels: ChannelRegistry,
}

impl<'a> Orchestrator<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams) -> Self {
        let buf = params.p2p_buf_bytes;
        Orchestrator {
            topo,
            params,
            router: NimbleRouter::adaptive_for(topo),
            channels: ChannelRegistry::new(buf),
        }
    }

    /// Execute one round of demands under the current plan, running
    /// the full dataplane bookkeeping: channel task queues
    /// (peer-exclusive pairing) and per-destination reassembly
    /// (ordering). Panics if the ordering invariant is violated.
    pub fn run_round(&mut self, demands: &[Demand]) -> RoundOutcome {
        let plan = self.router.plan(self.topo, demands);
        let chunk = self.params.chunk_bytes;

        // dataplane bookkeeping + flow construction
        let mut flows: Vec<Flow> = Vec::new();
        let mut reass = ReassemblyTable::default();
        let mut flow_id = 0usize;
        for (&(s, d), a) in &plan.assignments {
            // one send channel per destination peer; relays get forward
            // channels — exercising §IV-D exclusivity
            for (path, bytes) in &a.parts {
                // first GPU the stream lands on: switch vertices on
                // tiered fabrics are not channel peers (no SM there)
                let first_peer = path
                    .hops
                    .iter()
                    .map(|&h| self.topo.link(h).dst)
                    .find(|&v| !self.topo.is_switch(v))
                    .unwrap_or(path.dst);
                self.channels.enqueue(
                    s,
                    first_peer,
                    Direction::Send,
                    ChannelTask { flow_id, bytes: *bytes },
                );
                for relay in path.relays(self.topo) {
                    self.channels.enqueue(
                        relay,
                        d,
                        Direction::Forward,
                        ChannelTask { flow_id, bytes: *bytes },
                    );
                }
                self.channels.enqueue(
                    d,
                    s,
                    Direction::Recv,
                    ChannelTask { flow_id, bytes: *bytes },
                );
                flows.push(Flow::new(path.clone(), *bytes));
                flow_id += 1;
            }
            // reassembly: chunks are numbered per stream across all of
            // its paths; paths deliver their own chunks in order but
            // interleave with each other (modelled round-robin, the
            // worst pattern for contiguity).
            let seqs_per_part: Vec<u64> =
                a.parts.iter().map(|(_, b)| (b / chunk).ceil().max(1.0) as u64).collect();
            let mut cursors: Vec<u64> = Vec::new();
            let mut base = 0u64;
            for &n in &seqs_per_part {
                cursors.push(base);
                base += n;
            }
            let ends: Vec<u64> = cursors
                .iter()
                .zip(&seqs_per_part)
                .map(|(&c, &n)| c + n)
                .collect();
            let mut live = true;
            while live {
                live = false;
                for (ci, cur) in cursors.iter_mut().enumerate() {
                    if *cur < ends[ci] {
                        reass
                            .push(s, d, ChunkArrival { seq: *cur, bytes: chunk as u64 })
                            .expect("ordering invariant violated");
                        *cur += 1;
                        live = true;
                    }
                }
            }
            assert!(
                reass.stream(s, d).map(|q| q.is_drained()).unwrap_or(true),
                "stream ({s},{d}) not fully reassembled"
            );
        }

        let sim = FluidSim::new(self.topo, self.params.clone()).run(&flows);
        self.router.monitor.observe(&sim.link_bytes);
        let payload: f64 = demands.iter().map(|d| d.bytes).sum();
        let report = CommReport::from_sim("nimble", self.topo, &sim, payload);
        let peak_reassembly = plan
            .assignments
            .keys()
            .filter_map(|&(s, d)| reass.stream(s, d).map(|q| q.peak_pending))
            .max()
            .unwrap_or(0);
        RoundOutcome {
            report,
            sim,
            channel_buffer_bytes: self.channels.total_buffer_bytes(),
            peak_reassembly,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn router_flows_cover_demands() {
        let t = Topology::paper();
        let mut r = NimbleRouter::default_for(&t);
        let demands =
            vec![Demand::new(0, 1, 128.0 * MB), Demand::new(2, 7, 64.0 * MB)];
        let flows = r.route(&t, &demands);
        let total: f64 = flows.iter().map(|(_, b)| b).sum();
        assert!((total - 192.0 * MB).abs() < 1.0);
        r.last_plan.unwrap().validate(&t, &demands).unwrap();
    }

    #[test]
    fn orchestrator_round_runs_clean() {
        let t = Topology::paper();
        let mut o = Orchestrator::new(&t, FabricParams::default());
        // one large pair: the planner splits it across 3 paths, so the
        // receiver must reassemble interleaved chunk streams
        let demands = vec![Demand::new(0, 1, 512.0 * MB), Demand::new(2, 3, 64.0 * MB)];
        let out = o.run_round(&demands);
        assert!(out.report.makespan_s > 0.0);
        assert!(out.channel_buffer_bytes > 0.0);
        // multipath was active: some stream buffered out-of-order chunks
        assert!(out.peak_reassembly >= 1);
    }

    #[test]
    fn channel_buffers_do_not_grow_across_rounds() {
        let t = Topology::paper();
        let mut o = Orchestrator::new(&t, FabricParams::default());
        let demands: Vec<Demand> = (0..3).map(|s| Demand::new(s, 3, 32.0 * MB)).collect();
        let b1 = o.run_round(&demands).channel_buffer_bytes;
        let b2 = o.run_round(&demands).channel_buffer_bytes;
        let b3 = o.run_round(&demands).channel_buffer_bytes;
        // §IV-D: same peers ⇒ same channels ⇒ no new staging buffers
        assert_eq!(b1, b2);
        assert_eq!(b2, b3);
    }

    #[test]
    fn adaptive_router_reacts_to_background_load() {
        let t = Topology::paper();
        let mut r = NimbleRouter::adaptive_for(&t);
        // poison the monitor: pretend the direct (0,1) NVLink is slammed
        let direct = t.nvlink(0, 1).unwrap();
        let mut bg = vec![0.0; t.links.len()];
        bg[direct] = 4e9; // 4 GB observed
        for _ in 0..8 {
            r.monitor.observe(&bg);
        }
        let demands = vec![Demand::new(0, 1, 128.0 * MB)];
        let flows = r.route(&t, &demands);
        // the plan must shift most bytes OFF the direct link
        let direct_bytes: f64 = flows
            .iter()
            .filter(|(p, _)| p.hops == vec![direct])
            .map(|(_, b)| b)
            .sum();
        let total: f64 = flows.iter().map(|(_, b)| b).sum();
        assert!(
            direct_bytes / total < 0.34,
            "adaptive plan kept {:.0}% on the congested link",
            100.0 * direct_bytes / total
        );
    }
}
