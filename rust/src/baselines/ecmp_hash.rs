//! ECMP hash-striping baseline: the conventional datacenter answer to
//! multi-path fabrics (§II, §V). Each inter-node stream is striped in
//! **equal** shares across every NIC rail — capacity- and load-blind —
//! and on tiered fabrics each stripe's core path is chosen by a flow
//! hash over the spine group, exactly how switch-resident ECMP picks
//! among equal-cost uplinks.
//!
//! The two failure modes the planner exploits:
//! * equal splitting ignores *skew* — a hot destination's rails carry
//!   the same share as idle ones, so the hot rail's drain time sets
//!   the collective's makespan;
//! * hash spine selection ignores *collisions* — two heavy stripes
//!   hashing onto the same spine halve each other while a sibling
//!   spine idles (the classic ECMP elephant-flow problem).
//!
//! Fully deterministic for a fixed `seed`: spine choice is a pure
//! function of `(seed, src, dst, rail)` with no per-run state.

use super::Router;
use crate::fabric::XferMode;
use crate::planner::Demand;
use crate::topology::path::candidates;
use crate::topology::{Path, PathKind, Topology};
use crate::util::rng::mix64;

pub struct EcmpHash {
    /// Hash seed (switch ECMP function randomization). Same seed ⇒
    /// byte-identical routing.
    pub seed: u64,
}

impl EcmpHash {
    pub fn new() -> Self {
        EcmpHash { seed: 0 }
    }

    pub fn with_seed(seed: u64) -> Self {
        EcmpHash { seed }
    }

    /// The spine index a stripe of (s, d) on `rail` hashes to.
    fn spine_for(&self, topo: &Topology, s: usize, d: usize, rail: usize) -> usize {
        let tier = topo.tier.as_ref().expect("spine_for on tiered fabric");
        let key = self
            .seed
            .wrapping_mul(0x2545_F491_4F6C_DD1D)
            .wrapping_add((s as u64) << 40)
            .wrapping_add((d as u64) << 16)
            .wrapping_add(rail as u64);
        (mix64(key) % tier.spines_per_rail as u64) as usize
    }

    /// One stripe per rail, each 1/R of the bytes (ECMP's equal-share
    /// invariant), using the tier-walk candidates for the concrete hops.
    fn stripes(&self, topo: &Topology, s: usize, d: usize, bytes: f64) -> Vec<(Path, f64)> {
        if topo.same_node(s, d) {
            return vec![(candidates(topo, s, d, false).remove(0), bytes)];
        }
        let cands = candidates(topo, s, d, true);
        let rails = topo.nics_per_node;
        let share = bytes / rails as f64;
        let mut out = Vec::with_capacity(rails);
        for rail in 0..rails {
            let want = |k: &PathKind| match *k {
                PathKind::InterRail { rail: r } | PathKind::InterLeaf { rail: r } => r == rail,
                PathKind::InterSpine { rail: r, spine } => {
                    r == rail && spine == self.spine_for(topo, s, d, rail)
                }
                _ => false,
            };
            let p = cands
                .iter()
                .find(|p| want(&p.kind))
                .expect("per-rail candidate exists")
                .clone();
            out.push((p, share));
        }
        out
    }
}

impl Default for EcmpHash {
    fn default() -> Self {
        Self::new()
    }
}

impl Router for EcmpHash {
    fn name(&self) -> &'static str {
        "ecmp"
    }

    fn mode(&self) -> XferMode {
        XferMode::Kernel
    }

    fn route(&mut self, topo: &Topology, demands: &[Demand]) -> Vec<(Path, f64)> {
        let mut out = Vec::new();
        for d in demands {
            if d.bytes > 0.0 {
                out.extend(self.stripes(topo, d.src, d.dst, d.bytes));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::LinkKind;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn equal_share_across_all_rails_flat() {
        let t = Topology::paper();
        let mut e = EcmpHash::new();
        let flows = e.route(&t, &[Demand::new(1, 6, 8.0 * MB)]);
        assert_eq!(flows.len(), t.nics_per_node);
        let mut rails_seen = Vec::new();
        for (p, b) in &flows {
            assert!((b - 2.0 * MB).abs() < 1e-6);
            match p.kind {
                PathKind::InterRail { rail } => rails_seen.push(rail),
                k => panic!("unexpected kind {k:?}"),
            }
        }
        rails_seen.sort_unstable();
        assert_eq!(rails_seen, vec![0, 1, 2, 3]);
    }

    #[test]
    fn intra_node_is_direct() {
        let t = Topology::paper();
        let mut e = EcmpHash::new();
        let flows = e.route(&t, &[Demand::new(0, 3, MB)]);
        assert_eq!(flows.len(), 1);
        assert_eq!(flows[0].0.kind, PathKind::IntraDirect);
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let t = Topology::fat_tree(8, 2.0);
        let demands: Vec<Demand> = (0..8)
            .flat_map(|s| (32..40).map(move |d| Demand::new(s, d, 4.0 * MB)))
            .collect();
        let a = EcmpHash::with_seed(7).route(&t, &demands);
        let b = EcmpHash::with_seed(7).route(&t, &demands);
        assert_eq!(a.len(), b.len());
        for ((pa, ba), (pb, bb)) in a.iter().zip(&b) {
            assert_eq!(format!("{:?}", pa), format!("{:?}", pb));
            assert_eq!(ba, bb);
        }
        // and a different seed must actually move some spine choice
        let c = EcmpHash::with_seed(8).route(&t, &demands);
        assert!(
            a.iter().zip(&c).any(|((pa, _), (pc, _))| pa.kind != pc.kind),
            "seed change did not alter any spine pick"
        );
    }

    #[test]
    fn tiered_stripes_cover_every_rail_one_spine_each() {
        let t = Topology::fat_tree(8, 2.0);
        let mut e = EcmpHash::new();
        // cross-pod pair (pod_size = 4 nodes ⇒ GPU 33 is in pod 1)
        let flows = e.route(&t, &[Demand::new(1, 33, 8.0 * MB)]);
        assert_eq!(flows.len(), t.nics_per_node);
        for (p, _) in &flows {
            assert!(matches!(p.kind, PathKind::InterSpine { .. }), "{:?}", p.kind);
            // each stripe crosses the core exactly once
            let core_hops = p
                .hops
                .iter()
                .filter(|&&h| {
                    matches!(
                        t.link(h).kind,
                        LinkKind::SpineUp { .. } | LinkKind::SpineDown { .. }
                    )
                })
                .count();
            assert_eq!(core_hops, 2);
        }
    }
}
