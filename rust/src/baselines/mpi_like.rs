//! OpenMPI + UCX CUDA-aware baseline (§V: OpenMPI v5.0.7, UCX 1.18).
//!
//! Models the transport-level behaviours the paper contrasts with:
//! * **copy-engine dataplane** — transfers are driven by GPU DMA
//!   engines rather than kernels, so small-message setup is cheaper
//!   (the paper: "such copy-engine–based paths can more easily
//!   saturate fabrics at small message sizes"); NIMBLE/NCCL win back
//!   at scale.
//! * **static multi-rail striping** — UCX stripes large (rendezvous)
//!   messages across up to `max_rails` HCAs (UCX default 2),
//!   round-robin from the source rail, with no awareness of live load.
//! * **no GPU forwarding** — a rail whose NIC pair is mismatched with
//!   the endpoints crosses the switch tier (cross-rail penalty)
//!   instead of relaying through a peer GPU.

use super::Router;
use crate::fabric::fluid::Flow;
use crate::fabric::XferMode;
use crate::planner::Demand;
use crate::topology::path::candidates;
use crate::topology::{Path, PathKind, Topology};

pub struct MpiLike {
    /// Rendezvous threshold: messages larger than this are striped.
    pub rndv_bytes: f64,
    /// Max rails used per message (UCX `max_rndv_rails` default: 2).
    pub max_rails: usize,
    /// Rate derating for a stripe whose HCA is not the GPU's affine
    /// NIC: GPUDirect through a non-local PCIe switch / host bridge
    /// runs far below line rate. This is why static striping does not
    /// simply equal NIMBLE's GPU-forwarded rail matching (§IV-B).
    pub non_affine_factor: f64,
}

impl MpiLike {
    pub fn new() -> Self {
        MpiLike { rndv_bytes: 512.0 * 1024.0, max_rails: 2, non_affine_factor: 0.55 }
    }

    /// Rail path from src NIC `sr` to dst NIC `dr`, matched or crossed.
    fn nic_pair_path(topo: &Topology, s: usize, d: usize, sr: usize, dr: usize) -> Path {
        if sr == dr {
            // rail-matched NIC pair... but endpoints may still need the
            // staging hop; UCX DMA reads/writes GPU memory via PCIe
            // from any local HCA, modelled as the plain rail edge when
            // endpoints sit on the rail, else the cross edge is closer
            // to reality only for mismatched NICs. For matched NICs we
            // use the rail edge regardless of endpoint locality: the
            // DMA engine covers the intra-node leg without consuming
            // NVLink.
            let na = topo.node_of(s);
            let nb = topo.node_of(d);
            let rail_link = topo.rail(na, nb, sr).unwrap();
            Path { src: s, dst: d, kind: PathKind::InterRail { rail: sr }, hops: vec![rail_link] }
        } else {
            let na = topo.node_of(s);
            let nb = topo.node_of(d);
            let link = topo.cross_rail(na, nb, sr, dr).unwrap();
            Path {
                src: s,
                dst: d,
                kind: PathKind::InterCross { src_rail: sr, dst_rail: dr },
                hops: vec![link],
            }
        }
    }
}

impl Default for MpiLike {
    fn default() -> Self {
        Self::new()
    }
}

impl Router for MpiLike {
    fn name(&self) -> &'static str {
        "mpi-ucx"
    }

    fn mode(&self) -> XferMode {
        XferMode::CopyEngine
    }

    fn route(&mut self, topo: &Topology, demands: &[Demand]) -> Vec<(Path, f64)> {
        self.route_flows(topo, demands)
            .into_iter()
            .map(|f| (f.path, f.bytes))
            .collect()
    }

    fn route_flows(&mut self, topo: &Topology, demands: &[Demand]) -> Vec<Flow> {
        let mut out = Vec::new();
        for dm in demands.iter().filter(|d| d.bytes > 0.0) {
            let (s, d) = (dm.src, dm.dst);
            if topo.same_node(s, d) {
                out.push(
                    Flow::new(candidates(topo, s, d, false).remove(0), dm.bytes)
                        .with_mode(XferMode::CopyEngine),
                );
                continue;
            }
            let src_rail = topo.home_rail(s);
            let dst_rail = topo.home_rail(d);
            if dm.bytes <= self.rndv_bytes {
                // eager path: single (source) HCA
                out.push(
                    Flow::new(Self::nic_pair_path(topo, s, d, src_rail, dst_rail), dm.bytes)
                        .with_mode(XferMode::CopyEngine),
                );
            } else {
                // striped rendezvous: rails src_rail, src_rail+1, ...;
                // stripes on non-affine HCAs run derated (PCIe bridge)
                let rails = self.max_rails.min(topo.nics_per_node).max(1);
                let per = dm.bytes / rails as f64;
                for k in 0..rails {
                    let sr = (src_rail + k) % topo.nics_per_node;
                    let dr = (dst_rail + k) % topo.nics_per_node;
                    let affine = sr == src_rail && dr == dst_rail;
                    let factor = if affine { 1.0 } else { self.non_affine_factor };
                    out.push(
                        Flow::new(Self::nic_pair_path(topo, s, d, sr, dr), per)
                            .with_mode(XferMode::CopyEngine)
                            .with_rate_factor(factor),
                    );
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn small_message_single_rail() {
        let t = Topology::paper();
        let mut e = MpiLike::new();
        let flows = e.route(&t, &[Demand::new(0, 4, 0.25 * MB)]);
        assert_eq!(flows.len(), 1);
        assert_eq!(flows[0].0.kind, PathKind::InterRail { rail: 0 });
    }

    #[test]
    fn large_message_striped_across_two_rails() {
        let t = Topology::paper();
        let mut e = MpiLike::new();
        let flows = e.route(&t, &[Demand::new(0, 4, 64.0 * MB)]);
        assert_eq!(flows.len(), 2);
        let total: f64 = flows.iter().map(|(_, b)| b).sum();
        assert!((total - 64.0 * MB).abs() < 1.0);
        // stripes land on rails 0 and 1
        assert_eq!(flows[0].0.kind, PathKind::InterRail { rail: 0 });
        assert_eq!(flows[1].0.kind, PathKind::InterRail { rail: 1 });
    }

    #[test]
    fn mismatched_endpoints_cross_rails() {
        let t = Topology::paper();
        let mut e = MpiLike::new();
        // gpu0 (rail 0) → gpu5 (rail 1): eager path crosses 0→1
        let flows = e.route(&t, &[Demand::new(0, 5, 0.25 * MB)]);
        assert!(matches!(
            flows[0].0.kind,
            PathKind::InterCross { src_rail: 0, dst_rail: 1 }
        ));
    }

    #[test]
    fn copy_engine_mode() {
        assert_eq!(MpiLike::new().mode(), XferMode::CopyEngine);
    }

    #[test]
    fn intra_node_direct() {
        let t = Topology::paper();
        let mut e = MpiLike::new();
        let flows = e.route(&t, &[Demand::new(0, 2, 64.0 * MB)]);
        assert_eq!(flows.len(), 1);
        assert_eq!(flows[0].0.kind, PathKind::IntraDirect);
    }
}
