//! Routing engines: NIMBLE plus the baselines the paper evaluates
//! against (§V). Every engine maps a demand set to concrete
//! (path, bytes) flows; the fluid fabric simulator then produces
//! timing, so engines differ *only* in routing policy and transfer
//! mode — exactly the paper's experimental control.

pub mod ecmp_hash;
pub mod mpi_like;
pub mod nccl_like;
pub mod single_path;

use crate::fabric::fluid::{Flow, FluidSim};
use crate::fabric::{FabricParams, XferMode};
use crate::metrics::CommReport;
use crate::planner::Demand;
use crate::topology::{Path, Topology};

/// A routing engine: turns demands into per-path flow assignments.
pub trait Router {
    fn name(&self) -> &'static str;
    /// Transfer mode its dataplane uses.
    fn mode(&self) -> XferMode;
    /// Route the demand set. Returns the flows to launch (all at t=0).
    fn route(&mut self, topo: &Topology, demands: &[Demand]) -> Vec<(Path, f64)>;

    /// Route to concrete fluid-sim flows. Default: wrap `route` with
    /// the engine's transfer mode. Engines with per-flow derating
    /// (e.g. non-affine HCA stripes) override this.
    fn route_flows(&mut self, topo: &Topology, demands: &[Demand]) -> Vec<Flow> {
        let mode = self.mode();
        self.route(topo, demands)
            .into_iter()
            .filter(|(_, b)| *b > 0.0)
            .map(|(p, b)| Flow::new(p, b).with_mode(mode))
            .collect()
    }
}

/// Route + simulate one communication round; the common harness every
/// experiment uses.
pub fn run_round(
    topo: &Topology,
    params: &FabricParams,
    router: &mut dyn Router,
    demands: &[Demand],
) -> CommReport {
    let flows = router.route_flows(topo, demands);
    let sim = FluidSim::new(topo, params.clone()).run(&flows);
    let payload: f64 = demands.iter().map(|d| d.bytes).sum();
    CommReport::from_sim(router.name(), topo, &sim, payload)
}

pub use ecmp_hash::EcmpHash;
pub use mpi_like::MpiLike;
pub use nccl_like::NcclLike;
pub use single_path::SinglePath;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NimbleRouter;

    const MB: f64 = 1024.0 * 1024.0;

    /// Abstract-claim check: under balanced traffic NIMBLE matches the
    /// baseline (it must not be *worse* beyond a small tolerance).
    #[test]
    fn balanced_traffic_parity() {
        let t = Topology::paper();
        let params = FabricParams::default();
        let mut demands = Vec::new();
        for s in 0..8 {
            for d in 0..8 {
                if s != d {
                    demands.push(Demand::new(s, d, 8.0 * MB));
                }
            }
        }
        let mut nccl = NcclLike::new();
        let mut nimble = NimbleRouter::default_for(&t);
        let r_nccl = run_round(&t, &params, &mut nccl, &demands);
        let r_nim = run_round(&t, &params, &mut nimble, &demands);
        let ratio = r_nccl.makespan_s / r_nim.makespan_s;
        assert!(
            ratio > 0.95,
            "NIMBLE regressed on balanced traffic: {:.3}x vs NCCL",
            ratio
        );
    }

    /// Headline claim direction: under heavy skew NIMBLE beats NCCL by
    /// a large factor (Fig 7 reaches 5.2×; exact values in the bench).
    #[test]
    fn skewed_traffic_nimble_wins_big() {
        let t = Topology::paper();
        let params = FabricParams::default();
        // every rank sends 90% of 128 MB to GPU 4
        let demands = crate::workloads::skew::hotspot_alltoallv(&t, 128.0 * MB, 0.9, 4);
        let mut nccl = NcclLike::new();
        let mut nimble = NimbleRouter::default_for(&t);
        let r_nccl = run_round(&t, &params, &mut nccl, &demands);
        let r_nim = run_round(&t, &params, &mut nimble, &demands);
        let speedup = r_nccl.makespan_s / r_nim.makespan_s;
        assert!(speedup > 2.0, "expected big win under skew, got {speedup:.2}x");
    }
}
