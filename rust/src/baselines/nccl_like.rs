//! NCCL-style baseline (§II-B, §V): static fastest-path routing fixed
//! at init time, kernel-driven dataplane, PXN rail discipline.
//!
//! * Intra-node p2p: always the direct NVLink edge.
//! * Inter-node p2p: PXN — the message moves over NVLink to the local
//!   GPU sitting on the *destination's* rail, then crosses that single
//!   rail NIC (rail-matched, avoids switch tiers; NCCL ≥2.12).
//!
//! The failure mode the paper exploits: under a destination hotspot,
//! every source on a node picks the *same* rail (the hot GPU's), so
//! one NIC saturates while three idle.

use super::Router;
use crate::fabric::XferMode;
use crate::planner::Demand;
use crate::topology::path::candidates;
use crate::topology::{Path, PathKind, Topology};

pub struct NcclLike {
    /// PXN enabled (NCCL ≥ 2.12 default on rail-optimized fabrics).
    pub pxn: bool,
}

impl NcclLike {
    pub fn new() -> Self {
        NcclLike { pxn: true }
    }

    pub fn without_pxn() -> Self {
        NcclLike { pxn: false }
    }

    fn pick_path(&self, topo: &Topology, s: usize, d: usize) -> Path {
        if topo.same_node(s, d) {
            return candidates(topo, s, d, false).remove(0);
        }
        if self.pxn {
            // PXN: rail selected by the DESTINATION's NIC affinity.
            let rail = topo.home_rail(d);
            candidates(topo, s, d, true)
                .into_iter()
                .find(|p| p.kind == PathKind::InterRail { rail })
                .expect("rail-matched candidate exists")
        } else {
            // pre-PXN: source's own NIC; mismatched rails pay the
            // switch-tier penalty via the cross-rail edge.
            match crate::topology::path::cross_rail_path(topo, s, d) {
                Some(p) => p,
                None => candidates(topo, s, d, false).remove(0), // same rail
            }
        }
    }
}

impl Default for NcclLike {
    fn default() -> Self {
        Self::new()
    }
}

impl Router for NcclLike {
    fn name(&self) -> &'static str {
        if self.pxn {
            "nccl"
        } else {
            "nccl-nopxn"
        }
    }

    fn mode(&self) -> XferMode {
        XferMode::Kernel
    }

    fn route(&mut self, topo: &Topology, demands: &[Demand]) -> Vec<(Path, f64)> {
        demands
            .iter()
            .filter(|d| d.bytes > 0.0)
            .map(|d| (self.pick_path(topo, d.src, d.dst), d.bytes))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intra_always_direct() {
        let t = Topology::paper();
        let mut e = NcclLike::new();
        let flows = e.route(&t, &[Demand::new(0, 3, 1e6)]);
        assert_eq!(flows.len(), 1);
        assert_eq!(flows[0].0.kind, PathKind::IntraDirect);
    }

    #[test]
    fn pxn_picks_destination_rail() {
        let t = Topology::paper();
        let mut e = NcclLike::new();
        // gpu1 → gpu6 (dst local = 2): PXN uses rail 2
        let flows = e.route(&t, &[Demand::new(1, 6, 1e6)]);
        assert_eq!(flows[0].0.kind, PathKind::InterRail { rail: 2 });
    }

    #[test]
    fn hotspot_concentrates_on_one_rail() {
        let t = Topology::paper();
        let mut e = NcclLike::new();
        let demands: Vec<Demand> = (0..4).map(|s| Demand::new(s, 4, 1e6)).collect();
        let flows = e.route(&t, &demands);
        // all four land on rail 0 (GPU 4's rail): the congestion the
        // paper highlights
        for (p, _) in &flows {
            assert_eq!(p.kind, PathKind::InterRail { rail: 0 });
        }
    }

    #[test]
    fn no_pxn_uses_cross_rail() {
        let t = Topology::paper();
        let mut e = NcclLike::without_pxn();
        let flows = e.route(&t, &[Demand::new(1, 6, 1e6)]);
        assert!(matches!(flows[0].0.kind, PathKind::InterCross { .. }));
        // same-rail pair stays matched
        let flows2 = e.route(&t, &[Demand::new(1, 5, 1e6)]);
        assert_eq!(flows2[0].0.kind, PathKind::InterRail { rail: 1 });
    }

    #[test]
    fn zero_demands_dropped() {
        let t = Topology::paper();
        let mut e = NcclLike::new();
        let flows = e.route(&t, &[Demand::new(0, 1, 0.0), Demand::new(0, 2, 5.0)]);
        assert_eq!(flows.len(), 1);
    }
}
