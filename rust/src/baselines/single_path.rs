//! Single-path baseline: the "direct" series of Fig 6 — always the
//! default least-hop path (direct NVLink intra-node, source-rail NIC
//! inter-node), kernel dataplane, no splitting of any kind.

use super::Router;
use crate::fabric::XferMode;
use crate::planner::Demand;
use crate::topology::path::candidates;
use crate::topology::{Path, Topology};

#[derive(Default)]
pub struct SinglePath;

impl SinglePath {
    pub fn new() -> Self {
        SinglePath
    }
}

impl Router for SinglePath {
    fn name(&self) -> &'static str {
        "single-path"
    }

    fn mode(&self) -> XferMode {
        XferMode::Kernel
    }

    fn route(&mut self, topo: &Topology, demands: &[Demand]) -> Vec<(Path, f64)> {
        demands
            .iter()
            .filter(|d| d.bytes > 0.0)
            .map(|d| (candidates(topo, d.src, d.dst, false).remove(0), d.bytes))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::PathKind;

    #[test]
    fn one_flow_per_demand() {
        let t = Topology::paper();
        let mut e = SinglePath::new();
        let flows =
            e.route(&t, &[Demand::new(0, 1, 1e6), Demand::new(0, 4, 1e6)]);
        assert_eq!(flows.len(), 2);
        assert_eq!(flows[0].0.kind, PathKind::IntraDirect);
        assert_eq!(flows[1].0.kind, PathKind::InterRail { rail: 0 });
    }
}
