//! `nimble` — launcher CLI for the NIMBLE reproduction.
//!
//! Subcommands regenerate every table/figure of the paper plus the
//! ablations (all shared with benches/ via `nimble::exp`):
//!
//! ```text
//! nimble table1            planner overhead vs comm (Table I)
//! nimble fig6 [--part a|b|c|d|all]
//! nimble fig7 [--payload-mb 64]
//! nimble fig8
//! nimble sendrecv          async p2p imbalance sweep
//! nimble ablate            design-choice ablations
//! nimble replan            execution-time re-planning vs static plan
//! nimble scale             cluster-scale hot-path sweep (incremental vs reference solver)
//! nimble xcheck            fluid ↔ packet backend cross-validation + tail latency
//! nimble serve [--jobs N --seed S --no-joint]   multi-tenant orchestrator on one shared fabric
//! nimble faults [--scenario flap|degrade|straggler|mixed] [--no-replan]   fault injection + replan-as-recovery
//! nimble plan --src 0 --dst 1 --mb 256   show a routing plan
//! nimble report <trace.jsonl> [--check]  render/validate a recorded telemetry trace
//! nimble explain <trace.jsonl> [--epoch E] [--link L] [--tenant T] [--check]   congestion attribution: blame tables, decision audits, tenant SLO burn
//! nimble moe-compute       run the AOT FFN artifacts (offline interpreter)
//! nimble info              topology + fabric calibration summary
//! ```
//!
//! Global flags (any subcommand): `--config <file.toml>` and
//! `--trace <out.jsonl>` — the latter records the execution-time
//! telemetry trace (`replan`, `faults` and `serve` are deeply
//! instrumented; see the [`nimble::telemetry`] module docs for the
//! JSONL schema).

use nimble::exp::{
    ablate, faults, fig6, fig7, fig8, interference, replan, scale, sendrecv, serve,
    table1, xcheck, MB,
};
use nimble::fabric::Scenario;
use nimble::fabric::{BackendKind, FabricParams, SchedulerKind};
use nimble::planner::{CostModel, Demand, Planner};
use nimble::runtime::Runtime;
use nimble::telemetry::{explain, report, Recorder, TraceRecord};
use nimble::topology::Topology;
use nimble::util::cli::Args;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    // global --config <path> (anywhere on the line): applies to every
    // subcommand; see configs/paper.toml for the reference file
    let mut cfg = nimble::config::Config::default();
    if let Some(i) = argv.iter().position(|a| a == "--config") {
        let path = argv.get(i + 1).cloned().unwrap_or_default();
        cfg = match nimble::config::Config::load(&path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("--config {path}: {e}");
                std::process::exit(2);
            }
        };
        argv.drain(i..=i + 1);
    }
    // global --trace <out.jsonl> (anywhere on the line): record the
    // telemetry trace of the run; `[telemetry]` in the config file is
    // the flag-less way to turn it on (DESIGN.md §15)
    let mut trace_path: Option<String> = None;
    if let Some(i) = argv.iter().position(|a| a == "--trace") {
        let Some(path) = argv.get(i + 1).cloned() else {
            eprintln!("--trace requires an output path (e.g. --trace out.jsonl)");
            std::process::exit(2);
        };
        trace_path = Some(path);
        argv.drain(i..=i + 1);
    }
    if trace_path.is_none() && cfg.telemetry.enable {
        trace_path = Some(cfg.telemetry.path.clone());
    }
    // with a file sink configured, records stream to disk as they are
    // emitted (an aborted run still leaves everything recorded so far)
    let rec = match &trace_path {
        Some(path) => match Recorder::to_file(path) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("--trace {path}: {e}");
                std::process::exit(2);
            }
        },
        None => Recorder::disabled(),
    };
    let Some(cmd) = argv.first().cloned() else {
        eprintln!("{}", usage());
        std::process::exit(2);
    };
    let rest = &argv[1..];
    let topo = cfg.topology.clone();
    let params = cfg.fabric.clone();
    rec.emit(|| TraceRecord::Meta {
        subcommand: cmd.clone(),
        backend: match params.backend {
            BackendKind::Fluid => "fluid",
            BackendKind::Packet => "packet",
        }
        .to_string(),
        scheduler: match params.packet.scheduler {
            SchedulerKind::Wheel => "wheel",
            SchedulerKind::Heap => "heap",
        }
        .to_string(),
        threads: params.packet.threads,
        topo: if topo.tier.is_some() { "fat-tree" } else { "flat" }.to_string(),
        nodes: topo.nodes,
        links: topo.links.len(),
        gpus: topo.num_gpus(),
    });
    let result = match cmd.as_str() {
        "table1" => {
            println!("{}", table1::render(&topo, &params, 9));
            Ok(())
        }
        "fig6" => Args::new("nimble fig6", "point-to-point multi-path bandwidth")
            .flag("part", "all", "a|b|c|d|all")
            .parse(rest)
            .map(|p| println!("{}", fig6::render(&topo, &params, p.get("part")))),
        "fig7" => Args::new("nimble fig7", "skewed All-to-Allv sweep")
            .flag("payload-mb", "64", "per-rank payload in MB")
            .parse(rest)
            .map(|p| {
                println!("{}", fig7::render(&topo, &params, p.get_f64("payload-mb") * MB))
            }),
        "fig8" => {
            println!("{}", fig8::render(&topo, &params));
            Ok(())
        }
        "sendrecv" => {
            println!("{}", sendrecv::render(&topo, &params));
            Ok(())
        }
        "ablate" => {
            println!("{}", ablate::render(&topo, &params));
            Ok(())
        }
        "interference" => {
            println!("{}", interference::render(&topo, &params));
            Ok(())
        }
        "replan" => Args::new(
            "nimble replan",
            "execution-time re-planning loop vs the static plan",
        )
        .flag("workload", "hotrows", "hotrows|moe (time-varying skew pattern)")
        .flag("rounds", "6", "rounds to fly (hot spot shifts between them)")
        .flag("row-mb", "64", "hot-row bytes per peer in MB")
        .flag("cadence-ms", "-1", "replan epoch cadence in ms (-1: from config)")
        .flag("margin", "-1", "challenger hysteresis margin (-1: from config)")
        .switch("no-replan", "disable re-planning (shows the byte-identical static path)")
        .parse(rest)
        .map(|p| {
            let mut rcfg = cfg.replan.clone();
            rcfg.enable = !p.get_bool("no-replan");
            if p.get_f64("cadence-ms") > 0.0 {
                rcfg.cadence_s = p.get_f64("cadence-ms") * 1e-3;
            }
            let margin = p.get_f64("margin");
            if margin >= 0.0 {
                // same validity range config.rs enforces for [replan]
                if margin >= 1.0 {
                    eprintln!("--margin out of [0,1): {margin}");
                    std::process::exit(2);
                }
                rcfg.margin = margin;
            }
            let workload = match p.get("workload") {
                "moe" => replan::Workload::MoeDrift,
                "hotrows" => replan::Workload::HotRows,
                other => {
                    eprintln!("--workload must be hotrows|moe, got '{other}'");
                    std::process::exit(2);
                }
            };
            println!(
                "{}",
                replan::render_traced(
                    &topo,
                    &params,
                    &rcfg,
                    workload,
                    p.get_usize("rounds"),
                    p.get_f64("row-mb"),
                    &rec,
                )
            );
        }),
        "scale" => Args::new(
            "nimble scale",
            "cluster-scale hot-path sweep: incremental vs reference solver",
        )
        .flag("nodes", "4", "cluster nodes (8 GPUs, 4 rails each); 0 = sweep 1,2,4,8")
        .flag("payload-mb", "64", "All-to-Allv payload per rank in MB")
        .flag("threads", "0", "planner threads (0: from config)")
        .flag("topo", "flat", "fabric shape: flat | fat-tree (leaf-spine core tier)")
        .flag("oversub", "2.0", "fat-tree core oversubscription ratio (>= 1.0)")
        .switch("no-reference", "skip the (slow) reference-solver baseline run")
        .switch("json", "emit one machine-readable JSON line per row")
        .switch(
            "check",
            "assert solver + packet-scheduler bit-identity, static-path equivalence (CI perf smoke)",
        )
        .parse(rest)
        .map(|p| {
            let payload = p.get_f64("payload-mb") * MB;
            let mut pcfg = cfg.planner.clone();
            if p.get_usize("threads") > 0 {
                pcfg.threads = p.get_usize("threads");
            }
            let topo_kind = match p.get("topo") {
                "flat" => scale::ScaleTopo::Flat,
                "fat-tree" => {
                    let oversub = p.get_f64("oversub");
                    if !(oversub.is_finite() && oversub >= 1.0) {
                        eprintln!("--oversub must be a finite ratio >= 1.0, got {oversub}");
                        std::process::exit(2);
                    }
                    scale::ScaleTopo::FatTree { oversub }
                }
                other => {
                    eprintln!("--topo must be flat|fat-tree, got '{other}'");
                    std::process::exit(2);
                }
            };
            let with_reference = !p.get_bool("no-reference");
            let nodes_arg = p.get_usize("nodes");
            let node_counts: Vec<usize> =
                if nodes_arg == 0 { vec![1, 2, 4, 8] } else { vec![nodes_arg] };
            let rows = scale::sweep(
                &node_counts,
                payload,
                &params,
                &pcfg,
                with_reference,
                topo_kind,
            );
            if p.get_bool("json") {
                for r in &rows {
                    println!("{}", r.json_line());
                }
            } else {
                println!("{}", scale::render(&rows, payload, pcfg.threads));
            }
            if p.get_bool("check") {
                for r in &rows {
                    // run_one already asserted trajectory bit-identity;
                    // close the loop against the replan executor too
                    scale::check_static_bit_identity(
                        r.nodes, payload, &params, &pcfg, topo_kind,
                    );
                    if let Some(speedup) = r.speedup() {
                        // generous floor: the bench harness tracks the
                        // real ratio; this only catches regressions
                        // back toward from-scratch behavior
                        if r.nodes >= 4 && speedup < 2.0 {
                            eprintln!(
                                "perf smoke FAILED: {} nodes speedup {speedup:.2}x < 2x",
                                r.nodes
                            );
                            std::process::exit(1);
                        }
                    }
                    // packet-engine anchor: the timing wheel must replay
                    // the heap oracle bit-for-bit on this point's planned
                    // workload, and beat it on wall clock. The floor is
                    // noise-tolerant (the bench harness tracks the real
                    // ≥5x) and skipped at tiny sizes where wall clock is
                    // all jitter; the payload is capped because the gate
                    // is about per-event scheduling cost, not bytes.
                    let smoke = scale::check_packet_engine(
                        r.nodes,
                        payload.min(MB),
                        &params,
                        &pcfg,
                        topo_kind,
                        (r.nodes >= 4).then_some(1.5),
                    );
                    eprintln!(
                        "  {} nodes: packet wheel {:.2}M events/s, {:.2}x vs heap",
                        r.nodes,
                        smoke.events_per_sec() / 1e6,
                        smoke.speedup(),
                    );
                    // tiered acceptance anchor: planned multi-path must
                    // not lose to the ECMP hash-striping adversary
                    if let scale::ScaleTopo::FatTree { oversub } = topo_kind {
                        let (planned, ecmp) = scale::check_planned_beats_ecmp(
                            r.nodes, payload, oversub, &params, &pcfg,
                        );
                        eprintln!(
                            "  {} nodes: planned {planned:.1} GB/s vs ecmp {ecmp:.1} GB/s \
                             ({:.2}x)",
                            r.nodes,
                            planned / ecmp.max(1e-12),
                        );
                    }
                }
                // stderr: keep --json stdout purely machine-readable
                if with_reference {
                    eprintln!(
                        "scale check OK: solvers bit-identical, static path preserved"
                    );
                } else {
                    eprintln!(
                        "scale check OK: static path preserved (solver comparison \
                         skipped: --no-reference)"
                    );
                }
            }
        }),
        "serve" => Args::new(
            "nimble serve",
            "multi-tenant orchestrator: seeded job stream on one shared fabric",
        )
        .flag("jobs", "0", "jobs in the stream (0: from config [tenancy])")
        .flag("seed", "-1", "arrival/workload seed (-1: from config)")
        .flag("max-live", "0", "admission concurrency cap (0: from config)")
        .flag("gap-ms", "-1", "mean inter-arrival gap in ms (-1: from config)")
        .switch("no-joint", "independent per-job plans (the baseline arm only)")
        .switch("check", "assert joint beats independent + determinism + 1-job PR-2 anchor")
        .parse(rest)
        .map(|p| {
            let mut tcfg = cfg.tenancy.clone();
            if p.get_usize("jobs") > 0 {
                tcfg.jobs = p.get_usize("jobs");
            }
            if p.get("seed") != "-1" {
                tcfg.seed = p.get_u64("seed");
            }
            if p.get_usize("max-live") > 0 {
                tcfg.max_live = p.get_usize("max-live");
            }
            if p.get_f64("gap-ms") > 0.0 {
                tcfg.mean_gap_ms = p.get_f64("gap-ms");
            }
            if p.get_bool("no-joint") {
                tcfg.joint = false;
            }
            if let Err(e) = tcfg.validate() {
                eprintln!("{e}");
                std::process::exit(2);
            }
            let checking = p.get_bool("check");
            let check_result = if checking && tcfg.joint {
                // run each arm exactly once: the gates reuse the same
                // runs the report renders
                let (joint, indep) = serve::run_comparison_traced(
                    &topo,
                    &params,
                    &cfg.planner,
                    &cfg.replan,
                    &tcfg,
                    &rec,
                );
                print!("{}", serve::render_stream(&topo, &params, &tcfg));
                println!("{}", serve::render_runs(&cfg.replan, &joint, &indep));
                Some(serve::check_runs(
                    &topo,
                    &params,
                    &cfg.planner,
                    &cfg.replan,
                    &tcfg,
                    &joint,
                    &indep,
                ))
            } else {
                println!(
                    "{}",
                    serve::render_traced(
                        &topo,
                        &params,
                        &cfg.planner,
                        &cfg.replan,
                        &tcfg,
                        &rec,
                    )
                );
                checking.then(|| {
                    serve::check(&topo, &params, &cfg.planner, &cfg.replan, &tcfg)
                })
            };
            match check_result {
                // stderr, like the other smokes: stdout stays a report
                Some(Ok(())) => eprintln!(
                    "serve check OK: joint beats independent on goodput and \
                     weighted fairness; deterministic; 1-job --no-joint matches \
                     ReplanExecutor byte-for-byte"
                ),
                Some(Err(e)) => {
                    eprintln!("serve check FAILED: {e}");
                    std::process::exit(1);
                }
                None => {}
            }
        }),
        "faults" => Args::new(
            "nimble faults",
            "fault injection + replan-as-recovery: link flaps, degraded rails, stragglers",
        )
        .flag(
            "scenario",
            "config",
            "flap|degrade|straggler|mixed|all|config (config: the [faults] section; all when it says none)",
        )
        .switch("no-replan", "frozen arms only (shows what static plans lose on their own)")
        .switch("check", "enforce the recovery, bit-identity and cross-backend gates")
        .parse(rest)
        .map(|p| {
            let fparams = cfg.faults.params;
            let scenarios: Vec<Scenario> = match p.get("scenario") {
                "config" => match cfg.faults.scenario {
                    Some(sc) => vec![sc],
                    None => Scenario::all().to_vec(),
                },
                "all" => Scenario::all().to_vec(),
                name => match Scenario::parse(name) {
                    Some(sc) => vec![sc],
                    None => {
                        eprintln!(
                            "--scenario must be flap|degrade|straggler|mixed|all|config, \
                             got '{name}'"
                        );
                        std::process::exit(2);
                    }
                },
            };
            let with_replan = !p.get_bool("no-replan");
            let rep = faults::run_traced(
                &params, &cfg.planner, &fparams, &scenarios, with_replan, &rec,
            );
            println!("{}", faults::render(&rep));
            if p.get_bool("check") {
                match faults::check(&rep, &params, &cfg.planner, &fparams) {
                    // stderr, like the other smokes: stdout stays a report
                    Ok(()) => eprintln!(
                        "faults check OK: replan retains ≥ static and ≥ ecmp on every \
                         scenario; empty schedules bitwise inert; degrade agrees \
                         across backends within ±{:.0}%",
                        xcheck::GOODPUT_TOL * 100.0
                    ),
                    Err(e) => {
                        eprintln!("faults check FAILED: {e}");
                        std::process::exit(1);
                    }
                }
            }
        }),
        "xcheck" => Args::new(
            "nimble xcheck",
            "fluid ↔ packet backend cross-validation + tail-latency report",
        )
        .flag("payload-mb", "64", "anchor payload per flow/rank in MB (agreement is calibrated ≥ 64)")
        .flag("rounds", "4", "PhasedHotRows rounds on the packet backend")
        .flag("row-mb", "48", "hot-row bytes per peer in MB")
        .switch("quick", "CI-sized run (3 rounds of 24 MB rows)")
        .switch("check", "enforce the agreement tolerance + p99 acceptance gate")
        .parse(rest)
        .map(|p| {
            let quick = p.get_bool("quick");
            let payload_mb = p.get_f64("payload-mb");
            let rounds = if quick { 3 } else { p.get_usize("rounds") };
            let row_mb = if quick { 24.0 } else { p.get_f64("row-mb") };
            let rep = xcheck::run(&topo, &params, payload_mb, rounds, row_mb);
            println!("{}", xcheck::render(&rep));
            if p.get_bool("check") {
                match xcheck::check(&rep) {
                    // stderr, like the scale smoke: stdout stays a report
                    Ok(()) => eprintln!(
                        "xcheck OK: backends agree within ±{:.0}%, replanned p99 \
                         {:.1} µs < static {:.1} µs",
                        xcheck::GOODPUT_TOL * 100.0,
                        rep.replan.replanned_p99_us,
                        rep.replan.static_p99_us,
                    ),
                    Err(e) => {
                        eprintln!("xcheck FAILED: {e}");
                        std::process::exit(1);
                    }
                }
            }
        }),
        "plan" => Args::new("nimble plan", "show the routing plan for one demand")
            .flag("src", "0", "source GPU")
            .flag("dst", "1", "destination GPU")
            .flag("mb", "256", "message size in MB")
            .parse(rest)
            .map(|p| {
                let d = Demand::new(p.get_usize("src"), p.get_usize("dst"), p.get_f64("mb") * MB);
                let mut planner = Planner::new(&topo, cfg.planner.clone());
                let plan = planner.plan(&[d]);
                println!(
                    "plan for {} → {} ({} MB), computed in {:.1} µs:",
                    d.src,
                    d.dst,
                    p.get("mb"),
                    plan.plan_time_s * 1e6
                );
                for (path, bytes) in &plan.assignments[&(d.src, d.dst)].parts {
                    println!(
                        "  {:>10.1} MB via {:?} ({} hops{})",
                        bytes / MB,
                        path.kind,
                        path.hops.len(),
                        if CostModel::is_detour(&topo, path) { ", detour" } else { "" }
                    );
                }
            }),
        "report" => {
            run_report(rest);
            Ok(())
        }
        "explain" => {
            run_explain(rest);
            Ok(())
        }
        "moe-compute" => run_moe_compute(),
        "info" => {
            print_info(&topo, &params);
            Ok(())
        }
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("{e}");
        std::process::exit(2);
    }
    if let Some(path) = &trace_path {
        // shallow commands still leave a valid trace (meta + note)
        // rather than a bare meta line that looks like a broken run
        if rec.len() <= 1 {
            rec.emit(|| TraceRecord::Note {
                text: format!(
                    "subcommand '{cmd}' has no deep instrumentation; \
                     replan, faults and serve record full traces"
                ),
            });
        }
        // records were streamed as they were emitted; finish() flushes
        // and surfaces any write error deferred along the way
        match rec.finish() {
            Ok(n) => eprintln!("trace: {n} records -> {path}"),
            Err(e) => {
                eprintln!("--trace {path}: {e}");
                std::process::exit(2);
            }
        }
    }
}

/// `nimble report <trace.jsonl> [--check]`: render a recorded trace;
/// `--check` re-derives the headline numbers from the raw records and
/// exits 1 on any mismatch (hand-parsed: the one command that takes a
/// positional argument).
fn run_report(rest: &[String]) {
    let mut path: Option<String> = None;
    let mut checking = false;
    for a in rest {
        match a.as_str() {
            "--check" => checking = true,
            "--help" | "-h" => {
                println!(
                    "nimble report <trace.jsonl> [--check] — render a telemetry trace\n\
                     recorded with --trace; --check validates the schema and recomputes\n\
                     goodput/retention/time-to-recover bit-exactly from the raw records"
                );
                return;
            }
            other if !other.starts_with('-') && path.is_none() => {
                path = Some(other.to_string());
            }
            other => {
                eprintln!("nimble report: unexpected argument '{other}'");
                std::process::exit(2);
            }
        }
    }
    let Some(path) = path else {
        eprintln!("nimble report: missing trace path (usage: nimble report <trace.jsonl> [--check])");
        std::process::exit(2);
    };
    let trace = match report::Trace::load(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("nimble report: {e}");
            std::process::exit(2);
        }
    };
    print!("{}", report::render(&trace));
    if checking {
        let out = report::check(&trace);
        for w in &out.warnings {
            eprintln!("report check warning: {w}");
        }
        if out.ok() {
            eprintln!("report check OK: {} recomputations match bit-exactly", out.checks);
        } else {
            for e in &out.errors {
                eprintln!("report check FAILED: {e}");
            }
            std::process::exit(1);
        }
    }
}

/// `nimble explain <trace.jsonl> [--epoch E] [--link L] [--tenant T]
/// [--check]`: congestion attribution from a recorded trace — blame
/// tables, replan decision audits, per-tenant SLO burn. `--check`
/// re-verifies blame-sum conservation bit-exactly and recomputes every
/// histogram headline from its sparse buckets; exits 1 on any mismatch.
/// Hand-parsed like `report` (positional trace path).
fn run_explain(rest: &[String]) {
    let mut path: Option<String> = None;
    let mut checking = false;
    let mut opts = explain::ExplainOpts::default();
    let mut want_val: Option<&str> = None;
    for a in rest {
        if let Some(flag) = want_val.take() {
            let parsed: Result<i64, _> = a.parse();
            let Ok(v) = parsed else {
                eprintln!("nimble explain: --{flag} needs an integer, got '{a}'");
                std::process::exit(2);
            };
            match flag {
                "epoch" => opts.epoch = Some(v as u64),
                "link" => opts.link = Some(v as usize),
                _ => opts.tenant = Some(v),
            }
            continue;
        }
        match a.as_str() {
            "--check" => checking = true,
            "--epoch" => want_val = Some("epoch"),
            "--link" => want_val = Some("link"),
            "--tenant" => want_val = Some("tenant"),
            "--help" | "-h" => {
                println!(
                    "nimble explain <trace.jsonl> [--epoch E] [--link L] [--tenant T] [--check]\n\
                     — why was a constraint hot, why did a decision go the way it did, who is\n\
                     burning each tenant's latency budget. --epoch/--link focus the blame\n\
                     tables; --tenant focuses decisions and the SLO table; --check re-verifies\n\
                     blame-sum conservation (bit-exact) and histogram headline consistency"
                );
                return;
            }
            other if !other.starts_with('-') && path.is_none() => {
                path = Some(other.to_string());
            }
            other => {
                eprintln!("nimble explain: unexpected argument '{other}'");
                std::process::exit(2);
            }
        }
    }
    if let Some(flag) = want_val {
        eprintln!("nimble explain: --{flag} requires a value");
        std::process::exit(2);
    }
    let Some(path) = path else {
        eprintln!(
            "nimble explain: missing trace path \
             (usage: nimble explain <trace.jsonl> [--epoch E] [--link L] [--tenant T] [--check])"
        );
        std::process::exit(2);
    };
    let trace = match report::Trace::load(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("nimble explain: {e}");
            std::process::exit(2);
        }
    };
    print!("{}", explain::render(&trace, &opts));
    if checking {
        let out = explain::check(&trace);
        for w in &out.warnings {
            eprintln!("explain check warning: {w}");
        }
        if out.errors.is_empty() {
            eprintln!(
                "explain check OK: {} blame/histogram invariants verified bit-exactly",
                out.checks
            );
        } else {
            for e in &out.errors {
                eprintln!("explain check FAILED: {e}");
            }
            std::process::exit(1);
        }
    }
}

fn usage() -> String {
    "nimble — NIMBLE (skew-to-symmetry multi-path balancing) reproduction\n\
     commands: table1 | fig6 | fig7 | fig8 | sendrecv | ablate | interference | replan | scale | xcheck | serve | faults | plan | report | explain | moe-compute | info\n\
     global flags: --config <file.toml> | --trace <out.jsonl> (telemetry, rendered by `nimble report`)\n\
     run `nimble <cmd> --help` for flags"
        .to_string()
}

fn print_info(topo: &Topology, params: &FabricParams) {
    println!("topology: {} nodes × {} GPUs (+{} NICs) = {} GPUs, {} directed links",
        topo.nodes, topo.gpus_per_node, topo.nics_per_node, topo.num_gpus(), topo.links.len());
    println!("calibration (from the paper's §V-B measurements):");
    println!("  NVLink direct      : {:.1} GB/s effective", topo.nvlink_gbps);
    println!("  NDR rail           : {:.1} GB/s effective", topo.rail_gbps);
    println!("  relay pass-through : ρ = {:.3}  (⇒ 213.1 GB/s for 2 paths)", params.relay_rho);
    println!("  GPU injection cap  : {:.1} GB/s (⇒ 278.2 GB/s for 3 paths)", params.inject_cap_gbps);
    println!("  node NIC aggregate : {:.1} GB/s (4 rails)", params.node_net_cap_gbps);
    println!("  multi-path guard   : ≤ {} bytes single-path", 1024 * 1024);
}

fn run_moe_compute() -> Result<(), nimble::util::cli::CliError> {
    let dir = Runtime::default_dir();
    let mut rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            return Err(nimble::util::cli::CliError(format!(
                "{e}\nhint: run `make artifacts` first"
            )))
        }
    };
    println!("artifacts: {:?}", rt.artifact_names());
    for name in ["expert_ffn_t256", "expert_ffn_t1024", "expert_ffn_t4096"] {
        let info = rt.artifact_info(name);
        let (t, d, f) = (
            info.get("tokens").as_u64().unwrap() as usize,
            info.get("d_model").as_u64().unwrap() as usize,
            info.get("d_ff").as_u64().unwrap() as usize,
        );
        let x = vec![0.1f32; t * d];
        let w1 = vec![0.02f32; d * f];
        let w2 = vec![0.02f32; f * d];
        let inputs = [
            Runtime::literal_f32(&x, &[t as i64, d as i64]).unwrap(),
            Runtime::literal_f32(&w1, &[d as i64, f as i64]).unwrap(),
            Runtime::literal_f32(&w2, &[f as i64, d as i64]).unwrap(),
        ];
        let t0 = std::time::Instant::now();
        let out = rt.execute(name, &inputs).map_err(|e| {
            nimble::util::cli::CliError(format!("execute {name}: {e}"))
        })?;
        let dt = t0.elapsed().as_secs_f64();
        let y = out[0].to_vec::<f32>().unwrap();
        println!(
            "{name}: {t}×{d} tokens through FFN({d}→{f}→{d}) in {:.1} ms via the offline interpreter (y[0]={:.4})",
            dt * 1e3,
            y[0]
        );
    }
    Ok(())
}
