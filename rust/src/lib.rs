//! # NIMBLE — Node-Interconnect Multi-path Balancing with
//! Execution-time planning
//!
//! Reproduction of *"From Skew to Symmetry: Node-Interconnect
//! Multi-Path Balancing with Execution-time Planning for Modern GPU
//! Clusters"* (Yao et al., CS.DC 2026) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L3 (this crate)** — the paper's orchestration contribution:
//!   the MWU minimum-congestion planner (Algorithm 1), the NIMBLE
//!   coordinator (monitoring, channels, reassembly, thresholds), the
//!   closed execution-time re-planning loop, collectives, baselines,
//!   workload generators — all running against a calibrated fabric
//!   simulator standing in for the H100/NDR testbed (see DESIGN.md §2
//!   for the substitution table).
//! * **L2/L1 (python/compile)** — JAX MoE model with Pallas kernels,
//!   AOT-lowered to HLO text + manifest and executed from [`runtime`]
//!   (offline CPU interpreter; see DESIGN.md §6).
//!
//! ## Module map (code → paper)
//!
//! | module | paper | role |
//! |---|---|---|
//! | [`topology`] | §IV-B, §V-A | NVLink mesh + rail-matched NICs, candidate paths |
//! | [`planner`] | Algorithm 1, §IV-B | MWU min-congestion routing + incremental [`planner::Planner::replan`] |
//! | [`fabric`] | §V-B | calibrated fluid + packet + chunk-pipeline simulators behind the [`fabric::FabricBackend`] trait: resumable [`fabric::fluid::SimEngine`] (incremental + reference water-fillers, [`fabric::fluid::SolverKind`]) and the discrete-event [`fabric::packet::PacketSim`] (queueing + tail latency); [`fabric::faults`] injects seeded link flaps / degraded rails / stragglers into both (DESIGN.md §13) |
//! | [`coordinator`] | §IV | monitor / channels / reassembly, [`coordinator::Orchestrator`] and the mid-flight [`coordinator::ReplanExecutor`] |
//! | [`orchestrator`] | beyond §V-E | multi-tenant serving: seeded job stream → admission → joint planning ([`planner::Planner::plan_joint`]) → one shared fabric, weighted fairness via channel allocation, per-tenant reassembly (`nimble serve`) |
//! | [`collectives`] | §IV-E | All-to-Allv, async Send/Recv, ring collectives |
//! | [`baselines`] | §II-B, §V | NCCL-like (PXN), MPI/UCX-like, single-path |
//! | [`workloads`] | §III-A, §V-C/D | skew generators incl. time-varying [`workloads::dynamic`] |
//! | [`exp`] | §V tables/figures | one driver per paper artifact + `exp::replan`, the `exp::scale` hot-path sweep, the `exp::xcheck` fluid ↔ packet cross-validation, and the `exp::faults` recovery arms (`nimble faults`) |
//! | [`moe`] | §V-D, Fig 8 | MoE expert-parallel step driver |
//! | [`runtime`] | DESIGN.md §6 | AOT artifact interpreter (L2/L1 bridge) |
//! | [`telemetry`] | §IV-A observability | execution-time trace subsystem: [`telemetry::Recorder`] sink threaded through planner/coordinator/orchestrator/fabric, JSONL schema + `nimble report` renderer (DESIGN.md §15) |
//! | [`metrics`], [`util`], [`config`] | — | reports, std-only substrates, TOML config |
//!
//! ARCHITECTURE.md walks the planner ↔ fabric ↔ coordinator data flow,
//! including the replan feedback edge; EXPERIMENTS.md maps every CLI
//! subcommand to its paper artifact.
//!
//! ## Quickstart
//!
//! Plan a skewed transfer with Algorithm 1, then let the
//! execution-time loop rescue a stale plan mid-flight:
//!
//! ```
//! use nimble::coordinator::ReplanExecutor;
//! use nimble::fabric::FabricParams;
//! use nimble::planner::{Demand, Planner, PlannerCfg, ReplanCfg};
//! use nimble::topology::Topology;
//!
//! let topo = Topology::paper(); // 2 nodes × (4× H100 + 4× NDR NIC)
//! let mb = 1024.0 * 1024.0;
//!
//! // Algorithm 1 spreads a heavy pair across direct + relay paths
//! let demands = vec![Demand::new(0, 1, 512.0 * mb)];
//! let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
//! assert!(plan.assignments[&(0, 1)].path_count() > 1);
//!
//! // Execution-time loop: the incumbent was planned when (2→1) was
//! // tiny; once the pair turns heavy, the monitor → replan → reroute
//! // loop preempts the single-path residual and goes multi-path.
//! let stale = Planner::new(&topo, PlannerCfg::default())
//!     .plan(&[Demand::new(2, 1, 2.0 * mb)]);
//! let rcfg = ReplanCfg { enable: true, cadence_s: 2.0e-4, ..ReplanCfg::default() };
//! let mut exec = ReplanExecutor::new(
//!     &topo,
//!     FabricParams::default(),
//!     PlannerCfg::default(),
//!     rcfg,
//! );
//! let run = exec.execute(&stale, &[Demand::new(2, 1, 512.0 * mb)]);
//! assert!(run.replans >= 1, "the loop should have rerouted mid-flight");
//! ```
//!
//! Entry points: the `nimble` binary (`nimble --help`), the
//! `examples/`, and the per-figure benches under `benches/`.

// The simulator/planner hot loops iterate `0..len` while mutating
// sibling fields through `&mut self`; the iterator form clippy
// suggests cannot borrow-check there, so the lint is disabled
// crate-wide rather than annotating every hot loop.
#![allow(clippy::needless_range_loop)]

pub mod baselines;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod fabric;
pub mod metrics;
pub mod moe;
pub mod orchestrator;
pub mod planner;
pub mod runtime;
pub mod telemetry;
pub mod topology;
pub mod util;
pub mod workloads;
