//! # NIMBLE — Node-Interconnect Multi-path Balancing with
//! Execution-time planning
//!
//! Reproduction of *"From Skew to Symmetry: Node-Interconnect
//! Multi-Path Balancing with Execution-time Planning for Modern GPU
//! Clusters"* (Yao et al., CS.DC 2026) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L3 (this crate)** — the paper's orchestration contribution:
//!   the MWU minimum-congestion planner (Algorithm 1), the NIMBLE
//!   coordinator (monitoring, channels, reassembly, thresholds),
//!   collectives, baselines, workload generators — all running against
//!   a calibrated fabric simulator standing in for the H100/NDR
//!   testbed (see DESIGN.md §2 for the substitution table).
//! * **L2/L1 (python/compile)** — JAX MoE model with Pallas kernels,
//!   AOT-lowered to HLO text + manifest and executed from [`runtime`]
//!   (offline CPU interpreter; see DESIGN.md §6).
//!
//! Entry points: the `nimble` binary (`nimble --help`), the
//! `examples/`, and the per-figure benches under `benches/`.

pub mod baselines;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod fabric;
pub mod metrics;
pub mod moe;
pub mod planner;
pub mod runtime;
pub mod topology;
pub mod util;
pub mod workloads;
