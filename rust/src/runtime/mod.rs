//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the rust side touches XLA; Python never runs
//! on the request path. Artifacts are HLO *text* (see aot.py for why),
//! parsed with `HloModuleProto::from_text_file`, compiled once per
//! process, and cached.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Loader + executor over an artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Json,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {mpath:?} — run `make artifacts` first"))?;
        let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, cache: BTreeMap::new() })
    }

    /// Default artifact directory (repo-root/artifacts).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn manifest(&self) -> &Json {
        &self.manifest
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .get("artifacts")
            .as_obj()
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Metadata for one artifact.
    pub fn artifact_info(&self, name: &str) -> &Json {
        self.manifest.get("artifacts").get(name)
    }

    /// Compile (or fetch from cache) an artifact.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let info = self.manifest.get("artifacts").get(name);
            let file = info
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact with literal inputs; returns the flattened
    /// tuple outputs (aot.py lowers with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let expect = self.artifact_info(name).get("inputs").as_arr().map(|a| a.len());
        if let Some(n) = expect {
            if n != inputs.len() {
                bail!("artifact '{name}' wants {n} inputs, got {}", inputs.len());
            }
        }
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Helper: f32 literal from a flat vec + dims.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// Helper: i32 literal from a flat vec + dims.
    pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }
}

/// Analytic H100 compute model for the Fig 8 timeline (the simulated
/// cluster's compute phase; the *real* kernels run via [`Runtime`] in
/// the e2e example). bf16 FFN on an H100 SXM: peak 989 TFLOP/s; we
/// assume the paper's stack sustains ~45% on these GEMM shapes.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    pub sustained_tflops: f64,
    pub kernel_launch_us: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        ComputeModel { sustained_tflops: 445.0, kernel_launch_us: 12.0 }
    }
}

impl ComputeModel {
    /// Time for one expert to run its two-layer FFN over `tokens`.
    pub fn expert_ffn_s(&self, tokens: f64, d_model: f64, d_ff: f64) -> f64 {
        let flops = 2.0 * 2.0 * tokens * d_model * d_ff; // 2 GEMMs × 2 flop/MAC
        flops / (self.sustained_tflops * 1e12) + self.kernel_launch_us * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn compute_model_scales_linearly() {
        let m = ComputeModel::default();
        let t1 = m.expert_ffn_s(1024.0, 4096.0, 16384.0);
        let t2 = m.expert_ffn_s(2048.0, 4096.0, 16384.0);
        let flop_part1 = t1 - m.kernel_launch_us * 1e-6;
        let flop_part2 = t2 - m.kernel_launch_us * 1e-6;
        assert!((flop_part2 / flop_part1 - 2.0).abs() < 1e-9);
    }

    /// Full PJRT round-trip over the real artifacts (skips cleanly if
    /// `make artifacts` hasn't run yet — `make test` orders it first).
    #[test]
    fn expert_ffn_artifact_executes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        let info = rt.artifact_info("expert_ffn_t256");
        let d = info.get("d_model").as_u64().unwrap() as usize;
        let f = info.get("d_ff").as_u64().unwrap() as usize;
        let t = 256usize;
        let x = vec![0.5f32; t * d];
        let w1 = vec![0.01f32; d * f];
        let w2 = vec![0.01f32; f * d];
        let out = rt
            .execute(
                "expert_ffn_t256",
                &[
                    Runtime::literal_f32(&x, &[t as i64, d as i64]).unwrap(),
                    Runtime::literal_f32(&w1, &[d as i64, f as i64]).unwrap(),
                    Runtime::literal_f32(&w2, &[f as i64, d as i64]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let y = out[0].to_vec::<f32>().unwrap();
        assert_eq!(y.len(), t * d);
        // y = gelu(x@w1)@w2 with constant inputs: every element equal
        // and matching the analytic value
        assert!(y[0].is_finite());
        assert!((y[0] - y[t * d - 1]).abs() < 1e-3);
        let h = 0.5 * 0.01 * d as f64;
        let gelu = 0.5 * h * (1.0 + erf(h / std::f64::consts::SQRT_2));
        let expect = (gelu * 0.01 * f as f64) as f32;
        assert!(
            (y[0] - expect).abs() / expect.abs() < 2e-2,
            "y={} expect={expect}",
            y[0]
        );
    }

    /// erf via Abramowitz–Stegun 7.1.26 (tests only).
    fn erf(x: f64) -> f64 {
        let t = 1.0 / (1.0 + 0.3275911 * x.abs());
        let y = 1.0
            - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736)
                * t
                + 0.254829592)
                * t
                * (-x * x).exp();
        if x >= 0.0 {
            y
        } else {
            -y
        }
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        assert!(rt.execute("nonexistent", &[]).is_err());
    }
}
