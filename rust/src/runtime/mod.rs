//! Artifact runtime: loads the AOT manifest produced by
//! `python/compile/aot.py` and executes the FFN-family artifacts with a
//! built-in pure-Rust CPU reference interpreter.
//!
//! The original design executed the HLO text through PJRT via the `xla`
//! bindings; those bindings (and `anyhow`) are not in the offline
//! vendor set, and this crate ships with **zero external dependencies**
//! (DESIGN.md §6). The interpreter computes the same math the lowered
//! graphs encode — `expert_ffn`: `y = gelu(x @ w1) @ w2`, and
//! `moe_block_fwd`: softmax gating + per-expert FFN + gate-weighted
//! combine — directly from the manifest's shape metadata, so the
//! `nimble moe-compute` CLI and `examples/moe_e2e.rs` still run the L2
//! graphs' semantics end-to-end from Rust. `train_step` (fwd+bwd+SGD of
//! the tiny MoE-transformer LM) is out of interpreter scope and reports
//! a clear error; re-enabling true PJRT execution is a vendoring task,
//! not an API change — this module's surface matches the PJRT version.

use crate::util::json::Json;
use std::fmt;
use std::path::{Path, PathBuf};

/// Runtime error (message-carrying, mirrors the former `anyhow` usage).
#[derive(Debug)]
pub struct RtError(pub String);

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for RtError {}

pub type Result<T> = std::result::Result<T, RtError>;

fn err(msg: impl Into<String>) -> RtError {
    RtError(msg.into())
}

/// Typed dense tensor (the interpreter's stand-in for `xla::Literal`).
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

#[derive(Clone, Debug)]
enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types a [`Literal`] can hold / be read back as.
pub trait LiteralElem: Sized {
    fn from_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl LiteralElem for f32 {
    fn from_literal(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            LiteralData::F32(v) => Ok(v.clone()),
            LiteralData::I32(_) => Err(err("literal holds i32, asked for f32")),
        }
    }
}

impl LiteralElem for i32 {
    fn from_literal(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            LiteralData::I32(v) => Ok(v.clone()),
            LiteralData::F32(_) => Err(err("literal holds f32, asked for i32")),
        }
    }
}

impl Literal {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn len(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn to_vec<T: LiteralElem>(&self) -> Result<Vec<T>> {
        T::from_literal(self)
    }

    fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            LiteralData::F32(v) => Ok(v),
            LiteralData::I32(_) => Err(err("expected f32 literal")),
        }
    }
}

/// Loader + executor over an artifact directory.
pub struct Runtime {
    dir: PathBuf,
    manifest: Json,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .map_err(|e| err(format!("reading {mpath:?} — run `make artifacts` first: {e}")))?;
        let manifest = Json::parse(&text).map_err(|e| err(format!("manifest: {e}")))?;
        Ok(Runtime { dir, manifest })
    }

    /// Default artifact directory (repo-root/artifacts).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Directory this runtime was opened on.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &Json {
        &self.manifest
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .get("artifacts")
            .as_obj()
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Metadata for one artifact.
    pub fn artifact_info(&self, name: &str) -> &Json {
        self.manifest.get("artifacts").get(name)
    }

    /// Whether the built-in interpreter can execute this artifact.
    pub fn supports(&self, name: &str) -> bool {
        let info = self.artifact_info(name);
        if info.as_obj().is_none() {
            return false;
        }
        Self::interp_kind(name, info).is_some()
    }

    fn interp_kind(name: &str, info: &Json) -> Option<InterpKind> {
        let n_inputs = info.get("inputs").as_arr().map(|a| a.len())?;
        if name.starts_with("expert_ffn") && n_inputs == 3 {
            return Some(InterpKind::ExpertFfn);
        }
        if name == "moe_block_fwd" && n_inputs == 4 {
            return Some(InterpKind::MoeBlockFwd);
        }
        None
    }

    /// Execute an artifact with literal inputs; returns the flattened
    /// tuple outputs (aot.py lowers with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let info = self.manifest.get("artifacts").get(name);
        if info.as_obj().is_none() {
            return Err(err(format!("artifact '{name}' not in manifest")));
        }
        if let Some(specs) = info.get("inputs").as_arr() {
            if specs.len() != inputs.len() {
                return Err(err(format!(
                    "artifact '{name}' wants {} inputs, got {}",
                    specs.len(),
                    inputs.len()
                )));
            }
            // Per-input shapes must match the manifest specs — the PJRT
            // path rejected layout mismatches at compile time; an
            // element-count check alone would accept e.g. a transposed
            // tensor and silently compute on the wrong layout.
            for (i, (spec, lit)) in specs.iter().zip(inputs).enumerate() {
                if let Some(shape) = spec.get("shape").as_arr() {
                    let want: Vec<i64> = shape.iter().filter_map(|x| x.as_i64()).collect();
                    if want.len() == shape.len() && lit.dims() != want.as_slice() {
                        return Err(err(format!(
                            "artifact '{name}' input {i}: literal shape {:?} does not \
                             match manifest shape {want:?}",
                            lit.dims()
                        )));
                    }
                }
            }
        }
        match Self::interp_kind(name, info) {
            Some(InterpKind::ExpertFfn) => {
                let (t, d, f) = (
                    info.get("tokens").as_u64().ok_or_else(|| err("manifest missing tokens"))?
                        as usize,
                    info.get("d_model").as_u64().ok_or_else(|| err("manifest missing d_model"))?
                        as usize,
                    info.get("d_ff").as_u64().ok_or_else(|| err("manifest missing d_ff"))?
                        as usize,
                );
                let y = expert_ffn(
                    inputs[0].f32s()?,
                    inputs[1].f32s()?,
                    inputs[2].f32s()?,
                    t,
                    d,
                    f,
                )?;
                Ok(vec![Runtime::literal_f32(&y, &[t as i64, d as i64])?])
            }
            Some(InterpKind::MoeBlockFwd) => {
                let (t, d, f, e) = (
                    info.get("tokens").as_u64().ok_or_else(|| err("manifest missing tokens"))?
                        as usize,
                    info.get("d_model").as_u64().ok_or_else(|| err("manifest missing d_model"))?
                        as usize,
                    info.get("d_ff").as_u64().ok_or_else(|| err("manifest missing d_ff"))?
                        as usize,
                    info.get("n_experts")
                        .as_u64()
                        .ok_or_else(|| err("manifest missing n_experts"))?
                        as usize,
                );
                let y = moe_block_fwd(
                    inputs[0].f32s()?,
                    inputs[1].f32s()?,
                    inputs[2].f32s()?,
                    inputs[3].f32s()?,
                    t,
                    d,
                    f,
                    e,
                )?;
                Ok(vec![Runtime::literal_f32(&y, &[t as i64, d as i64])?])
            }
            None => Err(err(format!(
                "artifact '{name}' is outside the built-in interpreter's scope \
                 (only the FFN-family inference artifacts run offline; \
                 train_step needs the PJRT-enabled build)"
            ))),
        }
    }

    /// Helper: f32 literal from a flat vec + dims.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        check_dims(data.len(), dims)?;
        Ok(Literal { dims: dims.to_vec(), data: LiteralData::F32(data.to_vec()) })
    }

    /// Helper: i32 literal from a flat vec + dims.
    pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        check_dims(data.len(), dims)?;
        Ok(Literal { dims: dims.to_vec(), data: LiteralData::I32(data.to_vec()) })
    }
}

enum InterpKind {
    ExpertFfn,
    MoeBlockFwd,
}

fn check_dims(len: usize, dims: &[i64]) -> Result<()> {
    let expect: i64 = dims.iter().product();
    if expect < 0 || expect as usize != len {
        return Err(err(format!("literal of {len} elements cannot reshape to {dims:?}")));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Reference kernels (the interpreter's math, mirroring compile/kernels/ref.py)
// ---------------------------------------------------------------------------

/// `jax.nn.gelu` default: the tanh approximation.
fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    let x3 = x * x * x;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x3)).tanh())
}

/// `c[m][n] = sum_k a[m][k] * b[k][n]` — row-major f32 GEMM.
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// `y = gelu(x @ w1) @ w2` over (t×d) tokens.
fn expert_ffn(x: &[f32], w1: &[f32], w2: &[f32], t: usize, d: usize, f: usize) -> Result<Vec<f32>> {
    if x.len() != t * d || w1.len() != d * f || w2.len() != f * d {
        return Err(err(format!(
            "expert_ffn shape mismatch: x {}, w1 {}, w2 {} for (t={t}, d={d}, f={f})",
            x.len(),
            w1.len(),
            w2.len()
        )));
    }
    let mut h = matmul(x, w1, t, d, f);
    for v in h.iter_mut() {
        *v = gelu(*v);
    }
    Ok(matmul(&h, w2, t, f, d))
}

/// Softmax gating + all experts + gate-weighted combine
/// (`model.moe_block_fwd`): x (t,d), wg (d,e), w1s (e,d,f), w2s (e,f,d).
#[allow(clippy::too_many_arguments)]
fn moe_block_fwd(
    x: &[f32],
    wg: &[f32],
    w1s: &[f32],
    w2s: &[f32],
    t: usize,
    d: usize,
    f: usize,
    e: usize,
) -> Result<Vec<f32>> {
    if x.len() != t * d || wg.len() != d * e || w1s.len() != e * d * f || w2s.len() != e * f * d {
        return Err(err("moe_block_fwd shape mismatch"));
    }
    // gates = softmax(x @ wg, axis=-1)
    let mut gates = matmul(x, wg, t, d, e);
    for row in gates.chunks_mut(e) {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    let mut out = vec![0.0f32; t * d];
    for k in 0..e {
        let y = expert_ffn(x, &w1s[k * d * f..(k + 1) * d * f], &w2s[k * f * d..(k + 1) * f * d], t, d, f)?;
        for ti in 0..t {
            let g = gates[ti * e + k];
            for di in 0..d {
                out[ti * d + di] += g * y[ti * d + di];
            }
        }
    }
    Ok(out)
}

/// Analytic H100 compute model for the Fig 8 timeline (the simulated
/// cluster's compute phase; the artifacts above are the *real* kernel
/// math). bf16 FFN on an H100 SXM: peak 989 TFLOP/s; we assume the
/// paper's stack sustains ~45% on these GEMM shapes.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    pub sustained_tflops: f64,
    pub kernel_launch_us: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        ComputeModel { sustained_tflops: 445.0, kernel_launch_us: 12.0 }
    }
}

impl ComputeModel {
    /// Time for one expert to run its two-layer FFN over `tokens`.
    pub fn expert_ffn_s(&self, tokens: f64, d_model: f64, d_ff: f64) -> f64 {
        let flops = 2.0 * 2.0 * tokens * d_model * d_ff; // 2 GEMMs × 2 flop/MAC
        flops / (self.sustained_tflops * 1e12) + self.kernel_launch_us * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    /// erf via Abramowitz–Stegun 7.1.26 (tests only).
    fn erf(x: f64) -> f64 {
        let t = 1.0 / (1.0 + 0.3275911 * x.abs());
        let y = 1.0
            - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736)
                * t
                + 0.254829592)
                * t
                * (-x * x).exp();
        if x >= 0.0 {
            y
        } else {
            -y
        }
    }

    fn exact_gelu(x: f64) -> f64 {
        0.5 * x * (1.0 + erf(x / std::f64::consts::SQRT_2))
    }

    #[test]
    fn compute_model_scales_linearly() {
        let m = ComputeModel::default();
        let t1 = m.expert_ffn_s(1024.0, 4096.0, 16384.0);
        let t2 = m.expert_ffn_s(2048.0, 4096.0, 16384.0);
        let flop_part1 = t1 - m.kernel_launch_us * 1e-6;
        let flop_part2 = t2 - m.kernel_launch_us * 1e-6;
        assert!((flop_part2 / flop_part1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gelu_matches_exact_form() {
        // tanh approximation tracks the erf definition to <1e-3 abs
        for x in [-3.0f32, -1.0, -0.1, 0.0, 0.5, 1.0, 2.56, 4.0] {
            let approx = gelu(x) as f64;
            let exact = exact_gelu(x as f64);
            assert!((approx - exact).abs() < 1e-3, "x={x}: {approx} vs {exact}");
        }
    }

    #[test]
    fn matmul_small_case() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let c = matmul(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn literal_roundtrip_and_type_safety() {
        let l = Runtime::literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(Runtime::literal_f32(&[1.0], &[2, 2]).is_err());
        let i = Runtime::literal_i32(&[7, 8], &[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    /// End-to-end interpreter check against the analytic constant-input
    /// value, via a synthetic manifest (no `make artifacts` needed).
    #[test]
    fn expert_ffn_interpreter_matches_analytic() {
        let dir = std::env::temp_dir().join(format!("nimble-rt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (t, d, f) = (4usize, 64usize, 128usize);
        let manifest = format!(
            r#"{{"version": 1, "artifacts": {{"expert_ffn_t{t}": {{
                "file": "expert_ffn_t{t}.hlo.txt",
                "inputs": [{{"shape": [{t}, {d}]}}, {{"shape": [{d}, {f}]}}, {{"shape": [{f}, {d}]}}],
                "outputs": [{{"shape": [{t}, {d}]}}],
                "tokens": {t}, "d_model": {d}, "d_ff": {f}}}}}}}"#
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let mut rt = Runtime::open(&dir).unwrap();
        assert!(rt.supports(&format!("expert_ffn_t{t}")));
        assert!(!rt.supports("train_step"));
        let x = vec![0.5f32; t * d];
        let w1 = vec![0.01f32; d * f];
        let w2 = vec![0.01f32; f * d];
        let out = rt
            .execute(
                &format!("expert_ffn_t{t}"),
                &[
                    Runtime::literal_f32(&x, &[t as i64, d as i64]).unwrap(),
                    Runtime::literal_f32(&w1, &[d as i64, f as i64]).unwrap(),
                    Runtime::literal_f32(&w2, &[f as i64, d as i64]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let y = out[0].to_vec::<f32>().unwrap();
        assert_eq!(y.len(), t * d);
        // constant inputs ⇒ every element equal and analytic:
        // h = 0.5·0.01·d; y = gelu(h)·0.01·f
        let h = 0.5 * 0.01 * d as f64;
        let expect = (exact_gelu(h) * 0.01 * f as f64) as f32;
        assert!((y[0] - y[t * d - 1]).abs() < 1e-5);
        assert!(
            (y[0] - expect).abs() / expect.abs() < 2e-2,
            "y={} expect={expect}",
            y[0]
        );
        // probes: transposed input (same element count) and wrong arity
        // must both be rejected, like the PJRT path would have
        let transposed = rt.execute(
            &format!("expert_ffn_t{t}"),
            &[
                Runtime::literal_f32(&x, &[d as i64, t as i64]).unwrap(),
                Runtime::literal_f32(&w1, &[d as i64, f as i64]).unwrap(),
                Runtime::literal_f32(&w2, &[f as i64, d as i64]).unwrap(),
            ],
        );
        assert!(transposed.is_err(), "transposed x must be rejected");
        assert!(rt.execute(&format!("expert_ffn_t{t}"), &[]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Full round-trip over the real artifacts when `make artifacts`
    /// has produced them (skips cleanly otherwise — `make test` orders
    /// it first).
    #[test]
    fn expert_ffn_artifact_executes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        let info = rt.artifact_info("expert_ffn_t256");
        let Some(d) = info.get("d_model").as_u64().map(|x| x as usize) else {
            eprintln!("skipping: expert_ffn_t256 not in manifest");
            return;
        };
        let f = info.get("d_ff").as_u64().unwrap() as usize;
        let t = 256usize;
        let x = vec![0.5f32; t * d];
        let w1 = vec![0.01f32; d * f];
        let w2 = vec![0.01f32; f * d];
        let out = rt
            .execute(
                "expert_ffn_t256",
                &[
                    Runtime::literal_f32(&x, &[t as i64, d as i64]).unwrap(),
                    Runtime::literal_f32(&w1, &[d as i64, f as i64]).unwrap(),
                    Runtime::literal_f32(&w2, &[f as i64, d as i64]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let y = out[0].to_vec::<f32>().unwrap();
        assert_eq!(y.len(), t * d);
        let h = 0.5 * 0.01 * d as f64;
        let expect = (exact_gelu(h) * 0.01 * f as f64) as f32;
        assert!(y[0].is_finite());
        assert!((y[0] - y[t * d - 1]).abs() < 1e-3);
        assert!(
            (y[0] - expect).abs() / expect.abs() < 2e-2,
            "y={} expect={expect}",
            y[0]
        );
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        assert!(rt.execute("nonexistent", &[]).is_err());
    }
}
