//! Tiny declarative CLI flag parser (no `clap` in the offline vendor
//! set). Supports `--flag value`, `--flag=value`, boolean `--flag`,
//! positional arguments, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_bool: bool,
}

/// Declarative arg parser: register flags, then `parse`.
#[derive(Default)]
pub struct Args {
    specs: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
    prog: String,
    about: &'static str,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    pub fn new(prog: &str, about: &'static str) -> Args {
        Args { prog: prog.to_string(), about, ..Default::default() }
    }

    pub fn flag(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: Some(default), is_bool: false });
        self
    }

    pub fn flag_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: None, is_bool: false });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: Some("false"), is_bool: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.prog, self.about);
        let _ = writeln!(s, "\nflags:");
        for f in &self.specs {
            let d = match f.default {
                Some(d) if !f.is_bool => format!(" (default: {d})"),
                _ => String::new(),
            };
            let _ = writeln!(s, "  --{:<22} {}{}", f.name, f.help, d);
        }
        s
    }

    /// Parse a raw arg list (excluding argv[0]).
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed, CliError> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError(format!("unknown flag --{name}\n\n{}", self.usage())))?
                    .clone();
                let value = if spec.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| CliError(format!("flag --{name} needs a value")))?
                };
                self.values.insert(name, value);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // fill defaults, check required
        for s in &self.specs {
            if !self.values.contains_key(s.name) {
                match s.default {
                    Some(d) => {
                        self.values.insert(s.name.to_string(), d.to_string());
                    }
                    None => return Err(CliError(format!("missing required flag --{}", s.name))),
                }
            }
        }
        Ok(Parsed { values: self.values, positionals: self.positionals })
    }
}

#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not registered"))
    }
    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be a number"))
    }
    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }
    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }
    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes" | "on")
    }
    /// Comma-separated list of numbers, e.g. `--sizes 16,32,64`.
    pub fn get_list_f64(&self, name: &str) -> Vec<f64> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad number '{s}'")))
            .collect()
    }
    pub fn get_list_usize(&self, name: &str) -> Vec<usize> {
        self.get_list_f64(name).into_iter().map(|x| x as usize).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let p = Args::new("t", "test")
            .flag("size", "8", "message size")
            .switch("verbose", "chatty")
            .parse(&argv(&["--size", "64", "pos1"]))
            .unwrap();
        assert_eq!(p.get_usize("size"), 64);
        assert!(!p.get_bool("verbose"));
        assert_eq!(p.positionals, vec!["pos1"]);
    }

    #[test]
    fn equals_form_and_switch() {
        let p = Args::new("t", "test")
            .flag("ratio", "0.5", "hotspot")
            .switch("fast", "go fast")
            .parse(&argv(&["--ratio=0.9", "--fast"]))
            .unwrap();
        assert_eq!(p.get_f64("ratio"), 0.9);
        assert!(p.get_bool("fast"));
    }

    #[test]
    fn required_flag_missing() {
        let e = Args::new("t", "test").flag_req("model", "path").parse(&argv(&[]));
        assert!(e.is_err());
    }

    #[test]
    fn unknown_flag_rejected() {
        let e = Args::new("t", "test").parse(&argv(&["--nope", "1"]));
        assert!(e.is_err());
    }

    #[test]
    fn list_parsing() {
        let p = Args::new("t", "test")
            .flag("sizes", "1,2,3", "sizes")
            .parse(&argv(&["--sizes", "16, 32,64"]))
            .unwrap();
        assert_eq!(p.get_list_usize("sizes"), vec![16, 32, 64]);
    }
}
