//! Event-queue substrates for discrete-event simulation (DESIGN.md §9).
//!
//! The packet engine keys every event on `(time_ns, seq)` — `seq` is a
//! monotone insertion counter, so the key is total and ties never
//! consult unordered state. [`EventQueue`] abstracts the container
//! behind that contract with two implementations:
//!
//! * [`HeapQueue`] — a plain binary heap, `O(log n)` per operation.
//!   This is the **equivalence oracle**: it reproduces the original
//!   `BinaryHeap<Reverse<(t, seq, ev)>>` pop order exactly (the key is
//!   total, so the payload never decides order).
//! * [`WheelQueue`] — a calendar queue / hierarchical timing wheel:
//!   near-future events land in `O(1)` ring buckets (one small keyed
//!   heap for the bucket under the cursor), far-future events overflow
//!   into a `BTreeMap` until their bucket rotates into the horizon.
//!   Amortized `O(1)` per event for the DES access pattern (inserts
//!   cluster just ahead of the cursor), and the per-bucket heaps stay
//!   cache-resident where one global heap of 10⁴–10⁵ pending events
//!   does not.
//!
//! Both pop in strictly ascending `(time, seq)` order — asserted
//! against each other by the randomized tests below and by the
//! wheel-vs-heap properties in `tests/fabric_props.rs` — which is what
//! lets the packet engine swap them without changing a single event
//! trace.
//!
//! Bucket vectors are drained, never dropped, so their capacity is
//! reused across rotations: after warm-up the wheel performs **no
//! per-event allocation** (the arena property the packet engine's
//! determinism contract lists).

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

/// The scheduler contract: push events keyed `(time, seq)` with a
/// strictly increasing `seq`, pop them back in ascending key order.
/// `peek_key` takes `&mut self` because the wheel advances its cursor
/// lazily while locating the front.
pub trait EventQueue<T> {
    fn push(&mut self, t: u64, seq: u64, ev: T);
    fn pop(&mut self) -> Option<(u64, u64, T)>;
    fn peek_key(&mut self) -> Option<(u64, u64)>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Min-heap over `(t, seq)` with an opaque payload. Hand-rolled so the
/// payload needs no `Ord` bound and comparisons touch only the 16-byte
/// key (the derived `Ord` on an event enum is pure overhead: `seq` is
/// unique, so the payload can never decide an ordering).
#[derive(Clone, Debug)]
pub struct KeyedHeap<T> {
    items: Vec<(u64, u64, T)>,
}

impl<T> Default for KeyedHeap<T> {
    fn default() -> Self {
        KeyedHeap { items: Vec::new() }
    }
}

impl<T> KeyedHeap<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn peek_key(&self) -> Option<(u64, u64)> {
        self.items.first().map(|&(t, s, _)| (t, s))
    }

    pub fn push(&mut self, t: u64, seq: u64, ev: T) {
        self.items.push((t, seq, ev));
        self.sift_up(self.items.len() - 1);
    }

    pub fn pop(&mut self) -> Option<(u64, u64, T)> {
        let n = self.items.len();
        if n == 0 {
            return None;
        }
        self.items.swap(0, n - 1);
        let out = self.items.pop();
        if !self.items.is_empty() {
            self.sift_down(0);
        }
        out
    }

    #[inline]
    fn key(&self, i: usize) -> (u64, u64) {
        let (t, s, _) = self.items[i];
        (t, s)
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if self.key(i) < self.key(p) {
                self.items.swap(i, p);
                i = p;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.items.len();
        loop {
            let l = 2 * i + 1;
            if l >= n {
                break;
            }
            let r = l + 1;
            let c = if r < n && self.key(r) < self.key(l) { r } else { l };
            if self.key(c) < self.key(i) {
                self.items.swap(i, c);
                i = c;
            } else {
                break;
            }
        }
    }
}

/// The oracle scheduler: the original `BinaryHeap<Reverse<(t, seq, ev)>>`
/// the packet engine shipped with, retained verbatim behind the trait.
/// The payload's `Ord` bound is inert — `seq` is unique, so the key
/// always decides before the payload is ever compared.
#[derive(Clone, Debug, Default)]
pub struct HeapQueue<T: Ord> {
    heap: BinaryHeap<Reverse<(u64, u64, T)>>,
}

impl<T: Ord> HeapQueue<T> {
    pub fn new() -> Self {
        HeapQueue { heap: BinaryHeap::new() }
    }
}

impl<T: Ord> EventQueue<T> for HeapQueue<T> {
    fn push(&mut self, t: u64, seq: u64, ev: T) {
        self.heap.push(Reverse((t, seq, ev)));
    }

    fn pop(&mut self) -> Option<(u64, u64, T)> {
        self.heap.pop().map(|Reverse((t, s, ev))| (t, s, ev))
    }

    fn peek_key(&mut self) -> Option<(u64, u64)> {
        self.heap.peek().map(|Reverse((t, s, _))| (*t, *s))
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Ring size (buckets) and bucket width (2^BITS ns). 4096 × 1.024 µs
/// ≈ 4.2 ms of horizon — comfortably past the per-hop latencies and
/// service times the packet engine schedules ahead; replan-epoch wakes
/// beyond it take the overflow path once and rotate in.
const BUCKET_BITS: u32 = 10;
const N_BUCKETS: usize = 4096;

/// Calendar-queue scheduler (see the module docs). Events are stored
/// by value in ring buckets; the bucket under the cursor is held as a
/// small [`KeyedHeap`] so same-bucket inserts keep exact `(t, seq)`
/// order. Requires the DES invariant `t ≥ last popped time` on push
/// (events are never scheduled into the past); stragglers at or before
/// the cursor's bucket go straight into the front heap, which keeps
/// them correctly ordered regardless.
#[derive(Clone, Debug)]
pub struct WheelQueue<T> {
    /// Ring of unsorted future buckets; absolute bucket `b` lives at
    /// slot `b & (N_BUCKETS-1)` while `cursor < b < cursor + N_BUCKETS`.
    buckets: Vec<Vec<(u64, u64, T)>>,
    /// Sorted front: every event with absolute bucket ≤ `cursor`.
    front: KeyedHeap<T>,
    /// Absolute bucket index (`t >> BUCKET_BITS`) the front covers.
    cursor: u64,
    /// Events in `buckets` (not front, not overflow).
    in_buckets: usize,
    /// Beyond-horizon events, keyed `(t, seq)` (unique, total order).
    overflow: BTreeMap<(u64, u64), T>,
    len: usize,
}

impl<T> Default for WheelQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> WheelQueue<T> {
    pub fn new() -> Self {
        WheelQueue {
            buckets: (0..N_BUCKETS).map(|_| Vec::new()).collect(),
            front: KeyedHeap::new(),
            cursor: 0,
            in_buckets: 0,
            overflow: BTreeMap::new(),
            len: 0,
        }
    }

    #[inline]
    fn slot(b: u64) -> usize {
        (b as usize) & (N_BUCKETS - 1)
    }

    /// Pull every overflow event of absolute bucket `b` into the ring.
    fn admit_overflow_bucket(&mut self, b: u64) {
        let lo = (b << BUCKET_BITS, 0u64);
        let hi = ((b + 1) << BUCKET_BITS, 0u64);
        // split_off twice: [lo, hi) leaves the map, rest comes back
        let mut tail = self.overflow.split_off(&lo);
        let rest = tail.split_off(&hi);
        self.overflow.extend(rest);
        for ((t, seq), ev) in tail {
            self.buckets[Self::slot(b)].push((t, seq, ev));
            self.in_buckets += 1;
        }
    }

    /// Advance the cursor until the front heap holds the next event
    /// (or the queue is empty).
    fn ensure_front(&mut self) {
        while self.front.is_empty() && self.len > 0 {
            if self.in_buckets == 0 {
                // nothing inside the horizon: jump straight to the
                // first overflow bucket and re-expose the window
                let &(t, _) = self.overflow.keys().next().expect("len>0");
                self.cursor = t >> BUCKET_BITS;
                let last = self.cursor + (N_BUCKETS as u64) - 1;
                let lo = (self.cursor << BUCKET_BITS, 0u64);
                let hi = ((last + 1) << BUCKET_BITS, 0u64);
                let mut tail = self.overflow.split_off(&lo);
                let rest = tail.split_off(&hi);
                self.overflow.extend(rest);
                for ((te, seq), ev) in tail {
                    let b = te >> BUCKET_BITS;
                    if b <= self.cursor {
                        self.front.push(te, seq, ev);
                    } else {
                        self.buckets[Self::slot(b)].push((te, seq, ev));
                        self.in_buckets += 1;
                    }
                }
            } else {
                self.cursor += 1;
                // one more bucket rotated into the horizon
                self.admit_overflow_bucket(self.cursor + (N_BUCKETS as u64) - 1);
                let slot = Self::slot(self.cursor);
                if !self.buckets[slot].is_empty() {
                    // drain, keep capacity: no per-event allocation
                    // once the ring is warm
                    let mut drained = std::mem::take(&mut self.buckets[slot]);
                    self.in_buckets -= drained.len();
                    for (t, seq, ev) in drained.drain(..) {
                        self.front.push(t, seq, ev);
                    }
                    self.buckets[slot] = drained;
                }
            }
        }
    }
}

impl<T> EventQueue<T> for WheelQueue<T> {
    fn push(&mut self, t: u64, seq: u64, ev: T) {
        self.len += 1;
        let b = t >> BUCKET_BITS;
        if b <= self.cursor {
            self.front.push(t, seq, ev);
        } else if b < self.cursor + N_BUCKETS as u64 {
            self.buckets[Self::slot(b)].push((t, seq, ev));
            self.in_buckets += 1;
        } else {
            self.overflow.insert((t, seq), ev);
        }
    }

    fn pop(&mut self) -> Option<(u64, u64, T)> {
        self.ensure_front();
        let out = self.front.pop();
        if out.is_some() {
            self.len -= 1;
        }
        out
    }

    fn peek_key(&mut self) -> Option<(u64, u64)> {
        self.ensure_front();
        self.front.peek_key()
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn drain<T, Q: EventQueue<T>>(q: &mut Q) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        while let Some((t, s, _)) = q.pop() {
            out.push((t, s));
        }
        out
    }

    #[test]
    fn keyed_heap_sorts_and_breaks_ties_on_seq() {
        let mut h = KeyedHeap::new();
        for (t, s) in [(5u64, 3u64), (5, 1), (1, 2), (9, 4), (1, 5)] {
            h.push(t, s, ());
        }
        let mut got = Vec::new();
        while let Some((t, s, ())) = h.pop() {
            got.push((t, s));
        }
        assert_eq!(got, vec![(1, 2), (1, 5), (5, 1), (5, 3), (9, 4)]);
    }

    /// Static fill: wheel pops the identical sequence the heap does,
    /// including same-time ties and far-overflow events.
    #[test]
    fn wheel_matches_heap_static() {
        let mut rng = Rng::new(0xE001);
        let mut heap = HeapQueue::new();
        let mut wheel = WheelQueue::new();
        for seq in 0..20_000u64 {
            // cluster most events near the origin, sprinkle far ones
            // beyond the 4.2 ms horizon, and force heavy time ties
            let t = match rng.below(10) {
                0..=6 => rng.below(2_000_000),
                7 | 8 => rng.below(50_000) * 40, // tie-heavy lattice
                _ => 5_000_000 + rng.below(1 << 33),
            };
            heap.push(t, seq, seq);
            wheel.push(t, seq, seq);
        }
        assert_eq!(drain(&mut heap), drain(&mut wheel));
    }

    /// Interleaved DES pattern: pops interleave with pushes that are
    /// never earlier than the last popped time (the packet engine's
    /// invariant), often landing exactly at the current time or in the
    /// cursor's own bucket.
    #[test]
    fn wheel_matches_heap_interleaved() {
        let mut rng_h = Rng::new(0xE002);
        let mut rng_w = Rng::new(0xE002);
        let run = |rng: &mut Rng, q: &mut dyn EventQueue<u64>| -> Vec<(u64, u64)> {
            let mut seq = 0u64;
            let mut schedule = |q: &mut dyn EventQueue<u64>, t: u64, s: &mut u64| {
                *s += 1;
                q.push(t, *s, *s);
            };
            for _ in 0..64 {
                schedule(q, rng.below(3_000), &mut seq);
            }
            let mut now = 0u64;
            let mut order = Vec::new();
            while let Some((t, s, _)) = q.pop() {
                assert!(t >= now, "time went backwards");
                now = t;
                order.push((t, s));
                if order.len() > 60_000 {
                    break;
                }
                // each event schedules 0..3 children at now + jitter,
                // mimicking service chains, same-time kicks and
                // occasional far wakes
                for _ in 0..rng.below(3) {
                    let dt = match rng.below(8) {
                        0 => 0,
                        1..=5 => rng.below(6_000),
                        6 => rng.below(300_000),
                        _ => 4_500_000 + rng.below(20_000_000),
                    };
                    if seq < 50_000 {
                        schedule(q, now + dt, &mut seq);
                    }
                }
            }
            order
        };
        let mut heap = HeapQueue::new();
        let mut wheel = WheelQueue::new();
        let a = run(&mut rng_h, &mut heap);
        let b = run(&mut rng_w, &mut wheel);
        assert_eq!(a.len(), b.len());
        assert_eq!(a, b, "wheel diverged from heap oracle");
    }

    /// peek_key never disagrees with the subsequent pop.
    #[test]
    fn peek_matches_pop() {
        let mut rng = Rng::new(0xE003);
        let mut wheel = WheelQueue::new();
        for seq in 0..5_000u64 {
            wheel.push(rng.below(10_000_000), seq, ());
        }
        while let Some(k) = wheel.peek_key() {
            let (t, s, ()) = wheel.pop().expect("peeked");
            assert_eq!(k, (t, s));
        }
        assert_eq!(wheel.len(), 0);
    }

    /// Long idle gaps: the cursor jump over an empty horizon lands on
    /// the overflow events in order.
    #[test]
    fn wheel_handles_sparse_far_future() {
        let mut wheel = WheelQueue::new();
        let mut heap = HeapQueue::new();
        let times = [1u64, 10_000_000, 10_000_001, 800_000_000, 3_000_000_000];
        for (seq, &t) in times.iter().enumerate() {
            wheel.push(t, seq as u64, seq);
            heap.push(t, seq as u64, seq);
        }
        assert_eq!(drain(&mut wheel), drain(&mut heap));
    }
}
