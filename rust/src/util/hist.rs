//! Deterministic log-bucketed streaming latency histogram
//! (DESIGN.md §16): the memory-bounded replacement for the packet
//! backend's per-chunk sojourn/transit sample vectors.
//!
//! Bucket boundaries are **fixed integers in nanoseconds**, independent
//! of the data: values below 2^[`MANTISSA_BITS`] get one bucket per
//! nanosecond, and every octave above is split into
//! 2^[`MANTISSA_BITS`] equal sub-buckets (the HdrHistogram layout). A
//! bucket's relative width is therefore at most 2^-[`MANTISSA_BITS`]
//! ≈ 3.2% — the error bound on any histogram-derived quantile.
//! Because the boundaries are fixed, two histograms merge by exact
//! u64 bucket-count addition: merging is associative, commutative and
//! bit-deterministic, which is what the partitioned packet engine's
//! canonical component merge needs.
//!
//! Quantiles are nearest-rank over the bucket counts and return the
//! **lower boundary** of the bucket holding the nearest-rank sample —
//! a deterministic representative within one bucket width of the exact
//! nearest-rank value (the oracle contract pinned in
//! `tests/telemetry_props.rs`). The exact maximum is tracked
//! separately so `max` headlines stay exact.

/// Sub-bucket resolution: 2^5 = 32 sub-buckets per octave.
pub const MANTISSA_BITS: u32 = 5;

/// Exclusive upper bound on bucket indices for u64 values.
pub const MAX_BUCKETS: usize = ((64 - MANTISSA_BITS as usize) + 1) << MANTISSA_BITS;

/// A streaming latency histogram over integer nanoseconds. Buckets
/// are allocated lazily up to the highest observed index; untouched
/// tails count as zero, so equality and merging see one canonical
/// representation (trailing zero buckets are never stored).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LatencyHist {
    counts: Vec<u64>,
    total: u64,
    /// Exact maximum observed (ns) — kept so the `max` headline does
    /// not quantize.
    max_ns: u64,
}

/// Bucket index of a nanosecond value (fixed, data-independent).
pub fn bucket_of(ns: u64) -> usize {
    let m = MANTISSA_BITS;
    if ns < (1u64 << m) {
        return ns as usize;
    }
    let e = 63 - ns.leading_zeros();
    let sub = ((ns >> (e - m)) & ((1u64 << m) - 1)) as usize;
    (((e - m + 1) as usize) << m) + sub
}

/// `[lower, upper)` boundaries of bucket `idx`, in nanoseconds.
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    let m = MANTISSA_BITS as usize;
    if idx < (1usize << m) {
        return (idx as u64, idx as u64 + 1);
    }
    let g = (idx >> m) as u32; // octave group, >= 1
    let sub = (idx & ((1 << m) - 1)) as u64;
    let lower = ((1u64 << m) + sub) << (g - 1);
    let width = 1u64 << (g - 1);
    (lower, lower.saturating_add(width))
}

/// Width of the bucket containing `ns` — the quantile error bound at
/// that magnitude.
pub fn bucket_width_ns(ns: u64) -> u64 {
    let (lo, hi) = bucket_bounds(bucket_of(ns));
    hi - lo
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist::default()
    }

    /// Record one observation in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        let idx = bucket_of(ns);
        if idx >= self.counts.len() {
            self.counts.resize(idx + 1, 0);
        }
        self.counts[idx] += 1;
        self.total += 1;
        self.max_ns = self.max_ns.max(ns);
    }

    /// Record one observation in seconds (rounded to integer ns — the
    /// packet engine's native clock, so the conversion is exact there).
    pub fn record_s(&mut self, s: f64) {
        self.record_ns((s * 1e9).round().max(0.0) as u64);
    }

    /// Exact merge: bucket-wise u64 addition (order-independent).
    pub fn merge(&mut self, other: &LatencyHist) {
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (a, &b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Exact maximum observed, in nanoseconds (0 when empty).
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Nearest-rank quantile over the bucket counts (`q` in [0,100]):
    /// the lower boundary of the bucket holding the rank-
    /// `ceil(q/100·n)` sample. Within one bucket width of the exact
    /// nearest-rank value. 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                return bucket_bounds(i).0;
            }
        }
        bucket_bounds(self.counts.len().saturating_sub(1)).0
    }

    /// [`LatencyHist::quantile_ns`] in seconds.
    pub fn quantile_s(&self, q: f64) -> f64 {
        self.quantile_ns(q) as f64 * 1e-9
    }

    /// Non-empty buckets as `(index, count)` pairs, ascending — the
    /// sparse form the `histogram` trace record serializes.
    pub fn nonzero(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect()
    }

    /// Rebuild from the sparse form (trace round-trip). `max_ns` must
    /// be supplied — the sparse form only bounds it to a bucket.
    pub fn from_sparse(pairs: &[(usize, u64)], max_ns: u64) -> Self {
        let mut h = LatencyHist::new();
        for &(i, c) in pairs {
            if i >= h.counts.len() {
                h.counts.resize(i + 1, 0);
            }
            h.counts[i] += c;
            h.total += c;
        }
        h.max_ns = max_ns;
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile_nearest_rank;

    /// The bucket map is continuous, monotone, and bounded by
    /// [`MAX_BUCKETS`]; bounds invert the map exactly.
    #[test]
    fn buckets_are_continuous_and_invertible() {
        let mut prev = None;
        for ns in 0u64..5000 {
            let i = bucket_of(ns);
            if let Some(p) = prev {
                assert!(i == p || i == p + 1, "gap at {ns}: {p} -> {i}");
            }
            prev = Some(i);
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= ns && ns < hi, "{ns} outside [{lo},{hi})");
        }
        for &ns in &[1u64 << 20, (1 << 40) + 12345, u64::MAX] {
            let (lo, hi) = bucket_bounds(bucket_of(ns));
            assert!(lo <= ns && (ns < hi || hi <= lo), "{ns} outside [{lo},{hi})");
            assert!(bucket_of(ns) < MAX_BUCKETS);
        }
    }

    /// Relative bucket width stays under 2^-MANTISSA_BITS.
    #[test]
    fn relative_width_bound() {
        for &ns in &[100u64, 1_000, 33_333, 1_000_000, 123_456_789] {
            let w = bucket_width_ns(ns);
            let (lo, _) = bucket_bounds(bucket_of(ns));
            assert!(
                (w as f64) <= (lo.max(1) as f64) / 32.0 + 1.0,
                "bucket at {ns} too wide: {w} vs lower {lo}"
            );
        }
    }

    /// Histogram quantiles land within one bucket width of the exact
    /// nearest-rank value, at every rank, on an adversarial sample.
    #[test]
    fn quantiles_match_oracle_within_one_bucket() {
        let samples: Vec<u64> =
            (0..5000u64).map(|i| (i * 7919) % 2_000_000 + 3).collect();
        let mut h = LatencyHist::new();
        for &s in &samples {
            h.record_ns(s);
        }
        let exact_s: Vec<f64> = samples.iter().map(|&x| x as f64 * 1e-9).collect();
        for q in [1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let exact_ns = (percentile_nearest_rank(&exact_s, q) * 1e9).round() as u64;
            let got = h.quantile_ns(q);
            // the histogram returns the lower bound of exactly the
            // bucket holding the nearest-rank sample
            assert_eq!(
                got,
                bucket_bounds(bucket_of(exact_ns)).0,
                "p{q}: {got} vs exact {exact_ns}"
            );
            assert!(got <= exact_ns && exact_ns - got <= bucket_width_ns(exact_ns));
        }
        assert_eq!(h.max_ns(), *samples.iter().max().unwrap());
        assert_eq!(h.total(), samples.len() as u64);
    }

    /// Merging partitions is exact: any split of the sample stream
    /// merges back to the bit-identical histogram.
    #[test]
    fn merge_is_exact_and_order_independent() {
        let samples: Vec<u64> = (0..999u64).map(|i| (i * 104_729) % 10_000_000).collect();
        let mut whole = LatencyHist::new();
        for &s in &samples {
            whole.record_ns(s);
        }
        for split in [1usize, 3, 7] {
            let mut parts: Vec<LatencyHist> = vec![LatencyHist::new(); split];
            for (i, &s) in samples.iter().enumerate() {
                parts[i % split].record_ns(s);
            }
            // merge in reverse order: still identical
            let mut merged = LatencyHist::new();
            for p in parts.iter().rev() {
                merged.merge(p);
            }
            assert_eq!(merged, whole, "split {split} diverged");
        }
    }

    /// Sparse serialization round-trips bit-exactly.
    #[test]
    fn sparse_roundtrip() {
        let mut h = LatencyHist::new();
        for ns in [0u64, 5, 31, 32, 1000, 3_000_000, 3_000_100] {
            h.record_ns(ns);
        }
        let back = LatencyHist::from_sparse(&h.nonzero(), h.max_ns());
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.quantile_ns(q), back.quantile_ns(q));
        }
        assert_eq!(h.total(), back.total());
        assert_eq!(h.max_ns(), back.max_ns());
    }

    #[test]
    fn empty_hist_is_inert() {
        let h = LatencyHist::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile_ns(99.0), 0);
        assert_eq!(h.max_ns(), 0);
        assert!(h.nonzero().is_empty());
    }
}
