//! Minimal TOML-subset parser (no `toml` crate in the offline vendor
//! set). Supports what the config system needs: `[section]` tables,
//! `key = value` with string / integer / float / boolean values, `#`
//! comments, and bare or quoted keys. Arrays and nested tables are
//! intentionally out of scope — configs here are flat two-level.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(x) if *x >= 0 => Some(*x as usize),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// section → key → value; top-level keys live under the "" section.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(ln, "unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err(ln, "empty section name"));
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| err(ln, "expected `key = value`"))?;
            let key = key.trim().trim_matches('"').to_string();
            if key.is_empty() {
                return Err(err(ln, "empty key"));
            }
            let value = parse_value(value.trim()).map_err(|m| err(ln, &m))?;
            doc.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.as_f64()
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get(section, key)?.as_usize()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key)?.as_bool()
    }
}

fn err(ln: usize, msg: &str) -> TomlError {
    TomlError { line: ln + 1, msg: msg.to_string() }
}

/// Remove a trailing comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue, String> {
    if v.is_empty() {
        return Err("missing value".into());
    }
    if let Some(body) = v.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(body.to_string()));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{v}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            # cluster shape
            name = "paper"
            [topology]
            nodes = 2
            gpus_per_node = 4   # comment after value
            nvlink_gbps = 120.0
            nvswitch = false
            [planner]
            lambda = 0.25
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("paper"));
        assert_eq!(doc.get_usize("topology", "nodes"), Some(2));
        assert_eq!(doc.get_f64("topology", "nvlink_gbps"), Some(120.0));
        assert_eq!(doc.get_bool("topology", "nvswitch"), Some(false));
        assert_eq!(doc.get_f64("planner", "lambda"), Some(0.25));
    }

    #[test]
    fn int_promotes_to_f64() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get_f64("", "x"), Some(3.0));
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("bytes = 1_048_576").unwrap();
        assert_eq!(doc.get_usize("", "bytes"), Some(1 << 20));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse(r##"tag = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.get("", "tag").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("k = \n").is_err());
    }
}
