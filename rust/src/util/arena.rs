//! Index-based slab arena with generation-checked handles.
//!
//! The partitioned packet engine (`fabric/packet_par`) keeps its
//! component sub-simulations in a [`Slab`]: partition merges retire
//! slots (the absorbed sub-sim's state is transplanted into the
//! survivor) and later `add_flows` epochs allocate new ones, so a
//! plain `Vec` index would silently dangle. A [`Handle`] carries the
//! slot's generation; any access through a stale handle — a partition
//! that was merged away, a flow ticket outliving a preempt — reports
//! `None` instead of aliasing whatever reused the slot.
//!
//! Slots are recycled through an intrusive free list, so steady-state
//! insert/remove does no allocation — the same arena discipline the
//! event wheel applies to its buckets (DESIGN.md §9).

/// Generation-checked reference to a [`Slab`] slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Handle {
    idx: u32,
    gen: u32,
}

impl Handle {
    /// Raw slot index — stable for the lifetime of the referent, only
    /// meaningful alongside a generation check.
    pub fn index(&self) -> usize {
        self.idx as usize
    }
}

enum Slot<T> {
    Occupied { gen: u32, value: T },
    /// Free slot: remembers the generation to issue next and the next
    /// free slot in the intrusive list (`u32::MAX` = end).
    Vacant { gen: u32, next_free: u32 },
}

/// Slab allocator: `O(1)` insert/remove/get, dense `u32` indices,
/// generation-checked handles.
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free_head: u32,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab { slots: Vec::new(), free_head: u32::MAX, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn insert(&mut self, value: T) -> Handle {
        self.len += 1;
        if self.free_head != u32::MAX {
            let idx = self.free_head;
            let (gen, next_free) = match self.slots[idx as usize] {
                Slot::Vacant { gen, next_free } => (gen, next_free),
                Slot::Occupied { .. } => unreachable!("free list points at occupied slot"),
            };
            self.free_head = next_free;
            self.slots[idx as usize] = Slot::Occupied { gen, value };
            Handle { idx, gen }
        } else {
            let idx = self.slots.len() as u32;
            assert!(idx != u32::MAX, "slab full");
            self.slots.push(Slot::Occupied { gen: 0, value });
            Handle { idx, gen: 0 }
        }
    }

    /// Remove the referent; `None` if the handle is stale or vacant.
    pub fn remove(&mut self, h: Handle) -> Option<T> {
        let slot = self.slots.get_mut(h.idx as usize)?;
        match slot {
            Slot::Occupied { gen, .. } if *gen == h.gen => {
                // bump the generation so every outstanding handle to
                // this slot goes stale the moment it's vacated
                let next = Slot::Vacant { gen: h.gen.wrapping_add(1), next_free: self.free_head };
                let old = std::mem::replace(slot, next);
                self.free_head = h.idx;
                self.len -= 1;
                match old {
                    Slot::Occupied { value, .. } => Some(value),
                    Slot::Vacant { .. } => unreachable!(),
                }
            }
            _ => None,
        }
    }

    pub fn get(&self, h: Handle) -> Option<&T> {
        match self.slots.get(h.idx as usize) {
            Some(Slot::Occupied { gen, value }) if *gen == h.gen => Some(value),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, h: Handle) -> Option<&mut T> {
        match self.slots.get_mut(h.idx as usize) {
            Some(Slot::Occupied { gen, value }) if *gen == h.gen => Some(value),
            _ => None,
        }
    }

    pub fn contains(&self, h: Handle) -> bool {
        self.get(h).is_some()
    }

    /// Iterate live entries in slot order (deterministic: slot order
    /// is insertion order modulo free-list reuse, never hash order).
    pub fn iter(&self) -> impl Iterator<Item = (Handle, &T)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| match s {
            Slot::Occupied { gen, value } => {
                Some((Handle { idx: i as u32, gen: *gen }, value))
            }
            Slot::Vacant { .. } => None,
        })
    }

    /// Iterate live entries mutably in slot order — the disjoint
    /// `&mut` borrows the partitioned event loop hands its worker
    /// threads.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = (Handle, &mut T)> {
        self.slots.iter_mut().enumerate().filter_map(|(i, s)| match s {
            Slot::Occupied { gen, value } => {
                Some((Handle { idx: i as u32, gen: *gen }, value))
            }
            Slot::Vacant { .. } => None,
        })
    }

    /// Handles of live entries in slot order.
    pub fn handles(&self) -> Vec<Handle> {
        self.iter().map(|(h, _)| h).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.get(b), Some(&"b"));
        assert_eq!(s.remove(a), Some("a"));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(a), None);
        assert_eq!(s.get(b), Some(&"b"));
    }

    #[test]
    fn stale_handle_is_rejected_after_reuse() {
        let mut s = Slab::new();
        let a = s.insert(1u32);
        s.remove(a);
        let b = s.insert(2u32);
        // slot reused, generation bumped
        assert_eq!(b.index(), a.index());
        assert_ne!(a, b);
        assert_eq!(s.get(a), None);
        assert!(!s.contains(a));
        assert_eq!(s.remove(a), None);
        assert_eq!(s.get(b), Some(&2));
    }

    #[test]
    fn double_remove_is_none() {
        let mut s = Slab::new();
        let a = s.insert(9u8);
        assert_eq!(s.remove(a), Some(9));
        assert_eq!(s.remove(a), None);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn free_list_recycles_lifo_without_growth() {
        let mut s = Slab::new();
        let hs: Vec<_> = (0..8).map(|i| s.insert(i)).collect();
        for &h in &hs {
            s.remove(h);
        }
        // re-fill: all 8 slots recycled (LIFO), vec does not grow
        let hs2: Vec<_> = (0..8).map(|i| s.insert(i + 100)).collect();
        assert_eq!(s.slots.len(), 8);
        let mut idxs: Vec<_> = hs2.iter().map(|h| h.index()).collect();
        idxs.sort_unstable();
        assert_eq!(idxs, (0..8).collect::<Vec<_>>());
        for (i, &h) in hs2.iter().enumerate() {
            assert_eq!(s.get(h), Some(&(i + 100)));
        }
    }

    #[test]
    fn iter_is_slot_ordered() {
        let mut s = Slab::new();
        let a = s.insert("a");
        let _b = s.insert("b");
        let _c = s.insert("c");
        s.remove(a);
        let vals: Vec<_> = s.iter().map(|(_, v)| *v).collect();
        assert_eq!(vals, vec!["b", "c"]);
        assert_eq!(s.handles().len(), 2);
    }

    #[test]
    fn get_mut_mutates_through_handle() {
        let mut s = Slab::new();
        let a = s.insert(vec![1, 2]);
        s.get_mut(a).unwrap().push(3);
        assert_eq!(s.get(a), Some(&vec![1, 2, 3]));
    }
}
