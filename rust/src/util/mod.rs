//! Self-built substrates for crates unavailable in the offline vendor
//! set (see DESIGN.md §2): PRNG, JSON, CLI parsing, statistics, a
//! mini property-testing harness, and the event-engine substrates
//! (calendar-queue scheduler, slab arena).

pub mod arena;
pub mod bench;
pub mod cli;
pub mod eventq;
pub mod hist;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod stats;
pub mod toml;

/// Byte-size pretty printer used across reports.
pub fn fmt_bytes(b: u64) -> String {
    const KB: u64 = 1 << 10;
    const MB: u64 = 1 << 20;
    const GB: u64 = 1 << 30;
    if b >= GB && b % GB == 0 {
        format!("{} GB", b / GB)
    } else if b >= MB && b % MB == 0 {
        format!("{} MB", b / MB)
    } else if b >= KB && b % KB == 0 {
        format!("{} KB", b / KB)
    } else {
        format!("{b} B")
    }
}

pub const KB: u64 = 1 << 10;
pub const MB: u64 = 1 << 20;
pub const GB: u64 = 1 << 30;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * KB), "2 KB");
        assert_eq!(fmt_bytes(64 * MB), "64 MB");
        assert_eq!(fmt_bytes(3 * GB), "3 GB");
        assert_eq!(fmt_bytes(MB + 1), format!("{} B", MB + 1));
    }
}
