//! Mini property-based testing harness (no `proptest` in the offline
//! vendor set). Seeded generator + case runner with first-failure
//! reporting and a crude halving shrinker for integer/size parameters.
//!
//! Usage:
//! ```ignore
//! check(100, |g| {
//!     let n = g.usize(1, 64);
//!     let v = g.vec_f64(n, 0.0, 10.0);
//!     prop_assert!(v.len() == n, "len mismatch");
//!     Ok(())
//! });
//! ```

use crate::util::rng::{stream_seed, Rng};

/// A single test case's randomness source, with convenience generators.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }
    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }
    pub fn vec_u64(&mut self, n: usize, lo: u64, hi: u64) -> Vec<u64> {
        (0..n).map(|_| self.u64(lo, hi)).collect()
    }
    /// Pick one of the provided options.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
    /// Message sizes log-uniform over [lo, hi] bytes — the natural
    /// distribution for comms workloads.
    pub fn size_log(&mut self, lo: u64, hi: u64) -> u64 {
        let (a, b) = ((lo as f64).ln(), (hi as f64).ln());
        self.f64(a, b).exp() as u64
    }
}

pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop` with a fixed base seed.
/// Panics (test failure) on the first failing case, reporting the
/// seed so the case can be replayed with `check_seeded`.
pub fn check<F: FnMut(&mut Gen) -> PropResult>(cases: usize, prop: F) {
    check_seeded(0x01_B1E0_0u64, cases, prop)
}

pub fn check_seeded<F: FnMut(&mut Gen) -> PropResult>(base_seed: u64, cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = stream_seed(base_seed, case as u64);
        let mut g = Gen { rng: Rng::new(seed), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case} (replay: check_seeded({base_seed:#x}, ..) case seed {seed:#x}):\n  {msg}"
            );
        }
    }
}

/// Assert macro that returns a property error instead of panicking, so
/// the harness can attach seed/replay info.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Approximate float equality helper for property bodies.
pub fn close(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    diff <= abs || diff <= rel * a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check_seeded(1, 200, |g| {
            let n = g.usize(0, 100);
            prop_assert!(n <= 100, "n={n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        check_seeded(2, 50, |g| {
            let n = g.usize(0, 100);
            prop_assert!(n < 90, "n={n} too big");
            Ok(())
        });
    }

    #[test]
    fn size_log_in_range() {
        check_seeded(3, 200, |g| {
            let s = g.size_log(1 << 10, 1 << 30);
            prop_assert!((1 << 10..=1 << 30).contains(&s), "s={s}");
            Ok(())
        });
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 1e-6));
        assert!(close(0.0, 1e-9, 0.0, 1e-6));
    }
}
