//! Deterministic, seedable PRNG substrate (no `rand` crate in the
//! offline vendor set). SplitMix64 core with the usual convenience
//! samplers; good enough statistical quality for workload generation
//! and property testing, and fully reproducible across runs.
//!
//! The free functions below are the shared SplitMix64 primitives the
//! simulator's seeded subsystems build on: [`Rng`] itself, the ECMP
//! hash baseline (`baselines/ecmp_hash`), the packet engine's
//! per-injector streams (`fabric/packet`), and the property-test
//! case derivation (`util/quickcheck`). They were previously
//! re-implemented locally at each of those sites; keeping one copy
//! here pins the bit pattern every seeded anchor depends on.

/// The SplitMix64 increment, `⌊2⁶⁴/φ⌋` (Weyl constant).
pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// The SplitMix64 output finalizer: a bijective avalanche over `u64`.
/// Every bit of the input affects roughly half the output bits, which
/// is what lets correlated inputs (sequential Weyl states, packed
/// `(src, dst, rail)` keys) act as independent uniform draws.
#[inline]
pub fn avalanche64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One full stateless SplitMix64 step: `avalanche64(z + GOLDEN)`.
/// Equivalent to the output SplitMix64 produces from state `z`; this
/// is the hash the ECMP baseline applies to packed path keys.
#[inline]
pub fn mix64(z: u64) -> u64 {
    avalanche64(z.wrapping_add(GOLDEN))
}

/// Derive the seed for substream `stream` of a seeded subsystem:
/// `seed ^ stream·GOLDEN`. Used for per-injector RNG streams in the
/// packet engine and per-case property-test seeds, so sibling streams
/// share no prefix.
#[inline]
pub fn stream_seed(seed: u64, stream: u64) -> u64 {
    seed ^ stream.wrapping_mul(GOLDEN)
}

/// SplitMix64 PRNG (Steele, Lea, Flood 2014). Passes BigCrush; 64-bit
/// state, trivially seedable, never hits a zero-state pathology.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(GOLDEN) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN);
        avalanche64(self.state)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish
    /// multiply-shift with a rejection loop to kill modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        if hi - lo == u64::MAX {
            return self.next_u64(); // full range: no rejection needed
        }
        lo + self.below(hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (single value; the pair is not
    /// cached to keep the state machine trivially reproducible).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Zipf-like sample in [0, n): rank r with weight (r+1)^-s, via
    /// inverse-CDF over the precomputable harmonic; O(n) fallback is
    /// fine for the workload sizes used here.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let h: f64 = (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(s)).sum();
        let mut u = self.f64() * h;
        for r in 0..n {
            u -= 1.0 / ((r + 1) as f64).powf(s);
            if u <= 0.0 {
                return r;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Split off an independent child stream (for parallel generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden values (computed independently) pinning the shared
    /// SplitMix64 primitives: every seeded anchor in the repo depends
    /// on these exact bit patterns.
    #[test]
    fn splitmix_golden_values() {
        assert_eq!(avalanche64(0), 0);
        assert_eq!(avalanche64(1), 0x5692_161D_100B_05E5);
        assert_eq!(avalanche64(0xDEAD_BEEF), 0x4E06_2702_EC92_9EEA);
        // mix64(0) is the canonical SplitMix64 first output for seed 0
        assert_eq!(mix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(mix64(1), 0x910A_2DEC_8902_5CC1);
        assert_eq!(mix64(42), 0xBDD7_3226_2FEB_6E95);
        assert_eq!(stream_seed(0x9A_C4E7, 5), 0x1715_609F_7CEE_A88E);
        // stream 0 is the base seed itself
        assert_eq!(stream_seed(0x1234, 0), 0x1234);
        let mut r = Rng::new(7);
        assert_eq!(r.next_u64(), 0x044C_3CD7_F43C_661C);
        assert_eq!(r.next_u64(), 0xE698_4080_BAB1_2A02);
        assert_eq!(r.next_u64(), 0x953A_EB70_673E_29CB);
    }

    /// `Rng` is exactly the stateless step iterated: state k+G yields
    /// mix64(k+G) — the identity that makes `mix64` "one SplitMix64
    /// draw" rather than a lookalike.
    #[test]
    fn rng_is_iterated_mix64() {
        let seed = 0xFEED_F00D;
        let mut r = Rng::new(seed);
        let mut state = seed.wrapping_add(GOLDEN);
        for _ in 0..32 {
            assert_eq!(r.next_u64(), mix64(state));
            state = state.wrapping_add(GOLDEN);
        }
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(42);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            // expected 10_000 each; loose 5-sigma-ish band
            assert!((9500..10500).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn below_covers_full_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 4];
        for _ in 0..1000 {
            seen[r.below(4) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(9);
        let mut c = [0usize; 8];
        for _ in 0..20_000 {
            c[r.zipf(8, 1.2)] += 1;
        }
        assert!(c[0] > c[7] * 4, "c={c:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(1);
        let mut a = r.fork();
        let mut b = r.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
