//! Deterministic, seedable PRNG substrate (no `rand` crate in the
//! offline vendor set). SplitMix64 core with the usual convenience
//! samplers; good enough statistical quality for workload generation
//! and property testing, and fully reproducible across runs.

/// SplitMix64 PRNG (Steele, Lea, Flood 2014). Passes BigCrush; 64-bit
/// state, trivially seedable, never hits a zero-state pathology.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish
    /// multiply-shift with a rejection loop to kill modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        if hi - lo == u64::MAX {
            return self.next_u64(); // full range: no rejection needed
        }
        lo + self.below(hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (single value; the pair is not
    /// cached to keep the state machine trivially reproducible).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Zipf-like sample in [0, n): rank r with weight (r+1)^-s, via
    /// inverse-CDF over the precomputable harmonic; O(n) fallback is
    /// fine for the workload sizes used here.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let h: f64 = (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(s)).sum();
        let mut u = self.f64() * h;
        for r in 0..n {
            u -= 1.0 / ((r + 1) as f64).powf(s);
            if u <= 0.0 {
                return r;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Split off an independent child stream (for parallel generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(42);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            // expected 10_000 each; loose 5-sigma-ish band
            assert!((9500..10500).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn below_covers_full_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 4];
        for _ in 0..1000 {
            seen[r.below(4) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(9);
        let mut c = [0usize; 8];
        for _ in 0..20_000 {
            c[r.zipf(8, 1.2)] += 1;
        }
        assert!(c[0] > c[7] * 4, "c={c:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(1);
        let mut a = r.fork();
        let mut b = r.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
