//! Statistics helpers: percentiles, summary stats, Jain's fairness
//! index, and a fixed-bucket latency histogram. Used by the metrics
//! layer, the bench harness, and the experiment drivers.

/// Percentile over a *sorted* slice; `q` in [0,100].
///
/// Delegates to [`percentile_nearest_rank_sorted`]: since PR 5 the
/// repo has ONE percentile semantics — exact nearest rank — so the
/// interference report, the tail reports and the bench harness all
/// agree on what "p99" means (an observed sample, never an
/// interpolation). The pre-PR-4 linear-interpolation variant is gone.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    percentile_nearest_rank_sorted(sorted, q)
}

/// Percentile over an unsorted slice (copies + sorts); nearest-rank,
/// like every other percentile in the repo ([`percentile_sorted`]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    percentile_nearest_rank(xs, q)
}

/// Exact **nearest-rank** percentile over a *sorted* slice: the
/// smallest element such that at least `ceil(q/100 · n)` samples are ≤
/// it (q = 0 returns the minimum). No interpolation — the result is
/// always an observed sample, which is what tail-latency reporting
/// wants (an interpolated "p99" can be a latency no chunk ever saw).
pub fn percentile_nearest_rank_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "nearest-rank percentile of empty slice");
    assert!((0.0..=100.0).contains(&q), "quantile {q} out of [0,100]");
    let n = sorted.len();
    let rank = (q / 100.0 * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Nearest-rank percentile over an unsorted slice (copies + sorts).
pub fn percentile_nearest_rank(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_nearest_rank_sorted(&v, q)
}

/// Nearest-rank p50 (median sample) of an unsorted slice.
pub fn p50(xs: &[f64]) -> f64 {
    percentile_nearest_rank(xs, 50.0)
}

/// Nearest-rank p95 of an unsorted slice.
pub fn p95(xs: &[f64]) -> f64 {
    percentile_nearest_rank(xs, 95.0)
}

/// Nearest-rank p99 of an unsorted slice.
pub fn p99(xs: &[f64]) -> f64 {
    percentile_nearest_rank(xs, 99.0)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median absolute deviation (robust spread, used by the bench harness).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let med = percentile(xs, 50.0);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    percentile(&devs, 50.0)
}

/// Jain's fairness index: (Σx)² / (n·Σx²). 1.0 = perfectly balanced,
/// 1/n = maximally skewed. The paper's imbalance metric maps onto this.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        return 1.0; // all zero: vacuously balanced
    }
    s * s / (xs.len() as f64 * s2)
}

/// Max/mean ratio — the "congestion factor" the planner minimizes.
pub fn max_over_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    if m == 0.0 {
        return 1.0;
    }
    xs.iter().cloned().fold(f64::MIN, f64::max) / m
}

/// Summary of a sample set.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: v.len(),
            mean: mean(&v),
            std: stddev(&v),
            min: *v.first().unwrap_or(&f64::NAN),
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: *v.last().unwrap_or(&f64::NAN),
        }
    }
}

/// Log-bucketed histogram for latency distributions (µs-scale and up).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    /// bucket i covers [base*2^i, base*2^(i+1))
    pub base: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub total: u64,
}

impl LogHistogram {
    pub fn new(base: f64, buckets: usize) -> Self {
        LogHistogram { base, counts: vec![0; buckets], underflow: 0, total: 0 }
    }

    pub fn record(&mut self, x: f64) {
        self.total += 1;
        if x < self.base {
            self.underflow += 1;
            return;
        }
        let idx = (x / self.base).log2().floor() as usize;
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.base;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                // geometric midpoint of the bucket
                return self.base * 2f64.powi(i as i32) * std::f64::consts::SQRT_2;
            }
        }
        self.base * 2f64.powi(self.counts.len() as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert!((percentile(&v, 25.0) - 2.0).abs() < 1e-12);
    }

    /// The unification contract: `percentile` / `percentile_sorted`
    /// ARE the nearest-rank helpers, for every rank and input — the
    /// interference report and the tail reports share one semantics.
    #[test]
    fn percentile_is_nearest_rank_everywhere() {
        let v = [0.0, 10.0];
        // nearest rank returns an observed sample, never 7.5
        assert_eq!(percentile(&v, 75.0), 10.0);
        let samples: Vec<f64> = (0..37).map(|i| (i * 7 % 37) as f64 * 1.5).collect();
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 1.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
            let p = percentile(&samples, q);
            assert_eq!(p.to_bits(), percentile_nearest_rank(&samples, q).to_bits());
            assert_eq!(
                percentile_sorted(&sorted, q).to_bits(),
                percentile_nearest_rank_sorted(&sorted, q).to_bits()
            );
            assert!(samples.contains(&p), "p{q} = {p} not an observed sample");
        }
    }

    /// Textbook nearest-rank example (ISO 2602 style): ranks are exact
    /// samples, never interpolations.
    #[test]
    fn nearest_rank_textbook() {
        let v = [15.0, 20.0, 35.0, 40.0, 50.0];
        assert_eq!(percentile_nearest_rank(&v, 30.0), 20.0); // ceil(1.5) = rank 2
        assert_eq!(percentile_nearest_rank(&v, 40.0), 20.0); // ceil(2.0) = rank 2
        assert_eq!(percentile_nearest_rank(&v, 50.0), 35.0); // ceil(2.5) = rank 3
        assert_eq!(percentile_nearest_rank(&v, 100.0), 50.0);
        assert_eq!(percentile_nearest_rank(&v, 0.0), 15.0); // clamp to min
    }

    /// The convenience wrappers are nearest-rank (exact samples) and
    /// ordered; on n = 100 distinct values pXX is exactly the XXth.
    #[test]
    fn nearest_rank_wrappers_on_100() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(p50(&v), 50.0);
        assert_eq!(p95(&v), 95.0);
        assert_eq!(p99(&v), 99.0);
        assert!(p50(&v) <= p95(&v) && p95(&v) <= p99(&v));
        // members of the sample set even for awkward sizes
        let odd: Vec<f64> = (0..7).map(|i| 10.0 + i as f64 * 3.0).collect();
        for q in [1.0, 33.0, 50.0, 95.0, 99.0] {
            let x = percentile_nearest_rank(&odd, q);
            assert!(odd.contains(&x), "p{q} = {x} not an observed sample");
        }
        // singleton
        assert_eq!(p99(&[7.5]), 7.5);
    }

    #[test]
    fn jain_bounds() {
        assert!((jain_index(&[1.0, 1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        let skew = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((skew - 0.25).abs() < 1e-12);
    }

    #[test]
    fn summary_ordering() {
        let s = Summary::of(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn mad_robust() {
        // outlier barely moves MAD
        let a = mad(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let b = mad(&[1.0, 2.0, 3.0, 4.0, 500.0]);
        assert!((a - 1.0).abs() < 1e-12);
        assert!(b <= 2.0);
    }

    #[test]
    fn histogram_quantile_monotone() {
        let mut h = LogHistogram::new(1.0, 24);
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 100.0 && p50 < 1200.0);
    }

    #[test]
    fn max_over_mean_uniform_is_one() {
        assert!((max_over_mean(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
    }
}
