//! Minimal JSON substrate (no serde in the offline vendor set).
//!
//! Covers the interchange needs of this repo: the artifact manifest
//! written by `python/compile/aot.py`, experiment result dumps, and
//! config files. Full parser (numbers, strings with escapes, nested
//! containers) + pretty printer. Not a general-purpose speed demon —
//! manifests are kilobytes.

use std::collections::BTreeMap;
use std::fmt;
use std::io;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- accessors ----------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| if x >= 0.0 && x.fract() == 0.0 { Some(x as u64) } else { None })
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| if x.fract() == 0.0 { Some(x as i64) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj["k"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---------- constructors ----------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    // ---------- parse ----------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---------- emit ----------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

/// One machine-readable JSONL record: the single choke point every
/// `benches/*.rs` target and the telemetry trace writer emit through,
/// so the whole repo shares exactly one float-formatting policy
/// ([`Json::Num`]'s integral-`f64` rule). The `exp` tag is folded in as
/// a field; key order on the wire is [`Json::Obj`]'s (alphabetical).
pub fn json_line(exp: &str, fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("exp", Json::str(exp))];
    all.extend(fields);
    Json::obj(all).to_string_compact()
}

/// Line-oriented JSON sink (JSONL): one compact object per line.
/// Telemetry traces (`--trace out.jsonl`) stream through this; benches
/// use [`json_line`] directly since they print to stdout.
pub struct JsonlWriter<W: io::Write> {
    w: W,
    lines: usize,
}

impl JsonlWriter<io::BufWriter<std::fs::File>> {
    /// Create (truncate) `path` as a buffered JSONL sink.
    pub fn create(path: impl AsRef<std::path::Path>) -> io::Result<Self> {
        let f = std::fs::File::create(path)?;
        Ok(JsonlWriter::new(io::BufWriter::new(f)))
    }
}

impl<W: io::Write> JsonlWriter<W> {
    pub fn new(w: W) -> Self {
        JsonlWriter { w, lines: 0 }
    }

    /// Number of lines written so far.
    pub fn lines(&self) -> usize {
        self.lines
    }

    pub fn write(&mut self, line: &Json) -> io::Result<()> {
        self.lines += 1;
        writeln!(self.w, "{}", line.to_string_compact())
    }

    pub fn flush(&mut self) -> io::Result<()> {
        self.w.flush()
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: handle the common BMP case +
                            // paired surrogates; lone surrogates become U+FFFD.
                            if (0xD800..0xDC00).contains(&cp)
                                && self.b[self.i..].starts_with(b"\\u")
                            {
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                        .map_err(|_| self.err("bad surrogate"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad surrogate"))?;
                                if (0xDC00..0xE000).contains(&lo) {
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    out.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                    continue;
                                }
                            }
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_line_tags_and_roundtrips() {
        let line = json_line(
            "demo",
            vec![("n", Json::num(3.0)), ("ratio", Json::num(0.25))],
        );
        assert!(!line.contains('\n'), "JSONL records are single lines");
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("exp").as_str(), Some("demo"));
        assert_eq!(v.get("n").as_u64(), Some(3));
        assert_eq!(v.get("ratio").as_f64(), Some(0.25));
    }

    /// The `Display`-based float path is shortest-roundtrip: any finite
    /// f64 written by the line writer parses back bit-identically —
    /// the property `nimble report` relies on to reproduce headline
    /// numbers from a trace alone.
    #[test]
    fn jsonl_floats_roundtrip_bitwise() {
        let xs = [0.1 + 0.2, 1.0 / 3.0, 6.02e23, -4.9e-324, 1234.5678e-9];
        let mut buf = Vec::new();
        {
            let mut w = JsonlWriter::new(&mut buf);
            for &x in &xs {
                w.write(&Json::obj(vec![("x", Json::num(x))])).unwrap();
            }
            w.flush().unwrap();
            assert_eq!(w.lines(), xs.len());
        }
        let text = String::from_utf8(buf).unwrap();
        for (line, &x) in text.lines().zip(&xs) {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.get("x").as_f64().unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "hi\nthere"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(v.get("a").idx(2).as_f64(), Some(-300.0));
        assert_eq!(v.get("b").get("c").as_bool(), Some(true));
        assert_eq!(v.get("b").get("d"), &Json::Null);
        assert_eq!(v.get("s").as_str(), Some("hi\nthere"));
        // reparse the emitted form
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::num(42.0).to_string_compact(), "42");
        assert_eq!(Json::num(1.5).to_string_compact(), "1.5");
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.get("nope").get("deeper"), &Json::Null);
    }
}
