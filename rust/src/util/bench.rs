//! Minimal benchmarking harness (no `criterion` in the offline vendor
//! set): warmup + timed iterations, median/MAD reporting, and a
//! uniform table output used by every `benches/*.rs` target (which
//! are built with `harness = false`).

use crate::util::stats::{mad, percentile};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mad_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>10}  n={}",
            self.name,
            fmt_s(self.median_s),
            fmt_s(self.mad_s),
            fmt_s(self.min_s),
            self.iters
        )
    }
}

pub fn header() -> String {
    format!(
        "{:<44} {:>12} {:>12} {:>10}",
        "benchmark", "median", "±mad", "min"
    )
}

fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Time `f` with auto-scaled iteration count (targets ~`budget_s` of
/// total measurement after `warmup` calls).
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / one) as usize).clamp(5, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        median_s: percentile(&samples, 50.0),
        mad_s: mad(&samples),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Run a set of benches and print the table; returns results for
/// programmatic use.
pub fn run_suite(title: &str, benches: Vec<(&str, Box<dyn FnMut()>)>) -> Vec<BenchResult> {
    println!("\n== {title} ==");
    println!("{}", header());
    let mut out = Vec::new();
    for (name, mut f) in benches {
        let r = bench(name, 0.2, &mut *f);
        println!("{}", r.row());
        out.push(r);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleepless_work() {
        let mut acc = 0u64;
        let r = bench("spin", 0.02, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.median_s > 0.0 && r.median_s < 0.1);
        assert!(r.iters >= 5);
        assert!(acc != 0);
    }

    #[test]
    fn formatting_scales() {
        assert!(fmt_s(2.0).contains('s'));
        assert!(fmt_s(2e-3).contains("ms"));
        assert!(fmt_s(2e-6).contains("µs"));
        assert!(fmt_s(2e-9).contains("ns"));
    }
}
