//! Irregular point-to-point traffic (paper §III-A-d): graph/sparse
//! workloads whose per-pair volumes follow a heavy-tailed (Zipf)
//! distribution over randomly drawn communicating pairs.

use crate::planner::Demand;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Draw `pairs` distinct (src,dst) pairs; pair ranks get Zipf(s)
/// weights scaled so the total volume is `total_bytes`.
pub fn powerlaw_pairs(
    topo: &Topology,
    pairs: usize,
    zipf_s: f64,
    total_bytes: f64,
    rng: &mut Rng,
) -> Vec<Demand> {
    let n = topo.num_gpus();
    assert!(pairs <= n * (n - 1), "more pairs than the topology has");
    let mut chosen = Vec::with_capacity(pairs);
    let mut seen = std::collections::BTreeSet::new();
    while chosen.len() < pairs {
        let s = rng.below(n as u64) as usize;
        let d = rng.below(n as u64) as usize;
        if s != d && seen.insert((s, d)) {
            chosen.push((s, d));
        }
    }
    // Zipf weights over pair ranks
    let weights: Vec<f64> =
        (0..pairs).map(|r| 1.0 / ((r + 1) as f64).powf(zipf_s)).collect();
    let wsum: f64 = weights.iter().sum();
    chosen
        .into_iter()
        .zip(weights)
        .map(|((s, d), w)| Demand::new(s, d, total_bytes * w / wsum))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_conserved_and_skewed() {
        let t = Topology::paper();
        let mut rng = Rng::new(3);
        let d = powerlaw_pairs(&t, 20, 1.4, 1e9, &mut rng);
        assert_eq!(d.len(), 20);
        let total: f64 = d.iter().map(|x| x.bytes).sum();
        assert!((total - 1e9).abs() < 1.0);
        // first (rank-0) pair dominates the last
        assert!(d[0].bytes > d[19].bytes * 10.0);
    }

    #[test]
    fn pairs_are_distinct_and_valid() {
        let t = Topology::paper();
        let mut rng = Rng::new(11);
        let d = powerlaw_pairs(&t, 30, 1.0, 1e6, &mut rng);
        let mut set = std::collections::BTreeSet::new();
        for dm in &d {
            assert_ne!(dm.src, dm.dst);
            assert!(set.insert((dm.src, dm.dst)));
        }
    }
}
