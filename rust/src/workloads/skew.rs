//! Skewed All-to-Allv generator (paper §V-C / Fig 7): "each GPU
//! directs a fixed fraction of its payload to a designated hot peer,
//! while the remaining payload is spread across the other peers."

use crate::planner::Demand;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Every rank sends `payload_bytes` total; `hotspot_ratio` of it goes
/// to `hot_dst`, the rest evenly to all other peers. The hot rank
/// itself spreads uniformly (it has no hot peer other than itself).
pub fn hotspot_alltoallv(
    topo: &Topology,
    payload_bytes: f64,
    hotspot_ratio: f64,
    hot_dst: usize,
) -> Vec<Demand> {
    assert!((0.0..=1.0).contains(&hotspot_ratio));
    let n = topo.num_gpus();
    let mut out = Vec::new();
    for s in 0..n {
        if s == hot_dst {
            // uniform spread from the hot rank
            let per = payload_bytes / (n - 1) as f64;
            for d in 0..n {
                if d != s {
                    out.push(Demand::new(s, d, per));
                }
            }
            continue;
        }
        let hot_bytes = payload_bytes * hotspot_ratio;
        let rest = (payload_bytes - hot_bytes) / (n - 2).max(1) as f64;
        for d in 0..n {
            if d == s {
                continue;
            }
            let b = if d == hot_dst { hot_bytes } else { rest };
            if b > 0.0 {
                out.push(Demand::new(s, d, b));
            }
        }
    }
    out
}

/// Randomized variant: hot destination and per-rank payload jitter are
/// drawn from `rng` (used by the property suite and soak tests).
pub fn hotspot_alltoallv_jittered(
    topo: &Topology,
    payload_bytes: f64,
    hotspot_ratio: f64,
    rng: &mut Rng,
) -> (usize, Vec<Demand>) {
    let hot = rng.below(topo.num_gpus() as u64) as usize;
    let mut demands = hotspot_alltoallv(topo, payload_bytes, hotspot_ratio, hot);
    for d in demands.iter_mut() {
        d.bytes *= rng.range_f64(0.9, 1.1);
    }
    (hot, demands)
}

/// Skewed All-to-Allv with per-rank hot *peers* instead of one shared
/// hot sink: rank `s` directs `hotspot_ratio` of its payload to the
/// same-local-index GPU `shift_nodes` nodes away, the rest evenly to
/// everyone else. With `shift_nodes >= pod_size` every hot column
/// crosses the fat-tree core, so the aggregate skew stresses the
/// oversubscribed spine tier rather than a single receiver NIC (a
/// one-sink hotspot is ingress-bound at the hot node — every routing
/// scheme ties there, see DESIGN.md §12).
pub fn shifted_hotspot_alltoallv(
    topo: &Topology,
    payload_bytes: f64,
    hotspot_ratio: f64,
    shift_nodes: usize,
) -> Vec<Demand> {
    assert!((0.0..=1.0).contains(&hotspot_ratio));
    let n = topo.num_gpus();
    let mut out = Vec::new();
    for s in 0..n {
        let hot = topo.gpu((topo.node_of(s) + shift_nodes) % topo.nodes, topo.local_of(s));
        if hot == s {
            let per = payload_bytes / (n - 1) as f64;
            for d in 0..n {
                if d != s {
                    out.push(Demand::new(s, d, per));
                }
            }
            continue;
        }
        let hot_bytes = payload_bytes * hotspot_ratio;
        let rest = (payload_bytes - hot_bytes) / (n - 2).max(1) as f64;
        for d in 0..n {
            if d == s {
                continue;
            }
            let b = if d == hot { hot_bytes } else { rest };
            if b > 0.0 {
                out.push(Demand::new(s, d, b));
            }
        }
    }
    out
}

/// The uniform (hotspot_ratio = 1/(n-1)) All-to-All used for the
/// balanced-parity experiments.
pub fn uniform_alltoall(topo: &Topology, payload_bytes: f64) -> Vec<Demand> {
    let n = topo.num_gpus();
    let per = payload_bytes / (n - 1) as f64;
    let mut out = Vec::new();
    for s in 0..n {
        for d in 0..n {
            if s != d {
                out.push(Demand::new(s, d, per));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_hot_fraction() {
        let t = Topology::paper();
        let payload = 1e8;
        let demands = hotspot_alltoallv(&t, payload, 0.7, 4);
        // every rank sends exactly `payload`
        for s in 0..8 {
            let sent: f64 =
                demands.iter().filter(|d| d.src == s).map(|d| d.bytes).sum();
            assert!((sent - payload).abs() < 1e-3, "rank {s} sent {sent}");
        }
        // hot destination receives 7·0.7·payload + its own spread... no:
        // 7 non-hot ranks each send 0.7·payload to it
        let hot_in: f64 =
            demands.iter().filter(|d| d.dst == 4).map(|d| d.bytes).sum();
        assert!((hot_in - 7.0 * 0.7 * payload).abs() < 1e-3);
    }

    #[test]
    fn uniform_case_is_balanced() {
        let t = Topology::paper();
        let demands = uniform_alltoall(&t, 7e7);
        for d in 0..8 {
            let rx: f64 = demands.iter().filter(|x| x.dst == d).map(|x| x.bytes).sum();
            assert!((rx - 7e7).abs() < 1e-3);
        }
        assert_eq!(demands.len(), 8 * 7);
    }

    #[test]
    fn ratio_one_sends_everything_to_hot() {
        let t = Topology::paper();
        let demands = hotspot_alltoallv(&t, 1e6, 1.0, 0);
        for d in demands.iter().filter(|d| d.src != 0) {
            assert_eq!(d.dst, 0, "all non-hot traffic must target the hotspot");
        }
    }

    #[test]
    fn shifted_hot_peers_are_cross_pod_and_conserve() {
        let t = Topology::fat_tree(8, 2.0);
        let payload = 1e8;
        let demands = shifted_hotspot_alltoallv(&t, payload, 0.5, 4);
        for s in 0..t.num_gpus() {
            let sent: f64 =
                demands.iter().filter(|d| d.src == s).map(|d| d.bytes).sum();
            assert!((sent - payload).abs() < 1e-3, "rank {s} sent {sent}");
            // the hot column is the single largest part and crosses pods
            let hot = demands
                .iter()
                .filter(|d| d.src == s)
                .max_by(|a, b| a.bytes.total_cmp(&b.bytes))
                .unwrap();
            assert!((hot.bytes - 0.5 * payload).abs() < 1e-3);
            assert_eq!(t.local_of(hot.dst), t.local_of(s));
            assert_ne!(
                t.pod_of(t.node_of(s)),
                t.pod_of(t.node_of(hot.dst)),
                "shift >= pod_size must land in another pod"
            );
        }
        // every rank also receives exactly one hot column: no shared sink
        for d in 0..t.num_gpus() {
            let hot_in = demands
                .iter()
                .filter(|x| x.dst == d && (x.bytes - 0.5 * payload).abs() < 1e-3)
                .count();
            assert_eq!(hot_in, 1, "rank {d}");
        }
    }

    #[test]
    fn jittered_conserves_roughly() {
        let t = Topology::paper();
        let mut rng = Rng::new(7);
        let (hot, demands) = hotspot_alltoallv_jittered(&t, 1e8, 0.5, &mut rng);
        assert!(hot < 8);
        let total: f64 = demands.iter().map(|d| d.bytes).sum();
        assert!((total / 8e8 - 1.0).abs() < 0.1);
    }
}
