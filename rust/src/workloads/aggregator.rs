//! Sparse many-to-few aggregator traffic (paper §III-A-b): numerous
//! sources funnel into a small set of aggregation destinations —
//! parameter servers, distributed reductions, telemetry sinks.

use crate::planner::Demand;
use crate::topology::Topology;

/// Every non-aggregator rank sends `bytes` to each of the
/// `aggregators` (round-robin weighted if `weights` given).
pub fn many_to_few(topo: &Topology, aggregators: &[usize], bytes: f64) -> Vec<Demand> {
    let n = topo.num_gpus();
    let mut out = Vec::new();
    for s in 0..n {
        if aggregators.contains(&s) {
            continue;
        }
        for &a in aggregators {
            out.push(Demand::new(s, a, bytes / aggregators.len() as f64));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregators_receive_everything() {
        let t = Topology::paper();
        let d = many_to_few(&t, &[0, 4], 2e6);
        // 6 senders × 2e6 total each
        let total: f64 = d.iter().map(|x| x.bytes).sum();
        assert!((total - 12e6).abs() < 1e-3);
        for dm in &d {
            assert!(dm.dst == 0 || dm.dst == 4);
            assert!(dm.src != 0 && dm.src != 4);
        }
    }

    #[test]
    fn single_aggregator_pure_incast() {
        let t = Topology::paper();
        let d = many_to_few(&t, &[3], 1e6);
        assert_eq!(d.len(), 7);
        assert!(d.iter().all(|x| x.dst == 3));
    }
}
