//! MoE expert-parallel token routing traffic (paper §V-D / Fig 8).
//!
//! Two-node, eight-GPU EP: one expert per GPU, tokens of dimension
//! `d_model` in bf16 (2 bytes/element). Gating sends a `hotspot_ratio`
//! fraction of every rank's tokens to the hot expert, the rest spread
//! evenly — the inference-time drift the paper motivates with
//! DeepSeek/Qwen deployments. Dispatch is the forward All-to-Allv;
//! combine is its exact transpose (tokens return to their owners).

use crate::planner::Demand;
use crate::topology::Topology;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct MoeConfig {
    /// Total tokens across all ranks per step (paper sweeps 2K..64K).
    pub global_tokens: usize,
    /// Token embedding dimension (paper: 4096).
    pub d_model: usize,
    /// Bytes per element (bf16 = 2).
    pub elem_bytes: usize,
    /// Fraction of each rank's tokens routed to the hot expert.
    pub hotspot_ratio: f64,
    /// Hot expert's GPU.
    pub hot_expert: usize,
}

impl MoeConfig {
    pub fn paper(global_tokens: usize, hotspot_ratio: f64) -> MoeConfig {
        MoeConfig {
            global_tokens,
            d_model: 4096,
            elem_bytes: 2,
            hotspot_ratio,
            hot_expert: 4,
        }
    }

    pub fn token_bytes(&self) -> f64 {
        (self.d_model * self.elem_bytes) as f64
    }
}

/// Per-(src,dst) token counts for the dispatch phase.
/// `counts[s][d]` = tokens rank `s` sends to expert on GPU `d`
/// (self-routed tokens stay local — no demand).
pub fn routing_matrix(topo: &Topology, cfg: &MoeConfig) -> Vec<Vec<f64>> {
    let n = topo.num_gpus();
    let per_rank = cfg.global_tokens as f64 / n as f64;
    let mut m = vec![vec![0.0; n]; n];
    for s in 0..n {
        if s == cfg.hot_expert {
            // hot rank's own tokens spread evenly over all experts
            for d in 0..n {
                m[s][d] = per_rank / n as f64;
            }
        } else {
            let hot = per_rank * cfg.hotspot_ratio;
            let rest = (per_rank - hot) / (n - 1) as f64;
            for d in 0..n {
                m[s][d] = if d == cfg.hot_expert { hot + rest * 0.0 } else { rest };
            }
            // tokens for the local expert included in `rest` (d == s)
        }
    }
    m
}

/// Dispatch demands (tokens × token_bytes), excluding local traffic.
pub fn dispatch_demands(topo: &Topology, cfg: &MoeConfig) -> Vec<Demand> {
    let m = routing_matrix(topo, cfg);
    matrix_to_demands(&m, cfg.token_bytes())
}

/// Combine demands: the transpose of dispatch (experts return results
/// to token owners; same volume per token in this FFN setting).
pub fn combine_demands(topo: &Topology, cfg: &MoeConfig) -> Vec<Demand> {
    let m = routing_matrix(topo, cfg);
    let n = m.len();
    let mut t = vec![vec![0.0; n]; n];
    for s in 0..n {
        for d in 0..n {
            t[d][s] = m[s][d];
        }
    }
    matrix_to_demands(&t, cfg.token_bytes())
}

fn matrix_to_demands(m: &[Vec<f64>], token_bytes: f64) -> Vec<Demand> {
    let mut out = Vec::new();
    for (s, row) in m.iter().enumerate() {
        for (d, &tok) in row.iter().enumerate() {
            if s != d && tok > 0.0 {
                out.push(Demand::new(s, d, tok * token_bytes));
            }
        }
    }
    out
}

/// Tokens each expert must process (incl. locally routed ones) — the
/// compute-phase input sizes for the FFN.
pub fn expert_token_counts(topo: &Topology, cfg: &MoeConfig) -> Vec<f64> {
    let m = routing_matrix(topo, cfg);
    let n = m.len();
    (0..n).map(|d| (0..n).map(|s| m[s][d]).sum()).collect()
}

/// Stochastic gating variant: multinomial token draws instead of exact
/// fractions (soak/property tests).
pub fn routing_matrix_sampled(
    topo: &Topology,
    cfg: &MoeConfig,
    rng: &mut Rng,
) -> Vec<Vec<f64>> {
    let n = topo.num_gpus();
    let per_rank = cfg.global_tokens / n;
    let mut m = vec![vec![0.0; n]; n];
    for s in 0..n {
        for _ in 0..per_rank {
            let d = if s != cfg.hot_expert && rng.bool(cfg.hotspot_ratio) {
                cfg.hot_expert
            } else {
                rng.below(n as u64) as usize
            };
            m[s][d] += 1.0;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_conservation() {
        let t = Topology::paper();
        let cfg = MoeConfig::paper(16_384, 0.7);
        let m = routing_matrix(&t, &cfg);
        let total: f64 = m.iter().flatten().sum();
        assert!((total - 16_384.0).abs() < 1e-6);
        let per_expert = expert_token_counts(&t, &cfg);
        let total2: f64 = per_expert.iter().sum();
        assert!((total2 - 16_384.0).abs() < 1e-6);
    }

    #[test]
    fn hot_expert_dominates() {
        let t = Topology::paper();
        let cfg = MoeConfig::paper(16_384, 0.9);
        let counts = expert_token_counts(&t, &cfg);
        let hot = counts[cfg.hot_expert];
        let cold_max = counts
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != cfg.hot_expert)
            .map(|(_, &c)| c)
            .fold(0.0, f64::max);
        assert!(hot > cold_max * 5.0, "hot={hot} cold_max={cold_max}");
    }

    #[test]
    fn combine_is_transpose_of_dispatch() {
        let t = Topology::paper();
        let cfg = MoeConfig::paper(8192, 0.6);
        let disp = dispatch_demands(&t, &cfg);
        let comb = combine_demands(&t, &cfg);
        let find = |v: &[Demand], s: usize, d: usize| {
            v.iter().find(|x| x.src == s && x.dst == d).map(|x| x.bytes).unwrap_or(0.0)
        };
        for s in 0..8 {
            for d in 0..8 {
                if s != d {
                    assert!(
                        (find(&disp, s, d) - find(&comb, d, s)).abs() < 1e-6,
                        "transpose mismatch at ({s},{d})"
                    );
                }
            }
        }
    }

    #[test]
    fn bytes_scale_with_d_model() {
        let t = Topology::paper();
        let mut cfg = MoeConfig::paper(4096, 0.5);
        let d1: f64 = dispatch_demands(&t, &cfg).iter().map(|x| x.bytes).sum();
        cfg.d_model *= 2;
        let d2: f64 = dispatch_demands(&t, &cfg).iter().map(|x| x.bytes).sum();
        assert!((d2 / d1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sampled_matrix_is_close_to_exact() {
        let t = Topology::paper();
        let cfg = MoeConfig::paper(65_536, 0.8);
        let mut rng = Rng::new(5);
        let m = routing_matrix_sampled(&t, &cfg, &mut rng);
        let hot_in: f64 = (0..8).map(|s| m[s][cfg.hot_expert]).sum();
        let total: f64 = m.iter().flatten().sum();
        // hot share ≈ 7/8·0.8 + small uniform residue
        assert!((hot_in / total - 0.72).abs() < 0.06, "share={}", hot_in / total);
    }
}
