//! Workload generators for the imbalance patterns the paper
//! classifies in §III-A: skewed All-to-Allv (a), many-to-few
//! aggregation (b), stencil neighbor exchange with boundary hotspots
//! (c), and irregular point-to-point (d), plus the MoE token-routing
//! traffic used in §V-D and the *time-varying* drifts ([`dynamic`])
//! driving the execution-time re-planning experiments.

pub mod aggregator;
pub mod dynamic;
pub mod irregular;
pub mod moe_traffic;
pub mod skew;
pub mod stencil;

pub use dynamic::{MoeDrift, PhasedHotRows};
pub use skew::hotspot_alltoallv;
