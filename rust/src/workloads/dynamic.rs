//! Time-varying skew generators for the execution-time re-planning
//! experiments (`exp::replan` / `nimble replan`).
//!
//! Two drift patterns the paper motivates:
//!
//! * [`PhasedHotRows`] — a *hot row* of the traffic matrix (one source
//!   bursting to every peer, §III-A irregular p2p) that shifts to a
//!   different GPU every `period` rounds. A plan computed for one
//!   phase routes the next phase's burst over whatever single paths the
//!   then-light pairs were given — the static-plan failure mode §I
//!   describes, and exactly what mid-flight re-planning recovers.
//! * [`MoeDrift`] — MoE expert-popularity drift (§V-D): the hot expert
//!   wanders and the gate's concentration changes smoothly; each round
//!   emits the dispatch All-to-Allv plus its combine transpose.

use crate::planner::Demand;
use crate::topology::Topology;
use crate::util::rng::Rng;
use crate::workloads::moe_traffic::MoeConfig;

/// Phase-shifting hot-row workload: every round, `hot_at(round)` sends
/// `row_bytes` to each peer while all other pairs exchange
/// `background_bytes` (uniform all-to-all floor so every pair exists in
/// every phase).
#[derive(Clone, Debug)]
pub struct PhasedHotRows {
    /// Bytes the hot source sends to EACH peer per round.
    pub row_bytes: f64,
    /// Uniform background bytes for every other ordered pair.
    pub background_bytes: f64,
    /// Rounds between hot-row shifts.
    pub period: usize,
    /// Hot-source schedule, cycled; alternates nodes by default.
    pub hot_rows: Vec<usize>,
}

impl PhasedHotRows {
    /// Default schedule used by `nimble replan`: the hot row hops
    /// between the two nodes so both intra- and inter-node re-routing
    /// are exercised.
    pub fn paper_default(topo: &Topology, row_bytes: f64) -> Self {
        let g = topo.num_gpus();
        // 0, then a GPU on the far node, then staggered locals
        let hot_rows = vec![
            0,
            topo.gpu(topo.nodes - 1, 0),
            topo.gpu(0, 2usize.min(topo.gpus_per_node - 1)),
            topo.gpu(topo.nodes - 1, 3usize.min(topo.gpus_per_node - 1)),
        ]
        .into_iter()
        .map(|x| x % g)
        .collect();
        PhasedHotRows {
            row_bytes,
            background_bytes: row_bytes / 16.0,
            period: 1,
            hot_rows,
        }
    }

    /// The hot source active in `round`.
    pub fn hot_at(&self, round: usize) -> usize {
        self.hot_rows[(round / self.period.max(1)) % self.hot_rows.len()]
    }

    /// Demand set for `round`.
    pub fn demands_at(&self, topo: &Topology, round: usize) -> Vec<Demand> {
        let hot = self.hot_at(round);
        let n = topo.num_gpus();
        let mut out = Vec::new();
        for s in 0..n {
            for d in 0..n {
                if s == d {
                    continue;
                }
                let bytes =
                    if s == hot { self.row_bytes } else { self.background_bytes };
                if bytes > 0.0 {
                    out.push(Demand::new(s, d, bytes));
                }
            }
        }
        out
    }

    /// Jittered variant for soak/property tests (±10% per demand).
    pub fn demands_at_jittered(
        &self,
        topo: &Topology,
        round: usize,
        rng: &mut Rng,
    ) -> Vec<Demand> {
        let mut demands = self.demands_at(topo, round);
        for d in demands.iter_mut() {
            d.bytes *= rng.range_f64(0.9, 1.1);
        }
        demands
    }
}

/// MoE expert-popularity drift: the hot expert wanders over a schedule
/// and the per-round popularity vector is a linear blend between the
/// outgoing and incoming hot experts, so popularity *drifts* instead of
/// snapping. Each round's traffic is dispatch + combine (the transpose:
/// hot-expert rounds produce both a hot column and a hot row).
#[derive(Clone, Debug)]
pub struct MoeDrift {
    /// Base MoE shape (tokens, d_model, hotspot ratio); its
    /// `hot_expert` field is overridden by the schedule.
    pub cfg: MoeConfig,
    /// Rounds each expert stays hot before drifting onward.
    pub period: usize,
    /// Hot-expert schedule, cycled.
    pub experts: Vec<usize>,
}

impl MoeDrift {
    pub fn paper_default(topo: &Topology, global_tokens: usize) -> Self {
        let g = topo.num_gpus();
        MoeDrift {
            cfg: MoeConfig::paper(global_tokens, 0.8),
            period: 2,
            experts: vec![4 % g, 1 % g, 6 % g, 3 % g],
        }
    }

    /// Popularity vector at `round`: the hot expert holds
    /// `hotspot_ratio`, blended linearly into the next hot expert over
    /// the phase, remainder uniform.
    pub fn popularity_at(&self, topo: &Topology, round: usize) -> Vec<f64> {
        let n = topo.num_gpus();
        let period = self.period.max(1);
        let phase = (round / period) % self.experts.len();
        let next = (phase + 1) % self.experts.len();
        let alpha = (round % period) as f64 / period as f64;
        let (cur, nxt) = (self.experts[phase] % n, self.experts[next] % n);
        let hot_w = self.cfg.hotspot_ratio;
        let rest = (1.0 - hot_w) / (n as f64 - 1.0).max(1.0);
        let mut p = vec![rest; n];
        p[cur] += (hot_w - rest) * (1.0 - alpha);
        p[nxt] += (hot_w - rest) * alpha;
        // renormalize (cur == nxt keeps the vector a distribution)
        let sum: f64 = p.iter().sum();
        p.iter_mut().for_each(|x| *x /= sum);
        p
    }

    /// Dispatch + combine demands for `round`.
    pub fn demands_at(&self, topo: &Topology, round: usize) -> Vec<Demand> {
        let n = topo.num_gpus();
        let pop = self.popularity_at(topo, round);
        let per_rank = self.cfg.global_tokens as f64 / n as f64;
        let token_bytes = self.cfg.token_bytes();
        let mut out = Vec::new();
        for s in 0..n {
            for (d, &share) in pop.iter().enumerate() {
                if s == d {
                    continue; // self-routed tokens stay local
                }
                let bytes = per_rank * share * token_bytes;
                if bytes > 0.0 {
                    out.push(Demand::new(s, d, bytes)); // dispatch
                    out.push(Demand::new(d, s, bytes)); // combine (transpose)
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn hot_row_shifts_with_period() {
        let t = Topology::paper();
        let mut w = PhasedHotRows::paper_default(&t, 64.0 * MB);
        w.period = 2;
        assert_eq!(w.hot_at(0), w.hot_at(1));
        assert_ne!(w.hot_at(1), w.hot_at(2));
        // schedule cycles
        let cycle = w.hot_rows.len() * w.period;
        assert_eq!(w.hot_at(0), w.hot_at(cycle));
        // both nodes appear in the default schedule
        let nodes: Vec<usize> = w.hot_rows.iter().map(|&h| t.node_of(h)).collect();
        assert!(nodes.contains(&0) && nodes.contains(&1));
    }

    #[test]
    fn hot_row_dominates_its_round() {
        let t = Topology::paper();
        let w = PhasedHotRows::paper_default(&t, 64.0 * MB);
        for round in 0..4 {
            let hot = w.hot_at(round);
            let demands = w.demands_at(&t, round);
            // every ordered pair present
            assert_eq!(demands.len(), 8 * 7);
            let sent = |s: usize| -> f64 {
                demands.iter().filter(|d| d.src == s).map(|d| d.bytes).sum()
            };
            for s in 0..8 {
                if s == hot {
                    assert!((sent(s) - 7.0 * 64.0 * MB).abs() < 1.0);
                } else {
                    assert!(sent(s) < sent(hot) / 4.0, "row {s} too heavy");
                }
            }
        }
    }

    #[test]
    fn moe_popularity_is_distribution_and_drifts() {
        let t = Topology::paper();
        let w = MoeDrift::paper_default(&t, 16_384);
        let mut prev_hot = usize::MAX;
        let mut shifts = 0;
        for round in 0..(w.period * w.experts.len()) {
            let p = w.popularity_at(&t, round);
            let sum: f64 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            let hot = p
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if hot != prev_hot {
                shifts += 1;
                prev_hot = hot;
            }
        }
        assert!(shifts >= 3, "popularity never drifted: {shifts} shifts");
    }

    #[test]
    fn moe_demands_conserve_tokens_both_ways() {
        let t = Topology::paper();
        let w = MoeDrift::paper_default(&t, 16_384);
        let demands = w.demands_at(&t, 1);
        let total: f64 = demands.iter().map(|d| d.bytes).sum();
        // dispatch + combine move the same bytes; the self-routed share
        // stays local, so the total is below 2 × global payload
        let payload =
            w.cfg.global_tokens as f64 * w.cfg.token_bytes();
        assert!(total < 2.0 * payload);
        assert!(total > 1.5 * payload, "too much traffic stayed local");
        // transpose symmetry: bytes(s→d) appears as bytes(d→s) too
        let find = |s: usize, d: usize| -> f64 {
            demands.iter().filter(|x| x.src == s && x.dst == d).map(|x| x.bytes).sum()
        };
        for s in 0..8 {
            for d in 0..8 {
                if s != d {
                    assert!((find(s, d) - find(d, s)).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn jitter_stays_close() {
        let t = Topology::paper();
        let w = PhasedHotRows::paper_default(&t, 32.0 * MB);
        let mut rng = Rng::new(11);
        let base: f64 = w.demands_at(&t, 0).iter().map(|d| d.bytes).sum();
        let jit: f64 =
            w.demands_at_jittered(&t, 0, &mut rng).iter().map(|d| d.bytes).sum();
        assert!((jit / base - 1.0).abs() < 0.1);
    }
}
