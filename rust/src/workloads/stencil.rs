//! 1-D stencil neighbor exchange (paper Table I's workload, §III-A-c).
//!
//! Rank i exchanges halos with ranks i−1 and i+1. With an optional
//! boundary hotspot factor, edge ranks carry heavier halos — the
//! "boundary hotspot" pattern of adaptive mesh refinement.

use crate::planner::Demand;
use crate::topology::Topology;

/// Plain 1-D stencil: every adjacent rank pair exchanges `halo_bytes`
/// in both directions (open chain, no wraparound).
pub fn stencil_1d(topo: &Topology, halo_bytes: f64) -> Vec<Demand> {
    let n = topo.num_gpus();
    let mut out = Vec::new();
    for i in 0..n.saturating_sub(1) {
        out.push(Demand::new(i, i + 1, halo_bytes));
        out.push(Demand::new(i + 1, i, halo_bytes));
    }
    out
}

/// Boundary-hotspot stencil: ranks in the middle third exchange
/// `hot_factor ×` heavier halos (refined region).
pub fn stencil_1d_hotspot(topo: &Topology, halo_bytes: f64, hot_factor: f64) -> Vec<Demand> {
    let n = topo.num_gpus();
    let (lo, hi) = (n / 3, 2 * n / 3);
    let mut out = Vec::new();
    for i in 0..n.saturating_sub(1) {
        let hot = i >= lo && i < hi;
        let b = if hot { halo_bytes * hot_factor } else { halo_bytes };
        out.push(Demand::new(i, i + 1, b));
        out.push(Demand::new(i + 1, i, b));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_structure() {
        let t = Topology::paper();
        let d = stencil_1d(&t, 1e6);
        assert_eq!(d.len(), 14); // 7 adjacent pairs × 2 directions
        for dm in &d {
            assert_eq!((dm.src as i64 - dm.dst as i64).abs(), 1);
        }
    }

    #[test]
    fn only_one_cross_node_pair() {
        let t = Topology::paper();
        let d = stencil_1d(&t, 1e6);
        let cross = d.iter().filter(|dm| !t.same_node(dm.src, dm.dst)).count();
        assert_eq!(cross, 2); // 3↔4 both directions
    }

    #[test]
    fn hotspot_inflates_middle() {
        let t = Topology::paper();
        let d = stencil_1d_hotspot(&t, 1e6, 4.0);
        let mid: f64 = d
            .iter()
            .filter(|dm| dm.src.min(dm.dst) == 3)
            .map(|dm| dm.bytes)
            .sum();
        let edge: f64 = d
            .iter()
            .filter(|dm| dm.src.min(dm.dst) == 0)
            .map(|dm| dm.bytes)
            .sum();
        assert!((mid / edge - 4.0).abs() < 1e-9);
    }
}
