//! Candidate path enumeration per the paper's §IV-B.
//!
//! For a pair (s, d) the planner considers:
//! - **Intra-node direct**: the single NVLink edge (s, d).
//! - **Intra-node 2-hop**: (s, i), (i, d) for every other GPU i on the
//!   node — exactly one intermediate hop ("the rest of GPUs can be part
//!   of more potential paths").
//! - **Inter-node rail-matched**: for each rail r — optional NVLink hop
//!   s → GPU_r on the source node, the rail edge, optional NVLink hop
//!   GPU_r → d on the destination node. Rail matching is enforced
//!   (mismatched rails go through extra switch tiers; NCCL's PXN makes
//!   the same choice).
//! - **Inter-node cross-rail** (baselines only): the mismatched NIC
//!   edge, with its capacity penalty.

use super::{GpuId, LinkId, Topology};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PathKind {
    IntraDirect,
    /// via intermediate GPU (global id)
    IntraTwoHop { via: GpuId },
    /// rail-matched inter-node path over rail `rail`
    InterRail { rail: usize },
    /// rail-mismatched inter-node path (baselines)
    InterCross { src_rail: usize, dst_rail: usize },
    /// tiered: intra-pod inter-node path through the pod's rail-`rail`
    /// leaf switch
    InterLeaf { rail: usize },
    /// tiered: inter-pod path through rail plane `rail`'s spine `spine`
    InterSpine { rail: usize, spine: usize },
}

/// A concrete routed path: an ordered list of directed links.
#[derive(Clone, Debug, PartialEq)]
pub struct Path {
    pub src: GpuId,
    pub dst: GpuId,
    pub kind: PathKind,
    pub hops: Vec<LinkId>,
}

impl Path {
    /// Number of GPU-relay forwarding stops (not counting src/dst) on a
    /// **flat** fabric, where every interior vertex of the hop chain is
    /// a relay GPU. On tiered fabrics interior vertices may be switches
    /// (which forward in hardware, not software) — use
    /// [`Path::relays`]`.len()` there.
    pub fn relay_count(&self) -> usize {
        self.hops.len().saturating_sub(1)
    }

    /// GPUs that forward (interior vertices of the path). Switch
    /// vertices are skipped: forwarding there is the fabric's job, not
    /// a GPU copy engine's.
    pub fn relays(&self, topo: &Topology) -> Vec<GpuId> {
        let mut out = Vec::new();
        for w in self.hops.windows(2) {
            let mid = topo.link(w[0]).dst;
            debug_assert_eq!(mid, topo.link(w[1]).src, "disconnected path");
            if !topo.is_switch(mid) {
                out.push(mid);
            }
        }
        out
    }

    /// Validate connectivity: hops chain from src to dst.
    pub fn is_valid(&self, topo: &Topology) -> bool {
        if self.hops.is_empty() {
            return false;
        }
        if topo.link(self.hops[0]).src != self.src {
            return false;
        }
        if topo.link(*self.hops.last().unwrap()).dst != self.dst {
            return false;
        }
        self.hops.windows(2).all(|w| topo.link(w[0]).dst == topo.link(w[1]).src)
    }
}

/// Enumerate NIMBLE's candidate paths for (s, d).
///
/// `allow_multipath = false` restricts to the single fastest path (what
/// the planner uses below the size threshold).
pub fn candidates(topo: &Topology, s: GpuId, d: GpuId, allow_multipath: bool) -> Vec<Path> {
    assert_ne!(s, d, "no self-paths");
    let mut out = Vec::new();
    if topo.same_node(s, d) {
        let direct = topo.nvlink(s, d).expect("all-to-all NVLink mesh");
        out.push(Path { src: s, dst: d, kind: PathKind::IntraDirect, hops: vec![direct] });
        // §VII: on NVSwitch fabrics each GPU has one uplink — a relay
        // would reuse the link the direct path already occupies, so
        // intra-node multi-path is structurally unavailable.
        if allow_multipath && !topo.nvswitch {
            let node = topo.node_of(s);
            for local in 0..topo.gpus_per_node {
                let i = topo.gpu(node, local);
                if i == s || i == d {
                    continue;
                }
                out.push(Path {
                    src: s,
                    dst: d,
                    kind: PathKind::IntraTwoHop { via: i },
                    hops: vec![topo.nvlink(s, i).unwrap(), topo.nvlink(i, d).unwrap()],
                });
            }
        }
    } else {
        let (na, nb) = (topo.node_of(s), topo.node_of(d));
        let rails: Vec<usize> = if allow_multipath {
            (0..topo.nics_per_node).collect()
        } else {
            // single fastest path: the source GPU's home rail (GPU-NIC
            // affinity), like NCCL's default p2p choice.
            vec![topo.home_rail(s)]
        };
        // Tier-walk: per rail, the staging/landing NVLink hops (PXN
        // forwarding to/from the rail GPU) are tier-independent; the
        // fabric segment between the two rail GPUs depends on the tier
        // — a single flat NIC edge, a leaf bounce inside a pod, or one
        // candidate per core spine across pods.
        for r in rails {
            let g_ra = topo.gpu(na, r);
            let g_rb = topo.gpu(nb, r);
            for (kind, seg) in fabric_segments(topo, na, nb, r, allow_multipath) {
                let mut hops = Vec::with_capacity(seg.len() + 2);
                if g_ra != s {
                    hops.push(topo.nvlink(s, g_ra).unwrap());
                }
                hops.extend(seg);
                if g_rb != d {
                    hops.push(topo.nvlink(g_rb, d).unwrap());
                }
                out.push(Path { src: s, dst: d, kind, hops });
            }
        }
    }
    out
}

/// Candidate enumeration under a link-liveness mask (the fault
/// recovery path, DESIGN.md §13): candidates crossing any dead link
/// (`live[h] == false`) are **masked out** — removed from the set, not
/// re-priced, so no amount of load can route bytes onto a dead link.
///
/// If masking removes *every* candidate (the pair is fully cut), the
/// unfiltered set is returned: the planner must still produce a plan,
/// and a stalled-but-replayable path that resumes on recovery beats
/// having no path at all.
pub fn live_candidates(
    topo: &Topology,
    s: GpuId,
    d: GpuId,
    allow_multipath: bool,
    live: &[bool],
) -> Vec<Path> {
    let all = candidates(topo, s, d, allow_multipath);
    let filtered: Vec<Path> = all
        .iter()
        .filter(|p| p.hops.iter().all(|&h| live[h]))
        .cloned()
        .collect();
    if filtered.is_empty() {
        all
    } else {
        filtered
    }
}

/// The inter-node fabric segments between the rail-`r` GPUs of nodes
/// `na` and `nb`, one per distinct route through the fabric tier.
///
/// Flat fabrics return exactly the single NIC-to-NIC rail edge —
/// [`candidates`] therefore reproduces the pre-tier hop lists (and
/// kinds) bit-identically, which the flat-identity anchor tests pin.
/// Tiered fabrics return the leaf bounce for intra-pod pairs, and one
/// segment per spine (`allow_multipath`) or the deterministic
/// `(na + nb) % spines` spine (single-path mode) across pods.
fn fabric_segments(
    topo: &Topology,
    na: usize,
    nb: usize,
    r: usize,
    allow_multipath: bool,
) -> Vec<(PathKind, Vec<LinkId>)> {
    let Some(tier) = &topo.tier else {
        return vec![(
            PathKind::InterRail { rail: r },
            vec![topo.rail(na, nb, r).expect("flat inter-node rail")],
        )];
    };
    let up = topo.leaf_up(na, r).expect("node NIC uplink");
    let down = topo.leaf_down(nb, r).expect("node NIC downlink");
    let (pa, pb) = (topo.pod_of(na), topo.pod_of(nb));
    if pa == pb {
        return vec![(PathKind::InterLeaf { rail: r }, vec![up, down])];
    }
    let spines: Vec<usize> = if allow_multipath {
        (0..tier.spines_per_rail).collect()
    } else {
        vec![(na + nb) % tier.spines_per_rail]
    };
    spines
        .into_iter()
        .map(|k| {
            (
                PathKind::InterSpine { rail: r, spine: k },
                vec![
                    up,
                    topo.spine_up(pa, r, k).expect("leaf uplink"),
                    topo.spine_down(pb, r, k).expect("leaf downlink"),
                    down,
                ],
            )
        })
        .collect()
}

/// The baseline cross-rail path (source rail NIC straight to the
/// destination rail's NIC, no GPU forwarding): what a rail-unaware
/// library does for an inter-node pair whose endpoints sit on
/// different rails. On wide nodes a NIC-less endpoint enters/exits via
/// the NVLink hop to its home-rail GPU, mirroring [`candidates`]; on
/// the paper's one-NIC-per-GPU layout those hops vanish and the path
/// is the bare mismatched NIC edge, exactly as before.
pub fn cross_rail_path(topo: &Topology, s: GpuId, d: GpuId) -> Option<Path> {
    if topo.same_node(s, d) {
        return None;
    }
    let (sr, dr) = (topo.home_rail(s), topo.home_rail(d));
    if sr == dr {
        return None; // same rail: the matched path exists
    }
    let (na, nb) = (topo.node_of(s), topo.node_of(d));
    let link = topo.cross_rail(na, nb, sr, dr)?;
    let mut hops = Vec::with_capacity(3);
    let g_sr = topo.gpu(na, sr);
    let g_dr = topo.gpu(nb, dr);
    if g_sr != s {
        hops.push(topo.nvlink(s, g_sr).unwrap());
    }
    hops.push(link);
    if g_dr != d {
        hops.push(topo.nvlink(g_dr, d).unwrap());
    }
    Some(Path {
        src: s,
        dst: d,
        kind: PathKind::InterCross { src_rail: sr, dst_rail: dr },
        hops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intra_candidates_paper_topology() {
        let t = Topology::paper();
        let c = candidates(&t, 0, 1, true);
        // direct + 2 two-hop (via gpu 2, gpu 3)
        assert_eq!(c.len(), 3);
        assert!(c.iter().all(|p| p.is_valid(&t)));
        assert_eq!(c.iter().filter(|p| p.kind == PathKind::IntraDirect).count(), 1);
        let vias: Vec<_> = c
            .iter()
            .filter_map(|p| match p.kind {
                PathKind::IntraTwoHop { via } => Some(via),
                _ => None,
            })
            .collect();
        assert_eq!(vias, vec![2, 3]);
    }

    #[test]
    fn inter_candidates_rail_matched() {
        let t = Topology::paper();
        // GPU 1 (node 0) → GPU 6 (node 1, local 2)
        let c = candidates(&t, 1, 6, true);
        assert_eq!(c.len(), 4); // one per rail
        for p in &c {
            assert!(p.is_valid(&t));
            match p.kind {
                PathKind::InterRail { rail } => {
                    // rail 1: no hop on source side; rail 2: no hop on dst side
                    let expect_hops =
                        1 + usize::from(rail != 1) + usize::from(rail != 2);
                    assert_eq!(p.hops.len(), expect_hops, "rail {rail}");
                }
                _ => panic!("unexpected kind"),
            }
        }
    }

    #[test]
    fn single_path_mode() {
        let t = Topology::paper();
        assert_eq!(candidates(&t, 0, 1, false).len(), 1);
        let inter = candidates(&t, 1, 6, false);
        assert_eq!(inter.len(), 1);
        assert_eq!(inter[0].kind, PathKind::InterRail { rail: 1 });
    }

    #[test]
    fn relays_identified() {
        let t = Topology::paper();
        let c = candidates(&t, 0, 1, true);
        let two_hop = c
            .iter()
            .find(|p| matches!(p.kind, PathKind::IntraTwoHop { via: 2 }))
            .unwrap();
        assert_eq!(two_hop.relays(&t), vec![2]);
        // inter-node via rail 3 from gpu1→gpu6: relays are gpu3 and gpu7
        let inter = candidates(&t, 1, 6, true);
        let via3 = inter
            .iter()
            .find(|p| p.kind == (PathKind::InterRail { rail: 3 }))
            .unwrap();
        assert_eq!(via3.relays(&t), vec![3, 7]);
    }

    /// Wide nodes (8 GPU / 4 NIC): inter-node candidates still come one
    /// per rail, NIC-less GPUs enter via an NVLink hop to the rail GPU,
    /// and the single-path choice is the source's home rail.
    #[test]
    fn wide_node_candidates_use_home_rails() {
        let t = Topology::cluster(2);
        // GPU 6 (node 0, home rail 2) → GPU 13 (node 1, local 5)
        let c = candidates(&t, 6, 13, true);
        assert_eq!(c.len(), 4);
        for p in &c {
            assert!(p.is_valid(&t), "{:?} invalid", p.kind);
            match p.kind {
                PathKind::InterRail { rail } => {
                    // neither endpoint owns a NIC, so every rail path
                    // has an NVLink hop on both sides
                    assert_eq!(p.hops.len(), 3, "rail {rail}");
                }
                _ => panic!("unexpected kind"),
            }
        }
        let single = candidates(&t, 6, 13, false);
        assert_eq!(single.len(), 1);
        assert_eq!(single[0].kind, PathKind::InterRail { rail: 2 });
        // intra-node: direct + 6 relays on an 8-GPU mesh
        assert_eq!(candidates(&t, 0, 7, true).len(), 7);
    }

    #[test]
    fn cross_rail_only_when_mismatched() {
        let t = Topology::paper();
        assert!(cross_rail_path(&t, 0, 4).is_none()); // same rail 0
        let p = cross_rail_path(&t, 0, 5).unwrap(); // rails 0 → 1
        assert!(p.is_valid(&t));
        assert_eq!(p.hops.len(), 1);
        // wide nodes: NIC-less endpoints stage over NVLink, and the
        // path stays a valid connected chain
        let c = Topology::cluster(2);
        assert!(cross_rail_path(&c, 4, 12).is_none()); // both home rail 0
        let w = cross_rail_path(&c, 4, 13).unwrap(); // home rails 0 → 1
        assert!(w.is_valid(&c));
        assert_eq!(w.hops.len(), 3);
    }

    /// Tiered fabrics: intra-pod pairs bounce through the pod leaf (one
    /// candidate per rail), inter-pod pairs get one candidate per
    /// (rail, spine), and switch vertices never count as GPU relays.
    #[test]
    fn fat_tree_candidates() {
        let t = Topology::fat_tree(8, 2.0); // pods of 4 nodes
        // GPU 1 (node 0) → GPU 17 (node 2): same pod
        let intra_pod = candidates(&t, 1, 17, true);
        assert_eq!(intra_pod.len(), 4);
        for p in &intra_pod {
            assert!(p.is_valid(&t), "{:?} invalid", p.kind);
            assert!(matches!(p.kind, PathKind::InterLeaf { .. }));
            // stage + up + down + land: endpoints own no NIC on most rails
            assert!(p.hops.len() >= 2 && p.hops.len() <= 4);
        }
        // GPU 1 (node 0, pod 0) → GPU 33 (node 4, pod 1): cross-pod,
        // one candidate per rail × spine
        let inter_pod = candidates(&t, 1, 33, true);
        assert_eq!(inter_pod.len(), 4 * 2);
        for p in &inter_pod {
            assert!(p.is_valid(&t), "{:?} invalid", p.kind);
            assert!(matches!(p.kind, PathKind::InterSpine { .. }));
            // GPU relays are only the rail GPUs, never the switches
            assert!(p.relays(&t).len() <= 2, "{:?}", p.relays(&t));
        }
        // single-path mode: home rail + deterministic spine
        let single = candidates(&t, 1, 33, false);
        assert_eq!(single.len(), 1);
        // spine = (na + nb) % S = (0 + 4) % 2
        assert_eq!(single[0].kind, PathKind::InterSpine { rail: 1, spine: 0 });
    }

    #[test]
    fn fat_tree_intra_node_unchanged() {
        let t = Topology::fat_tree(8, 2.0);
        let c = candidates(&t, 0, 1, true);
        assert_eq!(c.len(), 7); // direct + 6 relays on the 8-GPU mesh
        assert!(c.iter().all(|p| p.is_valid(&t)));
    }

    /// Liveness masking removes exactly the candidates crossing dead
    /// links, falls back to the full set when the pair is cut, and with
    /// an all-live mask returns the identical enumeration.
    #[test]
    fn live_candidates_mask_and_fallback() {
        let t = Topology::paper();
        let all_live = vec![true; t.links.len()];
        assert_eq!(
            live_candidates(&t, 1, 6, true, &all_live),
            candidates(&t, 1, 6, true)
        );
        // kill rail 1: gpu1's home-rail candidate disappears
        let mut live = all_live.clone();
        let r1 = t.rail(0, 1, 1).unwrap();
        live[r1] = false;
        let masked = live_candidates(&t, 1, 6, true, &live);
        assert_eq!(masked.len(), 3);
        assert!(masked.iter().all(|p| !p.hops.contains(&r1)));
        // cut every inter-node path: fallback returns the full set
        let mut none = all_live;
        for (i, l) in t.links.iter().enumerate() {
            if !matches!(l.kind, crate::topology::LinkKind::NvLink) {
                none[i] = false;
            }
        }
        assert_eq!(
            live_candidates(&t, 1, 6, true, &none),
            candidates(&t, 1, 6, true)
        );
    }

    #[test]
    fn validity_catches_broken_chains() {
        let t = Topology::paper();
        let good = candidates(&t, 0, 3, true).pop().unwrap();
        let mut bad = good.clone();
        bad.hops.reverse();
        if bad.hops.len() > 1 {
            assert!(!bad.is_valid(&t));
        }
        let empty = Path { src: 0, dst: 3, kind: PathKind::IntraDirect, hops: vec![] };
        assert!(!empty.is_valid(&t));
    }
}
