//! Cluster topology model: nodes with all-to-all NVLink-connected GPUs
//! and rail-matched NICs (one NIC per GPU, NIC *i* ↔ GPU *i*), plus
//! inter-node rail links. This is the graph over which the planner
//! (Algorithm 1) routes and the fabric simulator schedules flows.
//!
//! Matches the paper's testbed shape (§V-A): per node, 4× H100 with
//! all-to-all NVLink4 and 4× NDR400 HCAs, one per GPU. The topology is
//! parametric so larger/smaller configurations are first-class.

pub mod path;

pub use path::{Path, PathKind};

/// Global GPU index: `node * gpus_per_node + local`.
pub type GpuId = usize;
/// Index into `Topology::links`.
pub type LinkId = usize;

/// Directed communication link.
#[derive(Clone, Debug, PartialEq)]
pub struct Link {
    pub id: LinkId,
    pub kind: LinkKind,
    /// Source GPU (for rail links: the GPU the source NIC is attached to).
    pub src: GpuId,
    /// Destination GPU.
    pub dst: GpuId,
    /// Capacity in GB/s (effective, large-message).
    pub cap_gbps: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Intra-node GPU↔GPU NVLink edge.
    NvLink,
    /// Inter-node rail-matched NIC↔NIC edge (rail r of node a → rail r
    /// of node b). Endpoints are expressed as the rail-attached GPUs.
    Rail { rail: usize },
    /// Inter-node rail-MISmatched NIC edge (crosses a switch tier);
    /// only baselines that ignore rail matching use these. Carries a
    /// capacity penalty.
    CrossRail { src_rail: usize, dst_rail: usize },
    /// Tiered fabrics: NIC uplink from the rail-`rail` GPU of a node
    /// into its pod's rail-`rail` leaf switch.
    LeafUp { rail: usize },
    /// Tiered fabrics: leaf-switch downlink onto a node's rail-`rail`
    /// NIC.
    LeafDown { rail: usize },
    /// Tiered fabrics: leaf → spine core uplink in rail plane `rail`
    /// (the oversubscribed tier congestion concentrates on).
    SpineUp { rail: usize, spine: usize },
    /// Tiered fabrics: spine → leaf core downlink.
    SpineDown { rail: usize, spine: usize },
}

/// Parameters of the leaf–spine tier above the rails (None on flat
/// rail-matched fabrics): nodes group into pods of `pod_size`; each pod
/// owns one leaf switch per rail, and each rail plane is served by
/// `spines_per_rail` spine switches shared by all pods.
#[derive(Clone, Debug, PartialEq)]
pub struct Tier {
    pub pod_size: usize,
    pub pods: usize,
    pub spines_per_rail: usize,
    /// Oversubscription ratio: leaf down-capacity (towards the nodes)
    /// divided by leaf up-capacity (towards the spines). 1.0 = full
    /// bisection; 2.0 = half the core bandwidth.
    pub oversub: f64,
    /// Per-edge leaf↔spine capacity (GB/s), derived so the pod's total
    /// uplink bandwidth is `pod_size · rail_gbps / oversub` per rail.
    pub uplink_gbps: f64,
}

/// Static description of the cluster fabric.
#[derive(Clone, Debug)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// NICs per node. NIC *i* attaches to GPU *i*, so the count must
    /// divide `gpus_per_node`; the paper's testbed has one NIC per GPU,
    /// the `nimble scale` cluster axis runs 8 GPUs over 4 NICs.
    pub nics_per_node: usize,
    pub links: Vec<Link>,
    /// NVLink effective capacity (GB/s) per directed edge.
    pub nvlink_gbps: f64,
    /// Rail (NIC) effective capacity (GB/s) per directed edge.
    pub rail_gbps: f64,
    /// Penalty factor applied to cross-rail (mismatched) edges.
    pub cross_rail_factor: f64,
    /// DGX-style NVSwitch fabric (paper §VII): every GPU has a single
    /// uplink into a central switch, so intra-node 2-hop forwarding is
    /// impossible — the only link a relay could use is already taken
    /// by the direct path. Inter-node multi-rail balancing still works.
    pub nvswitch: bool,
    /// Leaf–spine tier above the rails; `None` on flat fabrics, where
    /// inter-node rails connect NIC-to-NIC with no switch hops.
    pub tier: Option<Tier>,
    /// Switch vertex count (leaves + spines); switch vertices occupy
    /// ids `num_gpus()..num_gpus()+num_switches` in `Link::src/dst`.
    num_switches: usize,
    // ---- O(1) link lookup tables ----
    nvlink_idx: Vec<Vec<Vec<Option<LinkId>>>>, // [node][src_local][dst_local]
    rail_idx: Vec<Vec<Vec<Option<LinkId>>>>,   // [src_node][dst_node][rail]
    cross_idx: Vec<Vec<Vec<Vec<Option<LinkId>>>>>, // [src_node][dst_node][sr][dr]
    leaf_up_idx: Vec<Vec<Option<LinkId>>>,     // [node][rail]
    leaf_down_idx: Vec<Vec<Option<LinkId>>>,   // [node][rail]
    spine_up_idx: Vec<Vec<Vec<Option<LinkId>>>>, // [pod][rail][spine]
    spine_down_idx: Vec<Vec<Vec<Option<LinkId>>>>, // [pod][rail][spine]
}

/// Effective large-message capacities measured on the paper's testbed
/// (§V-B): 120 GB/s per direct NVLink path, 45.1 GB/s per NDR400 rail.
pub const NVLINK_GBPS: f64 = 120.0;
pub const RAIL_GBPS: f64 = 45.1;
/// Switch-tier penalty for rail-mismatched traffic (baselines only).
pub const CROSS_RAIL_FACTOR: f64 = 0.72;
/// Default spine switches per rail plane on tiered fabrics: two gives
/// the planner a real core-path choice (and ECMP something to hash
/// over) without exploding the candidate count.
pub const SPINES_PER_RAIL: usize = 2;

impl Topology {
    /// The paper's testbed: `hgx(2, 4, 4)` = 2 nodes × (4 GPU + 4 NIC).
    pub fn hgx(nodes: usize, gpus_per_node: usize, nics_per_node: usize) -> Topology {
        Self::build(nodes, gpus_per_node, nics_per_node, NVLINK_GBPS, RAIL_GBPS, true)
    }

    /// Paper evaluation config: 2 nodes, 4 GPUs + 4 NICs each.
    pub fn paper() -> Topology {
        Self::hgx(2, 4, 4)
    }

    /// The cluster-scale axis used by `nimble scale`: `nodes` × (8 GPUs
    /// + 4 NICs). With fewer NICs than GPUs, NIC *r* stays attached to
    /// GPU *r* and the NIC-less GPUs reach the network through an
    /// NVLink hop to their [`Topology::home_rail`] GPU — the same
    /// PXN-style forwarding the planner's inter-node candidates already
    /// model.
    pub fn cluster(nodes: usize) -> Topology {
        Self::build(nodes, 8, 4, NVLINK_GBPS, RAIL_GBPS, true)
    }

    /// Multi-tier leaf–spine fabric over the same node shape as
    /// [`Topology::cluster`] (8 GPUs + 4 NICs per node): nodes group
    /// into pods, each pod has one leaf switch per rail, and every rail
    /// plane is served by [`SPINES_PER_RAIL`] spines whose uplinks are
    /// oversubscribed by `oversub`. Inter-node traffic rides
    /// GPU→leaf(→spine→leaf)→GPU instead of the flat NIC-to-NIC rails.
    pub fn fat_tree(nodes: usize, oversub: f64) -> Topology {
        Self::build_fat_tree(nodes, 8, 4, NVLINK_GBPS, RAIL_GBPS, oversub, SPINES_PER_RAIL)
    }

    /// DGX-like NVSwitch variant (paper §VII "Limitations"): same
    /// node/GPU/NIC counts, but intra-node connectivity goes through a
    /// central NVSwitch — direct paths only, no GPU relaying inside a
    /// node. Used by `nimble ablate`-adjacent experiments to reproduce
    /// the paper's observation that only inter-node multi-NIC
    /// balancing remains available there.
    pub fn dgx_nvswitch(nodes: usize, gpus_per_node: usize, nics_per_node: usize) -> Topology {
        let mut t = Self::hgx(nodes, gpus_per_node, nics_per_node);
        t.nvswitch = true;
        t
    }

    /// Fully parametric constructor. `with_cross_rail` adds the
    /// mismatched-rail edges used by baselines.
    pub fn build(
        nodes: usize,
        gpus_per_node: usize,
        nics_per_node: usize,
        nvlink_gbps: f64,
        rail_gbps: f64,
        with_cross_rail: bool,
    ) -> Topology {
        assert!(nodes >= 1 && gpus_per_node >= 1);
        assert!(
            nics_per_node >= 1
                && nics_per_node <= gpus_per_node
                && gpus_per_node % nics_per_node == 0,
            "rail-matched layout requires NIC count to divide the GPU count \
             (NIC r attaches to GPU r; paper §IV-B)"
        );
        let mut links = Vec::new();
        let mut nvlink_idx =
            vec![vec![vec![None; gpus_per_node]; gpus_per_node]; nodes];
        let mut rail_idx = vec![vec![vec![None; nics_per_node]; nodes]; nodes];
        let mut cross_idx =
            vec![vec![vec![vec![None; nics_per_node]; nics_per_node]; nodes]; nodes];

        // Intra-node all-to-all NVLink mesh (directed edges).
        for n in 0..nodes {
            for i in 0..gpus_per_node {
                for j in 0..gpus_per_node {
                    if i == j {
                        continue;
                    }
                    let id = links.len();
                    links.push(Link {
                        id,
                        kind: LinkKind::NvLink,
                        src: n * gpus_per_node + i,
                        dst: n * gpus_per_node + j,
                        cap_gbps: nvlink_gbps,
                    });
                    nvlink_idx[n][i][j] = Some(id);
                }
            }
        }
        // Inter-node rail-matched NIC edges.
        for a in 0..nodes {
            for b in 0..nodes {
                if a == b {
                    continue;
                }
                for r in 0..nics_per_node {
                    let id = links.len();
                    links.push(Link {
                        id,
                        kind: LinkKind::Rail { rail: r },
                        src: a * gpus_per_node + r,
                        dst: b * gpus_per_node + r,
                        cap_gbps: rail_gbps,
                    });
                    rail_idx[a][b][r] = Some(id);
                }
                if with_cross_rail {
                    for sr in 0..nics_per_node {
                        for dr in 0..nics_per_node {
                            if sr == dr {
                                continue;
                            }
                            let id = links.len();
                            links.push(Link {
                                id,
                                kind: LinkKind::CrossRail { src_rail: sr, dst_rail: dr },
                                src: a * gpus_per_node + sr,
                                dst: b * gpus_per_node + dr,
                                cap_gbps: rail_gbps * CROSS_RAIL_FACTOR,
                            });
                            cross_idx[a][b][sr][dr] = Some(id);
                        }
                    }
                }
            }
        }
        Topology {
            nodes,
            gpus_per_node,
            nics_per_node,
            links,
            nvlink_gbps,
            rail_gbps,
            cross_rail_factor: CROSS_RAIL_FACTOR,
            nvswitch: false,
            tier: None,
            num_switches: 0,
            nvlink_idx,
            rail_idx,
            cross_idx,
            leaf_up_idx: Vec::new(),
            leaf_down_idx: Vec::new(),
            spine_up_idx: Vec::new(),
            spine_down_idx: Vec::new(),
        }
    }

    /// Fully parametric leaf–spine constructor. Pods are the largest of
    /// 4/2/1 nodes that divides `nodes`; each pod gets one leaf switch
    /// per rail and each rail plane `spines_per_rail` spines. Leaf↔spine
    /// edge capacity is set so a pod's total per-rail uplink bandwidth
    /// is `pod_size · rail_gbps / oversub`. No flat rail or cross-rail
    /// edges exist: all inter-node traffic takes switch hops.
    #[allow(clippy::too_many_arguments)]
    pub fn build_fat_tree(
        nodes: usize,
        gpus_per_node: usize,
        nics_per_node: usize,
        nvlink_gbps: f64,
        rail_gbps: f64,
        oversub: f64,
        spines_per_rail: usize,
    ) -> Topology {
        assert!(nodes >= 1 && gpus_per_node >= 1);
        assert!(
            nics_per_node >= 1
                && nics_per_node <= gpus_per_node
                && gpus_per_node % nics_per_node == 0,
            "rail-matched layout requires NIC count to divide the GPU count \
             (NIC r attaches to GPU r; paper §IV-B)"
        );
        assert!(
            oversub >= 1.0 && oversub.is_finite(),
            "oversubscription ratio is leaf-down / leaf-up capacity and must be ≥ 1"
        );
        assert!(spines_per_rail >= 1, "need at least one spine per rail plane");
        let pod_size = [4usize, 2, 1]
            .into_iter()
            .find(|p| *p <= nodes && nodes % p == 0)
            .unwrap();
        let pods = nodes / pod_size;
        let uplink_gbps = pod_size as f64 * rail_gbps / (spines_per_rail as f64 * oversub);
        let g = nodes * gpus_per_node;
        let num_leaves = pods * nics_per_node;
        let num_switches = num_leaves + nics_per_node * spines_per_rail;

        let mut links = Vec::new();
        let mut nvlink_idx =
            vec![vec![vec![None; gpus_per_node]; gpus_per_node]; nodes];
        let mut leaf_up_idx = vec![vec![None; nics_per_node]; nodes];
        let mut leaf_down_idx = vec![vec![None; nics_per_node]; nodes];
        let mut spine_up_idx = vec![vec![vec![None; spines_per_rail]; nics_per_node]; pods];
        let mut spine_down_idx =
            vec![vec![vec![None; spines_per_rail]; nics_per_node]; pods];

        // Intra-node all-to-all NVLink mesh — identical to `build`.
        for n in 0..nodes {
            for i in 0..gpus_per_node {
                for j in 0..gpus_per_node {
                    if i == j {
                        continue;
                    }
                    let id = links.len();
                    links.push(Link {
                        id,
                        kind: LinkKind::NvLink,
                        src: n * gpus_per_node + i,
                        dst: n * gpus_per_node + j,
                        cap_gbps: nvlink_gbps,
                    });
                    nvlink_idx[n][i][j] = Some(id);
                }
            }
        }
        // NIC tier: each node's rail-r NIC attaches up and down to its
        // pod's rail-r leaf (leaf vertex id = g + pod·nics + rail).
        for n in 0..nodes {
            let pod = n / pod_size;
            for r in 0..nics_per_node {
                let leaf = g + pod * nics_per_node + r;
                let nic_gpu = n * gpus_per_node + r;
                let id = links.len();
                links.push(Link {
                    id,
                    kind: LinkKind::LeafUp { rail: r },
                    src: nic_gpu,
                    dst: leaf,
                    cap_gbps: rail_gbps,
                });
                leaf_up_idx[n][r] = Some(id);
                let id = links.len();
                links.push(Link {
                    id,
                    kind: LinkKind::LeafDown { rail: r },
                    src: leaf,
                    dst: nic_gpu,
                    cap_gbps: rail_gbps,
                });
                leaf_down_idx[n][r] = Some(id);
            }
        }
        // Core tier: every leaf connects to all spines of its rail
        // plane (spine vertex id = g + num_leaves + rail·S + spine).
        for pod in 0..pods {
            for r in 0..nics_per_node {
                let leaf = g + pod * nics_per_node + r;
                for k in 0..spines_per_rail {
                    let spine = g + num_leaves + r * spines_per_rail + k;
                    let id = links.len();
                    links.push(Link {
                        id,
                        kind: LinkKind::SpineUp { rail: r, spine: k },
                        src: leaf,
                        dst: spine,
                        cap_gbps: uplink_gbps,
                    });
                    spine_up_idx[pod][r][k] = Some(id);
                    let id = links.len();
                    links.push(Link {
                        id,
                        kind: LinkKind::SpineDown { rail: r, spine: k },
                        src: spine,
                        dst: leaf,
                        cap_gbps: uplink_gbps,
                    });
                    spine_down_idx[pod][r][k] = Some(id);
                }
            }
        }
        Topology {
            nodes,
            gpus_per_node,
            nics_per_node,
            links,
            nvlink_gbps,
            rail_gbps,
            cross_rail_factor: CROSS_RAIL_FACTOR,
            nvswitch: false,
            tier: Some(Tier { pod_size, pods, spines_per_rail, oversub, uplink_gbps }),
            num_switches,
            nvlink_idx,
            rail_idx: Vec::new(),
            cross_idx: Vec::new(),
            leaf_up_idx,
            leaf_down_idx,
            spine_up_idx,
            spine_down_idx,
        }
    }

    pub fn num_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    pub fn node_of(&self, g: GpuId) -> usize {
        g / self.gpus_per_node
    }

    pub fn local_of(&self, g: GpuId) -> usize {
        g % self.gpus_per_node
    }

    pub fn gpu(&self, node: usize, local: usize) -> GpuId {
        node * self.gpus_per_node + local
    }

    pub fn same_node(&self, a: GpuId, b: GpuId) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// The rail a GPU has NIC affinity with. On the paper's one-NIC-
    /// per-GPU layout this is just the local index; on wider nodes
    /// (e.g. [`Topology::cluster`]'s 8 GPU / 4 NIC) GPUs without their
    /// own NIC map onto the rails round-robin.
    pub fn home_rail(&self, g: GpuId) -> usize {
        self.local_of(g) % self.nics_per_node
    }

    /// NVLink edge between two GPUs on the same node.
    pub fn nvlink(&self, src: GpuId, dst: GpuId) -> Option<LinkId> {
        if !self.same_node(src, dst) || src == dst {
            return None;
        }
        self.nvlink_idx[self.node_of(src)][self.local_of(src)][self.local_of(dst)]
    }

    /// Rail-matched inter-node edge on rail `r` (flat fabrics only —
    /// tiered fabrics have no NIC-to-NIC rails).
    pub fn rail(&self, src_node: usize, dst_node: usize, r: usize) -> Option<LinkId> {
        if src_node == dst_node || self.rail_idx.is_empty() {
            return None;
        }
        self.rail_idx[src_node][dst_node][r]
    }

    /// Cross-rail (mismatched) inter-node edge.
    pub fn cross_rail(
        &self,
        src_node: usize,
        dst_node: usize,
        sr: usize,
        dr: usize,
    ) -> Option<LinkId> {
        if src_node == dst_node || sr == dr || self.cross_idx.is_empty() {
            return None;
        }
        self.cross_idx[src_node][dst_node][sr][dr]
    }

    // ---- tiered-fabric vertices and links ----

    /// Switch vertex count (0 on flat fabrics).
    pub fn num_switches(&self) -> usize {
        self.num_switches
    }

    /// Whether vertex `v` (a `Link::src`/`dst` value) is a switch
    /// rather than a GPU. Switches forward in hardware: they are never
    /// relays, endpoints, or NIC owners.
    pub fn is_switch(&self, v: usize) -> bool {
        v >= self.num_gpus()
    }

    /// The pod a node belongs to (0 for every node on flat fabrics).
    pub fn pod_of(&self, node: usize) -> usize {
        match &self.tier {
            Some(t) => node / t.pod_size,
            None => 0,
        }
    }

    /// Vertex id of pod `pod`'s rail-`rail` leaf switch.
    pub fn leaf_id(&self, pod: usize, rail: usize) -> usize {
        self.num_gpus() + pod * self.nics_per_node + rail
    }

    /// Vertex id of rail plane `rail`'s spine `k`.
    pub fn spine_id(&self, rail: usize, k: usize) -> usize {
        let t = self.tier.as_ref().expect("spines exist only on tiered fabrics");
        self.num_gpus() + t.pods * self.nics_per_node + rail * t.spines_per_rail + k
    }

    /// NIC uplink of `node`'s rail `r` into its pod leaf.
    pub fn leaf_up(&self, node: usize, r: usize) -> Option<LinkId> {
        self.leaf_up_idx.get(node).and_then(|v| v.get(r).copied().flatten())
    }

    /// Leaf downlink onto `node`'s rail-`r` NIC.
    pub fn leaf_down(&self, node: usize, r: usize) -> Option<LinkId> {
        self.leaf_down_idx.get(node).and_then(|v| v.get(r).copied().flatten())
    }

    /// Core uplink from pod `pod`'s rail-`r` leaf to spine `k`.
    pub fn spine_up(&self, pod: usize, r: usize, k: usize) -> Option<LinkId> {
        self.spine_up_idx
            .get(pod)
            .and_then(|v| v.get(r))
            .and_then(|v| v.get(k).copied().flatten())
    }

    /// Core downlink from spine `k` to pod `pod`'s rail-`r` leaf.
    pub fn spine_down(&self, pod: usize, r: usize, k: usize) -> Option<LinkId> {
        self.spine_down_idx
            .get(pod)
            .and_then(|v| v.get(r))
            .and_then(|v| v.get(k).copied().flatten())
    }

    /// The node whose NIC-injection budget link `l` draws from: the
    /// node-side source of a NIC edge. `None` for NVLink and core
    /// (leaf↔spine) links, which never touch a node's NIC complex on
    /// the send side. On flat fabrics this is `Some` exactly for the
    /// non-NVLink links, which is what the fabric backends' per-node
    /// aggregate caps were keyed on before the tier existed.
    pub fn nic_out_node(&self, l: &Link) -> Option<usize> {
        match l.kind {
            LinkKind::Rail { .. } | LinkKind::CrossRail { .. } | LinkKind::LeafUp { .. } => {
                Some(self.node_of(l.src))
            }
            _ => None,
        }
    }

    /// The node whose NIC-receive budget link `l` draws from (see
    /// [`Topology::nic_out_node`]).
    pub fn nic_in_node(&self, l: &Link) -> Option<usize> {
        match l.kind {
            LinkKind::Rail { .. } | LinkKind::CrossRail { .. } | LinkKind::LeafDown { .. } => {
                Some(self.node_of(l.dst))
            }
            _ => None,
        }
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id]
    }

    /// All links a GPU injects into (used for per-endpoint load bounds).
    pub fn out_links(&self, g: GpuId) -> impl Iterator<Item = &Link> {
        self.links.iter().filter(move |l| l.src == g)
    }

    pub fn in_links(&self, g: GpuId) -> impl Iterator<Item = &Link> {
        self.links.iter().filter(move |l| l.dst == g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topology_counts() {
        let t = Topology::paper();
        assert_eq!(t.num_gpus(), 8);
        // per node: 4*3 = 12 nvlink edges, ×2 nodes = 24
        let nv = t.links.iter().filter(|l| l.kind == LinkKind::NvLink).count();
        assert_eq!(nv, 24);
        // rails: 2 ordered node pairs × 4 rails = 8
        let rails =
            t.links.iter().filter(|l| matches!(l.kind, LinkKind::Rail { .. })).count();
        assert_eq!(rails, 8);
        // cross rails: 2 × 4×3 = 24
        let cross = t
            .links
            .iter()
            .filter(|l| matches!(l.kind, LinkKind::CrossRail { .. }))
            .count();
        assert_eq!(cross, 24);
    }

    #[test]
    fn lookup_tables_agree_with_links() {
        let t = Topology::paper();
        for l in &t.links {
            match l.kind {
                LinkKind::NvLink => {
                    assert_eq!(t.nvlink(l.src, l.dst), Some(l.id));
                }
                LinkKind::Rail { rail } => {
                    assert_eq!(t.rail(t.node_of(l.src), t.node_of(l.dst), rail), Some(l.id));
                    assert_eq!(t.local_of(l.src), rail, "NIC r attaches to GPU r");
                    assert_eq!(t.local_of(l.dst), rail);
                }
                LinkKind::CrossRail { src_rail, dst_rail } => {
                    assert_eq!(
                        t.cross_rail(t.node_of(l.src), t.node_of(l.dst), src_rail, dst_rail),
                        Some(l.id)
                    );
                }
                _ => panic!("no switch links on a flat fabric"),
            }
        }
    }

    #[test]
    fn no_self_or_cross_node_nvlink() {
        let t = Topology::paper();
        assert_eq!(t.nvlink(0, 0), None);
        assert_eq!(t.nvlink(0, 4), None); // gpu 4 is on node 1
        assert!(t.nvlink(0, 3).is_some());
    }

    #[test]
    fn capacities() {
        let t = Topology::paper();
        for l in &t.links {
            match l.kind {
                LinkKind::NvLink => assert_eq!(l.cap_gbps, NVLINK_GBPS),
                LinkKind::Rail { .. } => assert_eq!(l.cap_gbps, RAIL_GBPS),
                LinkKind::CrossRail { .. } => {
                    assert!((l.cap_gbps - RAIL_GBPS * CROSS_RAIL_FACTOR).abs() < 1e-9)
                }
                _ => panic!("no switch links on a flat fabric"),
            }
        }
    }

    #[test]
    fn gpu_id_arithmetic() {
        let t = Topology::hgx(3, 4, 4);
        assert_eq!(t.gpu(2, 1), 9);
        assert_eq!(t.node_of(9), 2);
        assert_eq!(t.local_of(9), 1);
        assert!(t.same_node(8, 11));
        assert!(!t.same_node(7, 8));
    }

    /// The `nimble scale` axis: N × (8 GPU + 4 NIC) nodes.
    #[test]
    fn cluster_topology_counts_and_home_rails() {
        let t = Topology::cluster(4);
        assert_eq!(t.num_gpus(), 32);
        assert_eq!(t.nics_per_node, 4);
        let nv = t.links.iter().filter(|l| l.kind == LinkKind::NvLink).count();
        assert_eq!(nv, 4 * 8 * 7);
        let rails =
            t.links.iter().filter(|l| matches!(l.kind, LinkKind::Rail { .. })).count();
        assert_eq!(rails, 4 * 3 * 4); // ordered node pairs × rails
        // NIC r attaches to GPU r; GPUs 4..8 share rails round-robin
        for l in &t.links {
            if let LinkKind::Rail { rail } = l.kind {
                assert_eq!(t.local_of(l.src), rail);
                assert_eq!(t.local_of(l.dst), rail);
            }
        }
        assert_eq!(t.home_rail(0), 0);
        assert_eq!(t.home_rail(5), 1);
        assert_eq!(t.home_rail(8 + 7), 3);
        // on the paper layout home_rail degenerates to the local index
        let p = Topology::paper();
        for g in 0..p.num_gpus() {
            assert_eq!(p.home_rail(g), p.local_of(g));
        }
    }

    #[test]
    #[should_panic(expected = "rail-matched layout")]
    fn nic_count_must_divide_gpu_count() {
        let _ = Topology::build(2, 8, 3, NVLINK_GBPS, RAIL_GBPS, true);
    }

    #[test]
    fn out_links_of_gpu0() {
        let t = Topology::paper();
        // GPU 0 on node 0: 3 nvlink out + 1 rail out (to node 1, rail 0)
        // + 3 cross-rail out (to node 1 rails 1..3).
        assert_eq!(t.out_links(0).count(), 7);
    }

    #[test]
    fn fat_tree_counts_and_vertices() {
        let t = Topology::fat_tree(8, 2.0);
        let tier = t.tier.as_ref().unwrap();
        assert_eq!((tier.pod_size, tier.pods, tier.spines_per_rail), (4, 2, 2));
        assert_eq!(t.num_switches(), 2 * 4 + 4 * 2); // 8 leaves + 8 spines
        assert_eq!(t.num_gpus(), 64);
        assert!(t.is_switch(64) && !t.is_switch(63));
        // per-edge uplink cap: pod_size·rail / (S·oversub) = 4·45.1/4
        assert!((tier.uplink_gbps - RAIL_GBPS).abs() < 1e-9);
        // no flat rails or cross-rails on a tiered fabric
        assert!(t.rail(0, 1, 0).is_none());
        assert!(t.cross_rail(0, 1, 0, 1).is_none());
        let nic = t
            .links
            .iter()
            .filter(|l| matches!(l.kind, LinkKind::LeafUp { .. } | LinkKind::LeafDown { .. }))
            .count();
        assert_eq!(nic, 8 * 4 * 2);
        let core = t
            .links
            .iter()
            .filter(|l| matches!(l.kind, LinkKind::SpineUp { .. } | LinkKind::SpineDown { .. }))
            .count();
        assert_eq!(core, 2 * 4 * 2 * 2); // pods × rails × spines × both dirs
    }

    #[test]
    fn fat_tree_lookup_tables_agree_with_links() {
        let t = Topology::fat_tree(8, 2.0);
        for l in &t.links {
            match l.kind {
                LinkKind::NvLink => assert_eq!(t.nvlink(l.src, l.dst), Some(l.id)),
                LinkKind::LeafUp { rail } => {
                    let n = t.node_of(l.src);
                    assert_eq!(t.local_of(l.src), rail, "NIC r attaches to GPU r");
                    assert_eq!(t.leaf_up(n, rail), Some(l.id));
                    assert_eq!(l.dst, t.leaf_id(t.pod_of(n), rail));
                }
                LinkKind::LeafDown { rail } => {
                    let n = t.node_of(l.dst);
                    assert_eq!(t.leaf_down(n, rail), Some(l.id));
                    assert_eq!(l.src, t.leaf_id(t.pod_of(n), rail));
                }
                LinkKind::SpineUp { rail, spine } => {
                    let pod = (l.src - t.num_gpus()) / t.nics_per_node;
                    assert_eq!(t.spine_up(pod, rail, spine), Some(l.id));
                    assert_eq!(l.dst, t.spine_id(rail, spine));
                }
                LinkKind::SpineDown { rail, spine } => {
                    let pod = (l.dst - t.num_gpus()) / t.nics_per_node;
                    assert_eq!(t.spine_down(pod, rail, spine), Some(l.id));
                    assert_eq!(l.src, t.spine_id(rail, spine));
                }
                _ => panic!("flat rail link on a tiered fabric"),
            }
        }
    }

    #[test]
    fn nic_charge_helpers_match_flat_rule() {
        // Flat: charge both ends of every non-NVLink link — the rule
        // the fabric backends used before the tier existed.
        let t = Topology::paper();
        for l in &t.links {
            let is_net = !matches!(l.kind, LinkKind::NvLink);
            assert_eq!(t.nic_out_node(l), is_net.then_some(t.node_of(l.src)));
            assert_eq!(t.nic_in_node(l), is_net.then_some(t.node_of(l.dst)));
        }
        // Tiered: NIC edges charge their node-side end only; core
        // links charge no node.
        let ft = Topology::fat_tree(8, 2.0);
        for l in &ft.links {
            match l.kind {
                LinkKind::LeafUp { .. } => {
                    assert_eq!(ft.nic_out_node(l), Some(ft.node_of(l.src)));
                    assert_eq!(ft.nic_in_node(l), None);
                }
                LinkKind::LeafDown { .. } => {
                    assert_eq!(ft.nic_out_node(l), None);
                    assert_eq!(ft.nic_in_node(l), Some(ft.node_of(l.dst)));
                }
                LinkKind::SpineUp { .. } | LinkKind::SpineDown { .. } => {
                    assert_eq!(ft.nic_out_node(l), None);
                    assert_eq!(ft.nic_in_node(l), None);
                }
                _ => {}
            }
        }
    }

    #[test]
    fn fat_tree_pod_sizes_divide_nodes() {
        assert_eq!(Topology::fat_tree(64, 2.0).tier.as_ref().unwrap().pods, 16);
        assert_eq!(Topology::fat_tree(2, 1.0).tier.as_ref().unwrap().pod_size, 2);
        assert_eq!(Topology::fat_tree(3, 1.0).tier.as_ref().unwrap().pod_size, 1);
    }

    #[test]
    #[should_panic(expected = "oversubscription")]
    fn fat_tree_rejects_sub_unit_oversub() {
        let _ = Topology::fat_tree(8, 0.5);
    }
}
