//! Cluster topology model: nodes with all-to-all NVLink-connected GPUs
//! and rail-matched NICs (one NIC per GPU, NIC *i* ↔ GPU *i*), plus
//! inter-node rail links. This is the graph over which the planner
//! (Algorithm 1) routes and the fabric simulator schedules flows.
//!
//! Matches the paper's testbed shape (§V-A): per node, 4× H100 with
//! all-to-all NVLink4 and 4× NDR400 HCAs, one per GPU. The topology is
//! parametric so larger/smaller configurations are first-class.

pub mod path;

pub use path::{Path, PathKind};

/// Global GPU index: `node * gpus_per_node + local`.
pub type GpuId = usize;
/// Index into `Topology::links`.
pub type LinkId = usize;

/// Directed communication link.
#[derive(Clone, Debug, PartialEq)]
pub struct Link {
    pub id: LinkId,
    pub kind: LinkKind,
    /// Source GPU (for rail links: the GPU the source NIC is attached to).
    pub src: GpuId,
    /// Destination GPU.
    pub dst: GpuId,
    /// Capacity in GB/s (effective, large-message).
    pub cap_gbps: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Intra-node GPU↔GPU NVLink edge.
    NvLink,
    /// Inter-node rail-matched NIC↔NIC edge (rail r of node a → rail r
    /// of node b). Endpoints are expressed as the rail-attached GPUs.
    Rail { rail: usize },
    /// Inter-node rail-MISmatched NIC edge (crosses a switch tier);
    /// only baselines that ignore rail matching use these. Carries a
    /// capacity penalty.
    CrossRail { src_rail: usize, dst_rail: usize },
}

/// Static description of the cluster fabric.
#[derive(Clone, Debug)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// NICs per node. NIC *i* attaches to GPU *i*, so the count must
    /// divide `gpus_per_node`; the paper's testbed has one NIC per GPU,
    /// the `nimble scale` cluster axis runs 8 GPUs over 4 NICs.
    pub nics_per_node: usize,
    pub links: Vec<Link>,
    /// NVLink effective capacity (GB/s) per directed edge.
    pub nvlink_gbps: f64,
    /// Rail (NIC) effective capacity (GB/s) per directed edge.
    pub rail_gbps: f64,
    /// Penalty factor applied to cross-rail (mismatched) edges.
    pub cross_rail_factor: f64,
    /// DGX-style NVSwitch fabric (paper §VII): every GPU has a single
    /// uplink into a central switch, so intra-node 2-hop forwarding is
    /// impossible — the only link a relay could use is already taken
    /// by the direct path. Inter-node multi-rail balancing still works.
    pub nvswitch: bool,
    // ---- O(1) link lookup tables ----
    nvlink_idx: Vec<Vec<Vec<Option<LinkId>>>>, // [node][src_local][dst_local]
    rail_idx: Vec<Vec<Vec<Option<LinkId>>>>,   // [src_node][dst_node][rail]
    cross_idx: Vec<Vec<Vec<Vec<Option<LinkId>>>>>, // [src_node][dst_node][sr][dr]
}

/// Effective large-message capacities measured on the paper's testbed
/// (§V-B): 120 GB/s per direct NVLink path, 45.1 GB/s per NDR400 rail.
pub const NVLINK_GBPS: f64 = 120.0;
pub const RAIL_GBPS: f64 = 45.1;
/// Switch-tier penalty for rail-mismatched traffic (baselines only).
pub const CROSS_RAIL_FACTOR: f64 = 0.72;

impl Topology {
    /// The paper's testbed: `hgx(2, 4, 4)` = 2 nodes × (4 GPU + 4 NIC).
    pub fn hgx(nodes: usize, gpus_per_node: usize, nics_per_node: usize) -> Topology {
        Self::build(nodes, gpus_per_node, nics_per_node, NVLINK_GBPS, RAIL_GBPS, true)
    }

    /// Paper evaluation config: 2 nodes, 4 GPUs + 4 NICs each.
    pub fn paper() -> Topology {
        Self::hgx(2, 4, 4)
    }

    /// The cluster-scale axis used by `nimble scale`: `nodes` × (8 GPUs
    /// + 4 NICs). With fewer NICs than GPUs, NIC *r* stays attached to
    /// GPU *r* and the NIC-less GPUs reach the network through an
    /// NVLink hop to their [`Topology::home_rail`] GPU — the same
    /// PXN-style forwarding the planner's inter-node candidates already
    /// model.
    pub fn cluster(nodes: usize) -> Topology {
        Self::build(nodes, 8, 4, NVLINK_GBPS, RAIL_GBPS, true)
    }

    /// DGX-like NVSwitch variant (paper §VII "Limitations"): same
    /// node/GPU/NIC counts, but intra-node connectivity goes through a
    /// central NVSwitch — direct paths only, no GPU relaying inside a
    /// node. Used by `nimble ablate`-adjacent experiments to reproduce
    /// the paper's observation that only inter-node multi-NIC
    /// balancing remains available there.
    pub fn dgx_nvswitch(nodes: usize, gpus_per_node: usize, nics_per_node: usize) -> Topology {
        let mut t = Self::hgx(nodes, gpus_per_node, nics_per_node);
        t.nvswitch = true;
        t
    }

    /// Fully parametric constructor. `with_cross_rail` adds the
    /// mismatched-rail edges used by baselines.
    pub fn build(
        nodes: usize,
        gpus_per_node: usize,
        nics_per_node: usize,
        nvlink_gbps: f64,
        rail_gbps: f64,
        with_cross_rail: bool,
    ) -> Topology {
        assert!(nodes >= 1 && gpus_per_node >= 1);
        assert!(
            nics_per_node >= 1
                && nics_per_node <= gpus_per_node
                && gpus_per_node % nics_per_node == 0,
            "rail-matched layout requires NIC count to divide the GPU count \
             (NIC r attaches to GPU r; paper §IV-B)"
        );
        let mut links = Vec::new();
        let mut nvlink_idx =
            vec![vec![vec![None; gpus_per_node]; gpus_per_node]; nodes];
        let mut rail_idx = vec![vec![vec![None; nics_per_node]; nodes]; nodes];
        let mut cross_idx =
            vec![vec![vec![vec![None; nics_per_node]; nics_per_node]; nodes]; nodes];

        // Intra-node all-to-all NVLink mesh (directed edges).
        for n in 0..nodes {
            for i in 0..gpus_per_node {
                for j in 0..gpus_per_node {
                    if i == j {
                        continue;
                    }
                    let id = links.len();
                    links.push(Link {
                        id,
                        kind: LinkKind::NvLink,
                        src: n * gpus_per_node + i,
                        dst: n * gpus_per_node + j,
                        cap_gbps: nvlink_gbps,
                    });
                    nvlink_idx[n][i][j] = Some(id);
                }
            }
        }
        // Inter-node rail-matched NIC edges.
        for a in 0..nodes {
            for b in 0..nodes {
                if a == b {
                    continue;
                }
                for r in 0..nics_per_node {
                    let id = links.len();
                    links.push(Link {
                        id,
                        kind: LinkKind::Rail { rail: r },
                        src: a * gpus_per_node + r,
                        dst: b * gpus_per_node + r,
                        cap_gbps: rail_gbps,
                    });
                    rail_idx[a][b][r] = Some(id);
                }
                if with_cross_rail {
                    for sr in 0..nics_per_node {
                        for dr in 0..nics_per_node {
                            if sr == dr {
                                continue;
                            }
                            let id = links.len();
                            links.push(Link {
                                id,
                                kind: LinkKind::CrossRail { src_rail: sr, dst_rail: dr },
                                src: a * gpus_per_node + sr,
                                dst: b * gpus_per_node + dr,
                                cap_gbps: rail_gbps * CROSS_RAIL_FACTOR,
                            });
                            cross_idx[a][b][sr][dr] = Some(id);
                        }
                    }
                }
            }
        }
        Topology {
            nodes,
            gpus_per_node,
            nics_per_node,
            links,
            nvlink_gbps,
            rail_gbps,
            cross_rail_factor: CROSS_RAIL_FACTOR,
            nvswitch: false,
            nvlink_idx,
            rail_idx,
            cross_idx,
        }
    }

    pub fn num_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    pub fn node_of(&self, g: GpuId) -> usize {
        g / self.gpus_per_node
    }

    pub fn local_of(&self, g: GpuId) -> usize {
        g % self.gpus_per_node
    }

    pub fn gpu(&self, node: usize, local: usize) -> GpuId {
        node * self.gpus_per_node + local
    }

    pub fn same_node(&self, a: GpuId, b: GpuId) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// The rail a GPU has NIC affinity with. On the paper's one-NIC-
    /// per-GPU layout this is just the local index; on wider nodes
    /// (e.g. [`Topology::cluster`]'s 8 GPU / 4 NIC) GPUs without their
    /// own NIC map onto the rails round-robin.
    pub fn home_rail(&self, g: GpuId) -> usize {
        self.local_of(g) % self.nics_per_node
    }

    /// NVLink edge between two GPUs on the same node.
    pub fn nvlink(&self, src: GpuId, dst: GpuId) -> Option<LinkId> {
        if !self.same_node(src, dst) || src == dst {
            return None;
        }
        self.nvlink_idx[self.node_of(src)][self.local_of(src)][self.local_of(dst)]
    }

    /// Rail-matched inter-node edge on rail `r`.
    pub fn rail(&self, src_node: usize, dst_node: usize, r: usize) -> Option<LinkId> {
        if src_node == dst_node {
            return None;
        }
        self.rail_idx[src_node][dst_node][r]
    }

    /// Cross-rail (mismatched) inter-node edge.
    pub fn cross_rail(
        &self,
        src_node: usize,
        dst_node: usize,
        sr: usize,
        dr: usize,
    ) -> Option<LinkId> {
        if src_node == dst_node || sr == dr {
            return None;
        }
        self.cross_idx[src_node][dst_node][sr][dr]
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id]
    }

    /// All links a GPU injects into (used for per-endpoint load bounds).
    pub fn out_links(&self, g: GpuId) -> impl Iterator<Item = &Link> {
        self.links.iter().filter(move |l| l.src == g)
    }

    pub fn in_links(&self, g: GpuId) -> impl Iterator<Item = &Link> {
        self.links.iter().filter(move |l| l.dst == g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topology_counts() {
        let t = Topology::paper();
        assert_eq!(t.num_gpus(), 8);
        // per node: 4*3 = 12 nvlink edges, ×2 nodes = 24
        let nv = t.links.iter().filter(|l| l.kind == LinkKind::NvLink).count();
        assert_eq!(nv, 24);
        // rails: 2 ordered node pairs × 4 rails = 8
        let rails =
            t.links.iter().filter(|l| matches!(l.kind, LinkKind::Rail { .. })).count();
        assert_eq!(rails, 8);
        // cross rails: 2 × 4×3 = 24
        let cross = t
            .links
            .iter()
            .filter(|l| matches!(l.kind, LinkKind::CrossRail { .. }))
            .count();
        assert_eq!(cross, 24);
    }

    #[test]
    fn lookup_tables_agree_with_links() {
        let t = Topology::paper();
        for l in &t.links {
            match l.kind {
                LinkKind::NvLink => {
                    assert_eq!(t.nvlink(l.src, l.dst), Some(l.id));
                }
                LinkKind::Rail { rail } => {
                    assert_eq!(t.rail(t.node_of(l.src), t.node_of(l.dst), rail), Some(l.id));
                    assert_eq!(t.local_of(l.src), rail, "NIC r attaches to GPU r");
                    assert_eq!(t.local_of(l.dst), rail);
                }
                LinkKind::CrossRail { src_rail, dst_rail } => {
                    assert_eq!(
                        t.cross_rail(t.node_of(l.src), t.node_of(l.dst), src_rail, dst_rail),
                        Some(l.id)
                    );
                }
            }
        }
    }

    #[test]
    fn no_self_or_cross_node_nvlink() {
        let t = Topology::paper();
        assert_eq!(t.nvlink(0, 0), None);
        assert_eq!(t.nvlink(0, 4), None); // gpu 4 is on node 1
        assert!(t.nvlink(0, 3).is_some());
    }

    #[test]
    fn capacities() {
        let t = Topology::paper();
        for l in &t.links {
            match l.kind {
                LinkKind::NvLink => assert_eq!(l.cap_gbps, NVLINK_GBPS),
                LinkKind::Rail { .. } => assert_eq!(l.cap_gbps, RAIL_GBPS),
                LinkKind::CrossRail { .. } => {
                    assert!((l.cap_gbps - RAIL_GBPS * CROSS_RAIL_FACTOR).abs() < 1e-9)
                }
            }
        }
    }

    #[test]
    fn gpu_id_arithmetic() {
        let t = Topology::hgx(3, 4, 4);
        assert_eq!(t.gpu(2, 1), 9);
        assert_eq!(t.node_of(9), 2);
        assert_eq!(t.local_of(9), 1);
        assert!(t.same_node(8, 11));
        assert!(!t.same_node(7, 8));
    }

    /// The `nimble scale` axis: N × (8 GPU + 4 NIC) nodes.
    #[test]
    fn cluster_topology_counts_and_home_rails() {
        let t = Topology::cluster(4);
        assert_eq!(t.num_gpus(), 32);
        assert_eq!(t.nics_per_node, 4);
        let nv = t.links.iter().filter(|l| l.kind == LinkKind::NvLink).count();
        assert_eq!(nv, 4 * 8 * 7);
        let rails =
            t.links.iter().filter(|l| matches!(l.kind, LinkKind::Rail { .. })).count();
        assert_eq!(rails, 4 * 3 * 4); // ordered node pairs × rails
        // NIC r attaches to GPU r; GPUs 4..8 share rails round-robin
        for l in &t.links {
            if let LinkKind::Rail { rail } = l.kind {
                assert_eq!(t.local_of(l.src), rail);
                assert_eq!(t.local_of(l.dst), rail);
            }
        }
        assert_eq!(t.home_rail(0), 0);
        assert_eq!(t.home_rail(5), 1);
        assert_eq!(t.home_rail(8 + 7), 3);
        // on the paper layout home_rail degenerates to the local index
        let p = Topology::paper();
        for g in 0..p.num_gpus() {
            assert_eq!(p.home_rail(g), p.local_of(g));
        }
    }

    #[test]
    #[should_panic(expected = "rail-matched layout")]
    fn nic_count_must_divide_gpu_count() {
        let _ = Topology::build(2, 8, 3, NVLINK_GBPS, RAIL_GBPS, true);
    }

    #[test]
    fn out_links_of_gpu0() {
        let t = Topology::paper();
        // GPU 0 on node 0: 3 nvlink out + 1 rail out (to node 1, rail 0)
        // + 3 cross-rail out (to node 1 rails 1..3).
        assert_eq!(t.out_links(0).count(), 7);
    }
}
