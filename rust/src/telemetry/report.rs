//! `nimble report <trace.jsonl> [--check]` — render and validate a
//! recorded telemetry trace (schema in the [module docs](super)).
//!
//! The renderer reconstructs, **from the trace alone**: per-run epoch
//! time-series tables, a text per-link utilization heatmap, per-tenant
//! goodput/p99 rows, fault-recovery curves, and the headline tables of
//! `nimble replan`/`faults`/`serve`. `--check` additionally validates
//! the schema and *recomputes* every derived headline number from the
//! raw ingredients in the trace — goodput from payload/makespan,
//! retention from the clean-arm denominator, time-to-recover by
//! re-running [`recovery_epochs`] over the recorded goodput series —
//! and asserts **bit-equality** with the recorded values (the
//! shortest-roundtrip float policy of [`crate::util::json`] makes that
//! exact, not approximate). It also gates the congestion objective:
//! a faulted run that replanned must see its capacity-normalized
//! max-congestion recover to ≤ 1.1× the pre-fault level.

use crate::coordinator::replan::EpochStat;
use crate::exp::faults::recovery_epochs;
use crate::metrics::Table;
use crate::util::json::Json;

/// Every kind the schema defines, with the fields a valid line of that
/// kind must carry (`--check` schema validation).
const REQUIRED: &[(&str, &[&str])] = &[
    (
        "meta",
        &["schema", "subcommand", "backend", "scheduler", "threads", "topo", "nodes", "links", "gpus"],
    ),
    ("run", &["run", "cadence_s", "t0_s", "payload_bytes"]),
    (
        "epoch",
        &[
            "run",
            "epoch",
            "t_s",
            "goodput_gbps",
            "congestion",
            "deviation",
            "replanned",
            "preempted",
            "util",
        ],
    ),
    (
        "decision",
        &[
            "run",
            "t_s",
            "tenant",
            "accepted",
            "forced",
            "z_carry",
            "z_challenger",
            "margin",
            "mwu_visits",
            "changed_pairs",
        ],
    ),
    ("fault", &["run", "t_s", "desc"]),
    ("admit", &["run", "t_s", "tenant", "tenant_kind", "weight", "payload_bytes", "channels"]),
    (
        "tenant",
        &[
            "run",
            "tenant",
            "tenant_kind",
            "weight",
            "admit_s",
            "finish_s",
            "payload_bytes",
            "goodput_gbps",
            "p99_lat_s",
            "p99_chunk_s",
        ],
    ),
    (
        "summary",
        &["run", "makespan_s", "payload_bytes", "goodput_gbps", "replans", "preemptions", "sim_events"],
    ),
    (
        "fault_row",
        &[
            "run",
            "topo",
            "scenario",
            "arm",
            "goodput_gbps",
            "clean_gbps",
            "retention",
            "ttr_epochs",
            "ttr_ms",
            "replans",
            "preemptions",
        ],
    ),
    (
        "profile",
        &[
            "run",
            "events",
            "sched_pushes",
            "sched_pops",
            "solver_invocations",
            "mwu_plans",
            "mwu_visits",
            "plan_wall_s",
            "sim_wall_s",
        ],
    ),
    ("attribution", &["run", "t_s", "epoch", "links"]),
    (
        "histogram",
        &["run", "scope", "total", "max_ns", "buckets", "p50_ns", "p95_ns", "p99_ns"],
    ),
    ("note", &["text"]),
];

/// Congestion must recover to ≤ this × the pre-fault level after a
/// replanned epoch (the `--check` recovery gate, CI smoke).
pub const CONGESTION_RECOVERY_FACTOR: f64 = 1.1;

/// A parsed trace: one [`Json`] object per line, in file order.
/// Lines whose `kind` this build does not know are **skipped** at
/// parse time and counted in [`Trace::unknown_kinds`] — a trace
/// written by a newer schema stays readable (forward compatibility);
/// `--check` surfaces the count as a warning, not an error.
pub struct Trace {
    pub lines: Vec<Json>,
    /// Well-formed lines dropped because their `kind` is unknown.
    pub unknown_kinds: usize,
}

/// One labeled run's records, regrouped from the flat line stream.
struct RunView {
    label: String,
    cadence_s: f64,
    t0_s: f64,
    epochs: Vec<Json>,
    decisions: Vec<Json>,
    faults: Vec<Json>,
    admits: Vec<Json>,
    tenants: Vec<Json>,
    summaries: Vec<Json>,
    profiles: Vec<Json>,
}

impl Trace {
    /// Parse JSONL text; fails on the first malformed line. Lines
    /// carrying an unknown `kind` are skipped and counted (lines with
    /// no `kind` at all are kept so `--check` can flag them).
    pub fn parse(text: &str) -> Result<Trace, String> {
        let mut lines = Vec::new();
        let mut unknown_kinds = 0usize;
        let mut total = 0usize;
        for (i, raw) in text.lines().enumerate() {
            if raw.trim().is_empty() {
                continue;
            }
            let j = Json::parse(raw).map_err(|e| format!("line {}: {}", i + 1, e))?;
            total += 1;
            match j.get("kind").as_str() {
                Some(k) if !REQUIRED.iter().any(|(known, _)| *known == k) => {
                    unknown_kinds += 1;
                }
                _ => lines.push(j),
            }
        }
        if total == 0 {
            return Err("empty trace".to_string());
        }
        Ok(Trace { lines, unknown_kinds })
    }

    /// Read and parse a trace file.
    pub fn load(path: &str) -> Result<Trace, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
        Trace::parse(&text)
    }

    pub(crate) fn kind_lines(&self, kind: &str) -> impl Iterator<Item = &Json> {
        let k = kind.to_string();
        self.lines.iter().filter(move |l| l.get("kind").as_str() == Some(k.as_str()))
    }

    /// Group run-scoped records by label, in first-appearance order.
    fn runs(&self) -> Vec<RunView> {
        let mut order: Vec<String> = Vec::new();
        let mut views: Vec<RunView> = Vec::new();
        for l in &self.lines {
            let kind = l.get("kind").as_str().unwrap_or("");
            let label = match l.get("run").as_str() {
                Some(r) if !r.is_empty() => r.to_string(),
                _ => continue,
            };
            let idx = match order.iter().position(|o| *o == label) {
                Some(i) => i,
                None => {
                    order.push(label.clone());
                    views.push(RunView {
                        label,
                        cadence_s: 0.0,
                        t0_s: -1.0,
                        epochs: Vec::new(),
                        decisions: Vec::new(),
                        faults: Vec::new(),
                        admits: Vec::new(),
                        tenants: Vec::new(),
                        summaries: Vec::new(),
                        profiles: Vec::new(),
                    });
                    order.len() - 1
                }
            };
            let v = &mut views[idx];
            match kind {
                "run" => {
                    v.cadence_s = l.get("cadence_s").as_f64().unwrap_or(0.0);
                    v.t0_s = l.get("t0_s").as_f64().unwrap_or(-1.0);
                }
                "epoch" => v.epochs.push(l.clone()),
                "decision" => v.decisions.push(l.clone()),
                "fault" => v.faults.push(l.clone()),
                "admit" => v.admits.push(l.clone()),
                "tenant" => v.tenants.push(l.clone()),
                "summary" => v.summaries.push(l.clone()),
                "profile" => v.profiles.push(l.clone()),
                _ => {}
            }
        }
        views
    }
}

fn epoch_stats(epochs: &[Json]) -> Vec<EpochStat> {
    epochs
        .iter()
        .map(|e| EpochStat {
            t_s: e.get("t_s").as_f64().unwrap_or(0.0),
            deviation: e.get("deviation").as_f64().unwrap_or(0.0),
            replanned: e.get("replanned").as_bool().unwrap_or(false),
            preempted: e.get("preempted").as_f64().unwrap_or(0.0) as usize,
            goodput_gbps: e.get("goodput_gbps").as_f64().unwrap_or(0.0),
        })
        .collect()
}

fn heat_char(u: f64) -> char {
    const RAMP: &[u8] = b" .:-=+*#%@";
    if !(u > 0.0) {
        return ' ';
    }
    let i = ((u * (RAMP.len() - 1) as f64).ceil() as usize).min(RAMP.len() - 1);
    RAMP[i] as char
}

/// Text per-link utilization heatmap: one row per link that ever
/// carried traffic, one column per epoch (stride-sampled by window max
/// past `max_cols`, so congestion spikes survive the downsample).
fn heatmap(epochs: &[Json], max_cols: usize) -> String {
    let utils: Vec<Vec<f64>> = epochs
        .iter()
        .map(|e| {
            e.get("util")
                .as_arr()
                .map(|v| v.iter().map(|u| u.as_f64().unwrap_or(0.0)).collect())
                .unwrap_or_default()
        })
        .collect();
    let nl = utils.iter().map(|u| u.len()).max().unwrap_or(0);
    if nl == 0 || utils.is_empty() {
        return String::new();
    }
    let stride = utils.len().div_ceil(max_cols);
    let cols = utils.len().div_ceil(stride);
    let mut out = String::new();
    out.push_str(&format!(
        "  per-link utilization (rows=links, cols=epochs ×{stride}, ramp \" .:-=+*#%@\" = 0..≥1):\n"
    ));
    for link in 0..nl {
        let mut row = String::new();
        let mut any = false;
        for c in 0..cols {
            let m = utils[c * stride..((c + 1) * stride).min(utils.len())]
                .iter()
                .map(|u| u.get(link).copied().unwrap_or(0.0))
                .fold(0.0f64, f64::max);
            any |= m > 0.0;
            row.push(heat_char(m));
        }
        if any {
            out.push_str(&format!("  link {link:>4} |{row}|\n"));
        }
    }
    out
}

fn fmt_ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

fn fmt_opt(x: f64) -> String {
    if x < 0.0 { "—".to_string() } else { format!("{x:.2}") }
}

/// Render the human-readable report (every section the trace has data
/// for; sections with no records are skipped).
pub fn render(trace: &Trace) -> String {
    let mut out = String::new();
    for m in trace.kind_lines("meta") {
        out.push_str(&format!(
            "trace: schema v{} · nimble {} · backend {} ({} sched, {} threads) · topo {} ({} nodes, {} links, {} gpus)\n",
            m.get("schema").as_u64().unwrap_or(0),
            m.get("subcommand").as_str().unwrap_or("?"),
            m.get("backend").as_str().unwrap_or("?"),
            m.get("scheduler").as_str().unwrap_or("?"),
            m.get("threads").as_u64().unwrap_or(0),
            m.get("topo").as_str().unwrap_or("?"),
            m.get("nodes").as_u64().unwrap_or(0),
            m.get("links").as_u64().unwrap_or(0),
            m.get("gpus").as_u64().unwrap_or(0),
        ));
    }
    for n in trace.kind_lines("note") {
        out.push_str(&format!("note: {}\n", n.get("text").as_str().unwrap_or("")));
    }

    for run in trace.runs() {
        out.push_str(&format!("\n== run {} ==\n", run.label));
        if run.t0_s >= 0.0 {
            out.push_str(&format!(
                "  cadence {} ms, first fault at {} ms\n",
                fmt_ms(run.cadence_s),
                fmt_ms(run.t0_s)
            ));
        }

        if !run.epochs.is_empty() {
            let mut t = Table::new(&[
                "epoch",
                "t_ms",
                "goodput_gbps",
                "congestion",
                "deviation",
                "replanned",
                "preempted",
            ]);
            for e in &run.epochs {
                t.row(&[
                    format!("{}", e.get("epoch").as_u64().unwrap_or(0)),
                    fmt_ms(e.get("t_s").as_f64().unwrap_or(0.0)),
                    format!("{:.1}", e.get("goodput_gbps").as_f64().unwrap_or(0.0)),
                    format!("{:.3}", e.get("congestion").as_f64().unwrap_or(0.0)),
                    format!("{:.3}", e.get("deviation").as_f64().unwrap_or(0.0)),
                    format!("{}", e.get("replanned").as_bool().unwrap_or(false)),
                    format!("{}", e.get("preempted").as_u64().unwrap_or(0)),
                ]);
            }
            out.push_str(&t.render());
            out.push_str(&heatmap(&run.epochs, 72));
        }

        if !run.decisions.is_empty() {
            let mut t = Table::new(&[
                "t_ms", "tenant", "accepted", "forced", "z_carry", "z_chall", "margin",
                "mwu_visits", "changed",
            ]);
            for d in &run.decisions {
                let tenant = d.get("tenant").as_f64().unwrap_or(-1.0);
                t.row(&[
                    fmt_ms(d.get("t_s").as_f64().unwrap_or(0.0)),
                    if tenant < 0.0 { "—".to_string() } else { format!("{tenant:.0}") },
                    format!("{}", d.get("accepted").as_bool().unwrap_or(false)),
                    format!("{}", d.get("forced").as_bool().unwrap_or(false)),
                    format!("{:.3e}", d.get("z_carry").as_f64().unwrap_or(0.0)),
                    format!("{:.3e}", d.get("z_challenger").as_f64().unwrap_or(0.0)),
                    format!("{:.2}", d.get("margin").as_f64().unwrap_or(0.0)),
                    format!("{}", d.get("mwu_visits").as_u64().unwrap_or(0)),
                    format!("{}", d.get("changed_pairs").as_u64().unwrap_or(0)),
                ]);
            }
            out.push_str("  planner decisions:\n");
            out.push_str(&t.render());
        }

        for f in &run.faults {
            out.push_str(&format!(
                "  fault @ {} ms: {}\n",
                fmt_ms(f.get("t_s").as_f64().unwrap_or(0.0)),
                f.get("desc").as_str().unwrap_or("?")
            ));
        }
        for a in &run.admits {
            out.push_str(&format!(
                "  admit @ {} ms: tenant {} ({}, w={}, {:.0} MB, {} ch)\n",
                fmt_ms(a.get("t_s").as_f64().unwrap_or(0.0)),
                a.get("tenant").as_u64().unwrap_or(0),
                a.get("tenant_kind").as_str().unwrap_or("?"),
                a.get("weight").as_f64().unwrap_or(0.0),
                a.get("payload_bytes").as_f64().unwrap_or(0.0) / (1024.0 * 1024.0),
                a.get("channels").as_u64().unwrap_or(0),
            ));
        }

        if !run.tenants.is_empty() {
            let mut t = Table::new(&[
                "tenant",
                "kind",
                "weight",
                "admit_ms",
                "finish_ms",
                "goodput_gbps",
                "p99_lat_us",
                "p99_chunk_us",
            ]);
            for r in &run.tenants {
                let p99c = r.get("p99_chunk_s").as_f64().unwrap_or(-1.0);
                t.row(&[
                    format!("{}", r.get("tenant").as_u64().unwrap_or(0)),
                    r.get("tenant_kind").as_str().unwrap_or("?").to_string(),
                    format!("{:.1}", r.get("weight").as_f64().unwrap_or(0.0)),
                    fmt_ms(r.get("admit_s").as_f64().unwrap_or(0.0)),
                    fmt_ms(r.get("finish_s").as_f64().unwrap_or(0.0)),
                    format!("{:.1}", r.get("goodput_gbps").as_f64().unwrap_or(0.0)),
                    format!("{:.1}", r.get("p99_lat_s").as_f64().unwrap_or(0.0) * 1e6),
                    fmt_opt(if p99c < 0.0 { p99c } else { p99c * 1e6 }),
                ]);
            }
            out.push_str("  per-tenant series:\n");
            out.push_str(&t.render());
        }

        // recovery curve: goodput relative to pre-fault steady state
        if run.t0_s >= 0.0 && !run.epochs.is_empty() {
            let stats = epoch_stats(&run.epochs);
            if let Some(bidx) =
                stats.iter().position(|e| e.t_s >= run.t0_s - 0.5 * run.cadence_s)
            {
                let pre = &stats[..=bidx];
                let steady =
                    pre.iter().map(|e| e.goodput_gbps).sum::<f64>() / pre.len() as f64;
                if steady > 0.0 {
                    let ttr = recovery_epochs(&stats, run.t0_s, run.cadence_s);
                    let curve: Vec<String> = stats[bidx + 1..]
                        .iter()
                        .take(12)
                        .enumerate()
                        .map(|(k, e)| {
                            format!("+{}:{:.0}%", k + 1, 100.0 * e.goodput_gbps / steady)
                        })
                        .collect();
                    out.push_str(&format!(
                        "  recovery: steady {:.1} GB/s pre-fault; {}{}\n",
                        steady,
                        curve.join(" "),
                        match ttr {
                            Some(n) => format!(
                                " → recovered in {} epochs ({} ms)",
                                n,
                                fmt_ms(n as f64 * run.cadence_s)
                            ),
                            None => " → never recovered".to_string(),
                        }
                    ));
                }
            }
        }

        for s in &run.summaries {
            out.push_str(&format!(
                "  summary: {:.1} GB/s ({:.0} MB over {} ms), {} replans, {} preemptions, {} sim events\n",
                s.get("goodput_gbps").as_f64().unwrap_or(0.0),
                s.get("payload_bytes").as_f64().unwrap_or(0.0) / (1024.0 * 1024.0),
                fmt_ms(s.get("makespan_s").as_f64().unwrap_or(0.0)),
                s.get("replans").as_u64().unwrap_or(0),
                s.get("preemptions").as_u64().unwrap_or(0),
                s.get("sim_events").as_u64().unwrap_or(0),
            ));
        }
        for p in &run.profiles {
            out.push_str(&format!(
                "  profile: {} events ({} pushes / {} pops / {} solves), MWU {} plans / {} visits, wall plan {:.1} ms sim {:.1} ms\n",
                p.get("events").as_u64().unwrap_or(0),
                p.get("sched_pushes").as_u64().unwrap_or(0),
                p.get("sched_pops").as_u64().unwrap_or(0),
                p.get("solver_invocations").as_u64().unwrap_or(0),
                p.get("mwu_plans").as_u64().unwrap_or(0),
                p.get("mwu_visits").as_u64().unwrap_or(0),
                p.get("plan_wall_s").as_f64().unwrap_or(0.0) * 1e3,
                p.get("sim_wall_s").as_f64().unwrap_or(0.0) * 1e3,
            ));
        }
    }

    let rows: Vec<&Json> = trace.kind_lines("fault_row").collect();
    if !rows.is_empty() {
        let mut t = Table::new(&[
            "topo",
            "scenario",
            "arm",
            "goodput_gbps",
            "retention",
            "ttr_epochs",
            "ttr_ms",
            "replans",
            "preempts",
        ]);
        for r in rows {
            let ttr = r.get("ttr_epochs").as_f64().unwrap_or(-1.0);
            t.row(&[
                r.get("topo").as_str().unwrap_or("?").to_string(),
                r.get("scenario").as_str().unwrap_or("?").to_string(),
                r.get("arm").as_str().unwrap_or("?").to_string(),
                format!("{:.1}", r.get("goodput_gbps").as_f64().unwrap_or(0.0)),
                format!("{:.3}", r.get("retention").as_f64().unwrap_or(0.0)),
                if ttr < 0.0 { "—".to_string() } else { format!("{ttr:.0}") },
                fmt_opt(r.get("ttr_ms").as_f64().unwrap_or(-1.0)),
                format!("{}", r.get("replans").as_u64().unwrap_or(0)),
                format!("{}", r.get("preemptions").as_u64().unwrap_or(0)),
            ]);
        }
        out.push_str("\n== faults headline (reproduced from trace) ==\n");
        out.push_str(&t.render());
    }
    out
}

/// `--check` outcome: every failed assertion, plus how many checks ran
/// (so an empty `errors` on zero checks can't masquerade as a pass).
/// `warnings` are forward-compatibility notices (unknown record kinds,
/// a newer schema version) — reported but not failing.
pub struct CheckOutcome {
    pub checks: usize,
    pub errors: Vec<String>,
    pub warnings: Vec<String>,
}

impl CheckOutcome {
    pub fn ok(&self) -> bool {
        self.errors.is_empty() && self.checks > 0
    }
}

/// Validate the schema and recompute every derived headline number
/// from the trace's raw ingredients (bit-equality, see module docs).
pub fn check(trace: &Trace) -> CheckOutcome {
    let mut checks = 0usize;
    let mut errors: Vec<String> = Vec::new();
    let mut warnings: Vec<String> = Vec::new();
    let mut err = |msg: String| errors.push(msg);
    if trace.unknown_kinds > 0 {
        warnings.push(format!(
            "{} line(s) of unknown kind skipped (trace written by a newer schema?)",
            trace.unknown_kinds
        ));
    }

    // -- schema: every line has a known kind carrying its required fields
    let mut metas = 0usize;
    for (i, l) in trace.lines.iter().enumerate() {
        checks += 1;
        let kind = match l.get("kind").as_str() {
            Some(k) => k,
            None => {
                err(format!("line {}: missing \"kind\"", i + 1));
                continue;
            }
        };
        match REQUIRED.iter().find(|(k, _)| *k == kind) {
            None => warnings.push(format!("line {}: unknown kind {kind:?}", i + 1)),
            Some((_, fields)) => {
                for f in *fields {
                    if matches!(l.get(f), Json::Null) {
                        err(format!("line {}: kind {kind:?} missing field {f:?}", i + 1));
                    }
                }
            }
        }
        if kind == "meta" {
            metas += 1;
            let schema = l.get("schema").as_u64();
            if schema > Some(super::SCHEMA_VERSION) {
                warnings.push(format!(
                    "line {}: schema version {:?} is newer than this build's {} — \
                     unknown records are skipped",
                    i + 1,
                    schema,
                    super::SCHEMA_VERSION
                ));
            } else if schema != Some(super::SCHEMA_VERSION) {
                err(format!(
                    "line {}: schema version {:?} != {}",
                    i + 1,
                    schema,
                    super::SCHEMA_VERSION
                ));
            }
        }
    }
    if metas == 0 {
        err("no meta line in trace".to_string());
    }

    // -- headline reproduction: summaries and tenants recompute bitwise
    for s in trace.kind_lines("summary") {
        checks += 1;
        let payload = s.get("payload_bytes").as_f64().unwrap_or(f64::NAN);
        let makespan = s.get("makespan_s").as_f64().unwrap_or(f64::NAN);
        let recorded = s.get("goodput_gbps").as_f64().unwrap_or(f64::NAN);
        let recomputed = payload / makespan.max(1e-12) / 1e9;
        if recomputed.to_bits() != recorded.to_bits() {
            err(format!(
                "summary (run {:?}): goodput {} != recomputed payload/makespan {}",
                s.get("run").as_str().unwrap_or(""),
                recorded,
                recomputed
            ));
        }
    }
    for t in trace.kind_lines("tenant") {
        checks += 1;
        let payload = t.get("payload_bytes").as_f64().unwrap_or(f64::NAN);
        let admit = t.get("admit_s").as_f64().unwrap_or(f64::NAN);
        let finish = t.get("finish_s").as_f64().unwrap_or(f64::NAN);
        let recorded = t.get("goodput_gbps").as_f64().unwrap_or(f64::NAN);
        let recomputed = payload / (finish - admit).max(1e-12) / 1e9;
        if recomputed.to_bits() != recorded.to_bits() {
            err(format!(
                "tenant {}: goodput {} != recomputed {}",
                t.get("tenant").as_u64().unwrap_or(0),
                recorded,
                recomputed
            ));
        }
    }

    // -- fault rows: retention and time-to-recover recompute from the
    //    run's recorded goodput series
    let runs = trace.runs();
    for r in trace.kind_lines("fault_row") {
        checks += 1;
        let goodput = r.get("goodput_gbps").as_f64().unwrap_or(f64::NAN);
        let clean = r.get("clean_gbps").as_f64().unwrap_or(f64::NAN);
        let recorded = r.get("retention").as_f64().unwrap_or(f64::NAN);
        let recomputed = goodput / clean.max(1e-12);
        let arm = r.get("arm").as_str().unwrap_or("?");
        if recomputed.to_bits() != recorded.to_bits() {
            err(format!(
                "fault_row {arm}: retention {recorded} != recomputed goodput/clean {recomputed}"
            ));
        }
        let label = r.get("run").as_str().unwrap_or("");
        let recorded_ttr = r.get("ttr_epochs").as_f64().unwrap_or(-1.0);
        if let Some(run) = runs.iter().find(|v| v.label == label) {
            if run.t0_s >= 0.0 && !run.epochs.is_empty() {
                checks += 1;
                let stats = epoch_stats(&run.epochs);
                let ttr = recovery_epochs(&stats, run.t0_s, run.cadence_s)
                    .map_or(-1.0, |n| n as f64);
                if ttr != recorded_ttr {
                    err(format!(
                        "fault_row {arm}: ttr_epochs {recorded_ttr} != recomputed {ttr} from the epoch series"
                    ));
                }
            }
        }
    }

    // -- congestion recovery gate: a faulted run that replanned must
    //    see max-congestion return to ≤ 1.1× the pre-fault level
    for run in &runs {
        if run.t0_s < 0.0 || run.epochs.is_empty() {
            continue;
        }
        let stats = epoch_stats(&run.epochs);
        let cong: Vec<f64> =
            run.epochs.iter().map(|e| e.get("congestion").as_f64().unwrap_or(0.0)).collect();
        let bidx = match stats.iter().position(|e| e.t_s >= run.t0_s - 0.5 * run.cadence_s) {
            Some(i) => i,
            None => continue,
        };
        let replan_idx = match stats[bidx..].iter().position(|e| e.replanned) {
            Some(k) => bidx + k,
            None => continue, // frozen arm: nothing to gate
        };
        checks += 1;
        let pre = cong[..=bidx].iter().sum::<f64>() / (bidx + 1) as f64;
        if pre <= 0.0 {
            continue;
        }
        let post = cong[replan_idx + 1..].iter().cloned().fold(f64::INFINITY, f64::min);
        if !(post <= CONGESTION_RECOVERY_FACTOR * pre) {
            err(format!(
                "run {}: congestion never recovered after the replan epoch \
                 (pre-fault {pre:.3}, best post-replan {post:.3} > {CONGESTION_RECOVERY_FACTOR}×)",
                run.label
            ));
        }
    }

    CheckOutcome { checks, errors, warnings }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{Recorder, TraceRecord};

    fn meta() -> TraceRecord {
        TraceRecord::Meta {
            subcommand: "test".into(),
            backend: "fluid".into(),
            scheduler: "wheel".into(),
            threads: 1,
            topo: "flat".into(),
            nodes: 2,
            links: 3,
            gpus: 8,
        }
    }

    fn synth_trace(goodput_skew: bool) -> Trace {
        let rec = Recorder::enabled();
        rec.emit(meta);
        rec.set_run("r0");
        let payload = 1.5e9;
        let cadence = 2.0e-4;
        rec.emit(|| TraceRecord::Run { cadence_s: cadence, t0_s: 4.0 * cadence, payload_bytes: payload });
        // steady 100 GB/s for 4 epochs, fault crater, replan, recovery
        let gp = [100.0, 100.0, 100.0, 100.0, 10.0, 95.0, 98.0, 99.0];
        let cg = [0.8, 0.8, 0.8, 0.8, 2.4, 0.85, 0.8, 0.4];
        for (i, (&g, &c)) in gp.iter().zip(&cg).enumerate() {
            rec.emit(|| TraceRecord::Epoch {
                epoch: i as u64,
                t_s: (i + 1) as f64 * cadence,
                goodput_gbps: g,
                congestion: c,
                deviation: 0.1,
                replanned: i == 4,
                preempted: if i == 4 { 3 } else { 0 },
                util: vec![c, 0.2, 0.0],
            });
        }
        rec.emit(|| TraceRecord::Fault { t_s: 4.0 * cadence, desc: "LinkDown(0)".into() });
        let makespan = 8.0 * cadence;
        let good =
            if goodput_skew { 123.0 } else { payload / makespan.max(1e-12) / 1e9 };
        rec.emit(|| TraceRecord::Summary {
            makespan_s: makespan,
            payload_bytes: payload,
            goodput_gbps: good,
            replans: 1,
            preemptions: 3,
            sim_events: 4242,
        });
        rec.emit(|| TraceRecord::FaultRow {
            topo: "flat".into(),
            scenario: "flap".into(),
            arm: "replan".into(),
            goodput_gbps: good,
            clean_gbps: good / 0.9,
            retention: good / (good / 0.9).max(1e-12),
            ttr_epochs: 2.0, // epochs 5..: position of 95 (>=0.9*100) is 1 → +1 = 2
            ttr_ms: 2.0 * cadence * 1e3,
            replans: 1,
            preemptions: 3,
        });
        let text: Vec<String> =
            rec.drain().iter().map(|l| l.to_string_compact()).collect();
        Trace::parse(&text.join("\n")).unwrap()
    }

    #[test]
    fn render_reconstructs_sections_from_the_trace() {
        let t = synth_trace(false);
        let out = render(&t);
        assert!(out.contains("== run r0 =="), "{out}");
        assert!(out.contains("goodput_gbps"), "{out}");
        assert!(out.contains("link    0"), "missing heatmap row:\n{out}");
        assert!(out.contains("recovered in 2 epochs"), "{out}");
        assert!(out.contains("faults headline"), "{out}");
        assert!(out.contains("fault @"), "{out}");
    }

    #[test]
    fn check_passes_on_consistent_trace_and_counts_checks() {
        let t = synth_trace(false);
        let out = check(&t);
        assert!(out.ok(), "unexpected errors: {:?}", out.errors);
        assert!(out.checks > t.lines.len(), "derived checks beyond schema: {}", out.checks);
    }

    #[test]
    fn check_catches_skewed_goodput_and_ttr() {
        let t = synth_trace(true);
        let out = check(&t);
        assert!(!out.ok());
        assert!(
            out.errors.iter().any(|e| e.contains("goodput")),
            "no goodput error: {:?}",
            out.errors
        );
    }

    #[test]
    fn check_rejects_missing_fields_and_warns_on_unknown_kind() {
        let t = Trace::parse("{\"kind\":\"bogus\"}\n{\"kind\":\"note\"}").unwrap();
        // forward compat: the unknown kind was skipped at parse, not kept
        assert_eq!(t.unknown_kinds, 1);
        assert_eq!(t.lines.len(), 1);
        let out = check(&t);
        assert!(out.warnings.iter().any(|w| w.contains("unknown kind")), "{:?}", out.warnings);
        assert!(out.errors.iter().any(|e| e.contains("missing field")));
        assert!(out.errors.iter().any(|e| e.contains("no meta")));
        assert!(!out.errors.iter().any(|e| e.contains("unknown kind")), "{:?}", out.errors);
    }

    #[test]
    fn newer_schema_version_warns_but_does_not_fail_schema_rows() {
        let newer = super::super::SCHEMA_VERSION + 1;
        let text = format!(
            "{{\"kind\":\"meta\",\"schema\":{newer},\"subcommand\":\"x\",\"backend\":\"fluid\",\
             \"scheduler\":\"wheel\",\"threads\":1,\"topo\":\"flat\",\"nodes\":1,\"links\":1,\
             \"gpus\":1}}\n{{\"kind\":\"future_kind\",\"run\":\"r\",\"payload\":42}}"
        );
        let t = Trace::parse(&text).unwrap();
        assert_eq!(t.unknown_kinds, 1);
        let out = check(&t);
        assert!(out.warnings.iter().any(|w| w.contains("newer")), "{:?}", out.warnings);
        assert!(
            !out.errors.iter().any(|e| e.contains("schema version")),
            "newer schema must not error: {:?}",
            out.errors
        );
    }

    #[test]
    fn congestion_gate_fires_when_congestion_stays_high() {
        let rec = Recorder::enabled();
        rec.emit(meta);
        rec.set_run("bad");
        let cadence = 2.0e-4;
        rec.emit(|| TraceRecord::Run { cadence_s: cadence, t0_s: 2.0 * cadence, payload_bytes: 1.0 });
        for i in 0..6u64 {
            rec.emit(|| TraceRecord::Epoch {
                epoch: i,
                t_s: (i + 1) as f64 * cadence,
                goodput_gbps: 50.0,
                congestion: if i < 2 { 0.5 } else { 2.0 }, // never recovers
                deviation: 0.0,
                replanned: i == 2,
                preempted: 0,
                util: vec![0.5],
            });
        }
        let text: Vec<String> = rec.drain().iter().map(|l| l.to_string_compact()).collect();
        let t = Trace::parse(&text.join("\n")).unwrap();
        let out = check(&t);
        assert!(out.errors.iter().any(|e| e.contains("congestion never recovered")), "{:?}", out.errors);
    }

    #[test]
    fn heatmap_downsamples_with_max() {
        let rec = Recorder::enabled();
        rec.set_run("h");
        for i in 0..144u64 {
            rec.emit(|| TraceRecord::Epoch {
                epoch: i,
                t_s: i as f64,
                goodput_gbps: 1.0,
                congestion: 0.1,
                deviation: 0.0,
                replanned: false,
                preempted: 0,
                // one spike that must survive the ×2 downsample
                util: vec![if i == 77 { 1.0 } else { 0.1 }],
            });
        }
        let lines = rec.drain();
        let hm = heatmap(&lines, 72);
        assert!(hm.contains('@'), "spike lost in downsample:\n{hm}");
    }
}
