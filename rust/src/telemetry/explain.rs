//! `nimble explain <trace.jsonl> [--epoch E] [--link L] [--tenant T]
//! [--check]` — congestion attribution from a recorded trace: *why*
//! was a constraint hot, *why* did a replan decision go the way it
//! did, and *who* is burning each tenant's latency budget.
//!
//! Everything here is reconstructed **from the trace alone** (schema
//! v2, see [the module docs](super)):
//!
//! * **blame tables** — `attribution` records decompose each hot
//!   link's window bytes per `(tenant tag, src GPU, dst GPU)`;
//!   without `--epoch` the windows aggregate into a whole-run view,
//!   with `--epoch E` the single window at that monitor epoch is
//!   shown (`--link L` restricts either view to one link);
//! * **decision audits** — `decision` records carry the judged
//!   candidates (schema v2 `candidates`): per-candidate drain time,
//!   delta vs carrying the incumbent, and the top binding
//!   constraints each candidate's drain time sits on;
//! * **tenant SLO burn** — per-tenant headline latencies joined with
//!   the per-tag `histogram` records: the *burn* column is the share
//!   of a tenant's chunk sojourns landing at or above the run-wide
//!   p95 sojourn bucket (cross-tenant tail pressure).
//!
//! `--check` ([`check`]) re-verifies the two v2 invariants from raw
//! trace ingredients, **bit-exactly** where the writer promises it:
//!
//! 1. *blame conservation* — summing each listed link's blame bytes
//!    in listed order reproduces `window_bytes` to the bit (the
//!    writer lists the full decomposition in canonical key order and
//!    floats roundtrip bitwise through [`crate::util::json`]);
//! 2. *histogram consistency* — every `histogram` record's `total`
//!    and headline quantiles are recomputed from its sparse bucket
//!    counts via [`LatencyHist::from_sparse`] and must equal the
//!    recorded values exactly, and the exact `max_ns` must fall in
//!    the highest nonzero bucket.

use super::report::{CheckOutcome, Trace};
use crate::metrics::Table;
use crate::util::hist::{bucket_bounds, bucket_of, LatencyHist};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Filters for [`render`]; `None` = show everything.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExplainOpts {
    /// Only the attribution window at this monitor epoch.
    pub epoch: Option<u64>,
    /// Only this link's blame rows.
    pub link: Option<usize>,
    /// Only this tenant's decisions and SLO row.
    pub tenant: Option<i64>,
}

/// Blame contributors a table row spells out before folding the rest
/// into an `… (+n more)` remainder.
const TOP_CONTRIBUTORS: usize = 3;

/// Detailed decision rows rendered before truncating (rejected
/// decisions beyond the cap are still counted in the totals line).
const MAX_DECISIONS: usize = 24;

/// One parsed `attribution` link entry.
struct LinkRow {
    link: usize,
    window_bytes: f64,
    blame: Vec<(u64, usize, usize, f64)>,
}

fn parse_links(a: &Json) -> Vec<LinkRow> {
    let mut out = Vec::new();
    let Some(links) = a.get("links").as_arr() else { return out };
    for l in links {
        let blame = l
            .get("blame")
            .as_arr()
            .map(|entries| {
                entries
                    .iter()
                    .filter_map(|e| {
                        let q = e.as_arr()?;
                        Some((
                            q.first()?.as_u64()?,
                            q.get(1)?.as_u64()? as usize,
                            q.get(2)?.as_u64()? as usize,
                            q.get(3)?.as_f64()?,
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default();
        out.push(LinkRow {
            link: l.get("link").as_u64().unwrap_or(0) as usize,
            window_bytes: l.get("window_bytes").as_f64().unwrap_or(0.0),
            blame,
        });
    }
    out
}

fn fmt_mb(bytes: f64) -> String {
    format!("{:.2}", bytes / (1024.0 * 1024.0))
}

fn fmt_contributors(blame: &[(u64, usize, usize, f64)], total: f64) -> String {
    let mut ranked: Vec<&(u64, usize, usize, f64)> = blame.iter().collect();
    ranked.sort_by(|a, b| {
        b.3.partial_cmp(&a.3)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then((a.0, a.1, a.2).cmp(&(b.0, b.1, b.2)))
    });
    let shown: Vec<String> = ranked
        .iter()
        .take(TOP_CONTRIBUTORS)
        .map(|(tag, src, dst, b)| {
            format!("t{tag} g{src}→g{dst} {} MB ({:.0}%)", fmt_mb(*b), 100.0 * b / total.max(1e-12))
        })
        .collect();
    let rest = ranked.len().saturating_sub(TOP_CONTRIBUTORS);
    if rest > 0 {
        format!("{} (+{rest} more)", shown.join(", "))
    } else {
        shown.join(", ")
    }
}

fn blame_table(rows: &[LinkRow], link_filter: Option<usize>) -> String {
    let mut t = Table::new(&["link", "window_MB", "blame (tag src→dst, share of link bytes)"]);
    let mut any = false;
    for r in rows {
        if link_filter.map_or(false, |l| l != r.link) {
            continue;
        }
        any = true;
        t.row(&[
            format!("{}", r.link),
            fmt_mb(r.window_bytes),
            fmt_contributors(&r.blame, r.window_bytes),
        ]);
    }
    if any {
        t.render()
    } else {
        "  (no matching link in the recorded windows)\n".to_string()
    }
}

fn fmt_ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

/// Render the explanation report for one trace.
pub fn render(trace: &Trace, opts: &ExplainOpts) -> String {
    let mut out = String::new();
    let attrs: Vec<&Json> = trace.kind_lines("attribution").collect();
    let decisions: Vec<&Json> = trace.kind_lines("decision").collect();
    let hists: Vec<&Json> = trace.kind_lines("histogram").collect();
    let tenants: Vec<&Json> = trace.kind_lines("tenant").collect();

    // ---- blame tables ----
    if attrs.is_empty() {
        out.push_str(
            "no attribution records in trace (recorded by a pre-v2 build, or the run \
             drained before the first monitor window?)\n",
        );
    } else if let Some(e) = opts.epoch {
        let mut found = false;
        for a in &attrs {
            if a.get("epoch").as_u64() != Some(e) {
                continue;
            }
            found = true;
            let run = a.get("run").as_str().unwrap_or("");
            out.push_str(&format!(
                "== blame @ epoch {e} (run {run}, t = {} ms) ==\n",
                fmt_ms(a.get("t_s").as_f64().unwrap_or(0.0))
            ));
            out.push_str(&blame_table(&parse_links(a), opts.link));
        }
        if !found {
            out.push_str(&format!("== blame @ epoch {e} ==\n  (no attribution record at this epoch)\n"));
        }
    } else {
        // whole-run aggregate: per-link byte totals and merged blame
        // across every recorded window, hottest links first
        let mut per_link: BTreeMap<usize, (f64, BTreeMap<(u64, usize, usize), f64>)> =
            BTreeMap::new();
        for a in &attrs {
            for r in parse_links(a) {
                let slot = per_link.entry(r.link).or_default();
                slot.0 += r.window_bytes;
                for (tag, src, dst, b) in r.blame {
                    *slot.1.entry((tag, src, dst)).or_insert(0.0) += b;
                }
            }
        }
        let mut ranked: Vec<(usize, (f64, BTreeMap<(u64, usize, usize), f64>))> =
            per_link.into_iter().collect();
        ranked.sort_by(|a, b| {
            b.1 .0.partial_cmp(&a.1 .0).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
        });
        let rows: Vec<LinkRow> = ranked
            .into_iter()
            .map(|(link, (bytes, blame))| LinkRow {
                link,
                window_bytes: bytes,
                blame: blame.into_iter().map(|((t, s, d), b)| (t, s, d, b)).collect(),
            })
            .collect();
        out.push_str(&format!(
            "== blame, aggregated over {} windows (hottest links first) ==\n",
            attrs.len()
        ));
        out.push_str(&blame_table(&rows, opts.link));
    }

    // ---- decision audits ----
    let picked: Vec<&Json> = decisions
        .iter()
        .copied()
        .filter(|d| {
            opts.tenant
                .map_or(true, |t| d.get("tenant").as_f64().map(|x| x as i64) == Some(t))
        })
        .collect();
    if !picked.is_empty() {
        let accepted = picked.iter().filter(|d| d.get("accepted").as_bool() == Some(true)).count();
        let forced = picked.iter().filter(|d| d.get("forced").as_bool() == Some(true)).count();
        out.push_str(&format!(
            "\n== decisions: {} total, {accepted} accepted, {forced} forced ==\n",
            picked.len()
        ));
        // detail the interesting ones first: accepted or forced, then
        // rejections, truncating past the cap
        let hot = |d: &Json| {
            d.get("accepted").as_bool() == Some(true) || d.get("forced").as_bool() == Some(true)
        };
        let mut detail: Vec<&Json> = Vec::new();
        for &d in &picked {
            if hot(d) {
                detail.push(d);
            }
        }
        for &d in &picked {
            if !hot(d) {
                detail.push(d);
            }
        }
        let shown = detail.len().min(MAX_DECISIONS);
        for d in &detail[..shown] {
            let tenant = d.get("tenant").as_f64().unwrap_or(-1.0);
            out.push_str(&format!(
                "  @{} ms{}: {}{} — z_carry {:.3e}s vs z_challenger {:.3e}s (margin {:.2}, {} pairs changed)\n",
                fmt_ms(d.get("t_s").as_f64().unwrap_or(0.0)),
                if tenant < 0.0 { String::new() } else { format!(" tenant {tenant:.0}") },
                if d.get("accepted").as_bool() == Some(true) { "ACCEPTED" } else { "rejected" },
                if d.get("forced").as_bool() == Some(true) { " (fault-forced)" } else { "" },
                d.get("z_carry").as_f64().unwrap_or(0.0),
                d.get("z_challenger").as_f64().unwrap_or(0.0),
                d.get("margin").as_f64().unwrap_or(0.0),
                d.get("changed_pairs").as_u64().unwrap_or(0),
            ));
            if let Some(cands) = d.get("candidates").as_arr() {
                for c in cands {
                    let binding: Vec<String> = c
                        .get("binding")
                        .as_arr()
                        .map(|b| {
                            b.iter()
                                .filter_map(|e| {
                                    let p = e.as_arr()?;
                                    Some(format!(
                                        "{}={:.3e}s",
                                        p.first()?.as_str()?,
                                        p.get(1)?.as_f64()?
                                    ))
                                })
                                .collect()
                        })
                        .unwrap_or_default();
                    out.push_str(&format!(
                        "      {:<10} z {:.3e}s (Δ {:+.3e}s), binding: {}\n",
                        c.get("name").as_str().unwrap_or("?"),
                        c.get("z_s").as_f64().unwrap_or(0.0),
                        c.get("delta_s").as_f64().unwrap_or(0.0),
                        if binding.is_empty() { "—".to_string() } else { binding.join(", ") },
                    ));
                }
            }
        }
        if detail.len() > shown {
            out.push_str(&format!("  … {} more decisions not shown\n", detail.len() - shown));
        }
    }

    // ---- per-tenant SLO burn ----
    if !tenants.is_empty() {
        // run-wide p95 sojourn bucket: the burn threshold
        let p95_ns = hists
            .iter()
            .find(|h| h.get("scope").as_str() == Some("sojourn"))
            .and_then(|h| h.get("p95_ns").as_u64());
        let tag_hist = |tag: u64| -> Option<LatencyHist> {
            let h = hists
                .iter()
                .find(|h| h.get("scope").as_str() == Some(format!("tag:{tag}").as_str()))?;
            Some(from_record(h))
        };
        let mut t = Table::new(&[
            "tenant",
            "weight",
            "goodput_gbps",
            "p99_lat_us",
            "p99_chunk_us",
            "slo_burn_pct",
        ]);
        let mut any = false;
        for r in &tenants {
            let tid = r.get("tenant").as_u64().unwrap_or(0);
            if opts.tenant.map_or(false, |t| t != tid as i64) {
                continue;
            }
            any = true;
            let p99c = r.get("p99_chunk_s").as_f64().unwrap_or(-1.0);
            let burn = match (p95_ns, tag_hist(tid)) {
                (Some(thr), Some(h)) if h.total() > 0 => {
                    let above: u64 = h
                        .nonzero()
                        .iter()
                        .filter(|&&(idx, _)| bucket_bounds(idx).0 >= thr)
                        .map(|&(_, c)| c)
                        .sum();
                    format!("{:.1}", 100.0 * above as f64 / h.total() as f64)
                }
                _ => "—".to_string(),
            };
            t.row(&[
                format!("{tid}"),
                format!("{:.1}", r.get("weight").as_f64().unwrap_or(0.0)),
                format!("{:.1}", r.get("goodput_gbps").as_f64().unwrap_or(0.0)),
                format!("{:.1}", r.get("p99_lat_s").as_f64().unwrap_or(0.0) * 1e6),
                if p99c < 0.0 { "—".to_string() } else { format!("{:.1}", p99c * 1e6) },
                burn,
            ]);
        }
        if any {
            out.push_str(
                "\n== tenant SLO burn (share of chunk sojourns at/above the run-wide p95 bucket) ==\n",
            );
            out.push_str(&t.render());
        }
    }
    out
}

/// Rebuild a [`LatencyHist`] from a `histogram` record's sparse
/// buckets (the `--check` oracle path and the SLO-burn source).
fn from_record(h: &Json) -> LatencyHist {
    let pairs: Vec<(usize, u64)> = h
        .get("buckets")
        .as_arr()
        .map(|b| {
            b.iter()
                .filter_map(|e| {
                    let p = e.as_arr()?;
                    Some((p.first()?.as_u64()? as usize, p.get(1)?.as_u64()?))
                })
                .collect()
        })
        .unwrap_or_default();
    LatencyHist::from_sparse(&pairs, h.get("max_ns").as_u64().unwrap_or(0))
}

/// Re-verify the v2 invariants from raw trace ingredients: blame-sum
/// conservation (bit-exact) and histogram/headline consistency.
pub fn check(trace: &Trace) -> CheckOutcome {
    let mut checks = 0usize;
    let mut errors: Vec<String> = Vec::new();
    let mut warnings: Vec<String> = Vec::new();

    // -- blame conservation: Σ listed blame bytes (in listed order)
    //    reproduces window_bytes bit-exactly on every listed link
    let mut attr_records = 0usize;
    for a in trace.kind_lines("attribution") {
        attr_records += 1;
        let epoch = a.get("epoch").as_u64().unwrap_or(0);
        for r in parse_links(a) {
            checks += 1;
            let mut sum = 0.0f64;
            for &(_, _, _, b) in &r.blame {
                sum += b;
            }
            if sum.to_bits() != r.window_bytes.to_bits() {
                errors.push(format!(
                    "attribution epoch {epoch} link {}: blame sum {} != window_bytes {} \
                     (conservation violated)",
                    r.link, sum, r.window_bytes
                ));
            }
            if r.blame.is_empty() && r.window_bytes != 0.0 {
                errors.push(format!(
                    "attribution epoch {epoch} link {}: {} window bytes with an empty \
                     blame decomposition",
                    r.link, r.window_bytes
                ));
            }
        }
    }
    if attr_records == 0 {
        warnings.push("no attribution records to verify".to_string());
    }

    // -- histogram consistency: totals and headline quantiles
    //    recompute exactly from the sparse buckets; the exact max
    //    falls in the highest nonzero bucket
    let mut hist_records = 0usize;
    for h in trace.kind_lines("histogram") {
        hist_records += 1;
        checks += 1;
        let scope = h.get("scope").as_str().unwrap_or("?").to_string();
        let rebuilt = from_record(h);
        let total = h.get("total").as_u64().unwrap_or(0);
        if rebuilt.total() != total {
            errors.push(format!(
                "histogram {scope}: recorded total {total} != bucket-count sum {}",
                rebuilt.total()
            ));
        }
        for (q, field) in [(50.0, "p50_ns"), (95.0, "p95_ns"), (99.0, "p99_ns")] {
            let recorded = h.get(field).as_u64().unwrap_or(0);
            let recomputed = rebuilt.quantile_ns(q);
            if recomputed != recorded {
                errors.push(format!(
                    "histogram {scope}: {field} {recorded} != {recomputed} recomputed \
                     from the buckets"
                ));
            }
        }
        if total > 0 {
            let max_ns = h.get("max_ns").as_u64().unwrap_or(0);
            let top = rebuilt.nonzero().last().map(|&(i, _)| i);
            if top != Some(bucket_of(max_ns)) {
                errors.push(format!(
                    "histogram {scope}: max_ns {max_ns} does not fall in the highest \
                     nonzero bucket"
                ));
            }
        }
    }
    if hist_records == 0 {
        warnings.push(
            "no histogram records to verify (fluid backend records no tails)".to_string(),
        );
    }

    CheckOutcome { checks, errors, warnings }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{LinkBlame, Recorder, TraceRecord};

    fn attr_record(window: &[(usize, Vec<(u64, usize, usize, f64)>)]) -> TraceRecord {
        TraceRecord::Attribution {
            t_s: 1.0e-3,
            epoch: 0,
            links: window
                .iter()
                .map(|(link, blame)| {
                    // totals derived exactly as the writer does: fold
                    // the listed bytes in order
                    let mut t = 0.0;
                    for &(_, _, _, b) in blame {
                        t += b;
                    }
                    LinkBlame { link: *link, window_bytes: t, blame: blame.clone() }
                })
                .collect(),
        }
    }

    fn hist_record(scope: &str, samples_ns: &[u64]) -> TraceRecord {
        let mut h = LatencyHist::new();
        for &s in samples_ns {
            h.record_ns(s);
        }
        TraceRecord::Histogram {
            scope: scope.to_string(),
            total: h.total(),
            max_ns: h.max_ns(),
            buckets: h.nonzero(),
            p50_ns: h.quantile_ns(50.0),
            p95_ns: h.quantile_ns(95.0),
            p99_ns: h.quantile_ns(99.0),
        }
    }

    fn trace_of(records: Vec<TraceRecord>) -> Trace {
        let rec = Recorder::enabled();
        rec.set_run("r0");
        for r in records {
            rec.emit(move || r);
        }
        let text: Vec<String> = rec.drain().iter().map(|l| l.to_string_compact()).collect();
        Trace::parse(&text.join("\n")).unwrap()
    }

    #[test]
    fn conservation_check_passes_and_catches_tampering() {
        let blame = vec![(0u64, 0usize, 4usize, 1.5e6), (1, 1, 5, 0.7e6), (1, 2, 6, 0.1e6)];
        let t = trace_of(vec![attr_record(&[(3, blame.clone())])]);
        let out = check(&t);
        assert!(out.ok(), "unexpected errors: {:?}", out.errors);
        assert!(out.checks > 0);

        // tamper: drop one contributor — the sum no longer reproduces
        let short = vec![(3usize, blame[..2].to_vec())];
        let mut bad = attr_record(&short);
        if let TraceRecord::Attribution { links, .. } = &mut bad {
            links[0].window_bytes += 0.1e6; // the dropped entry's bytes
        }
        let t = trace_of(vec![bad]);
        let out = check(&t);
        assert!(
            out.errors.iter().any(|e| e.contains("conservation")),
            "tampered blame not caught: {:?}",
            out.errors
        );
    }

    #[test]
    fn histogram_check_recomputes_headlines_and_catches_skew() {
        let samples: Vec<u64> = (1..=200u64).map(|i| i * 750).collect();
        let t = trace_of(vec![hist_record("sojourn", &samples)]);
        let out = check(&t);
        assert!(out.ok(), "unexpected errors: {:?}", out.errors);

        let mut bad = hist_record("sojourn", &samples);
        if let TraceRecord::Histogram { p99_ns, .. } = &mut bad {
            *p99_ns += 1; // not a bucket boundary the counts produce
        }
        let t = trace_of(vec![bad]);
        let out = check(&t);
        assert!(
            out.errors.iter().any(|e| e.contains("p99_ns")),
            "skewed headline not caught: {:?}",
            out.errors
        );
    }

    #[test]
    fn check_warns_but_passes_without_v2_records() {
        let rec = Recorder::enabled();
        rec.emit(|| TraceRecord::Note { text: "old trace".into() });
        let text: Vec<String> = rec.drain().iter().map(|l| l.to_string_compact()).collect();
        let t = Trace::parse(&text.join("\n")).unwrap();
        let out = check(&t);
        // zero checks ran: ok() is false by construction, but nothing errored
        assert!(out.errors.is_empty());
        assert_eq!(out.warnings.len(), 2, "{:?}", out.warnings);
    }

    #[test]
    fn render_blame_decisions_and_slo_sections() {
        let blame0 = vec![(0u64, 0usize, 4usize, 2.0e6), (1, 1, 5, 1.0e6)];
        let blame1 = vec![(1u64, 1usize, 5usize, 4.0e6)];
        let records = vec![
            attr_record(&[(3, blame0), (7, blame1)]),
            TraceRecord::Decision {
                t_s: 2.0e-3,
                tenant: 1,
                accepted: true,
                forced: false,
                z_carry: 3.0e-3,
                z_challenger: 2.0e-3,
                margin: 0.05,
                mwu_visits: 42,
                changed_pairs: 2,
                candidates: vec![crate::telemetry::DecisionCandidate {
                    name: "challenger".into(),
                    z_s: 2.0e-3,
                    delta_s: -1.0e-3,
                    binding: vec![("link:7".into(), 2.0e-3)],
                }],
            },
            TraceRecord::Tenant {
                tenant: 1,
                tenant_kind: "AllToAll".into(),
                weight: 2.0,
                admit_s: 0.0,
                finish_s: 1.0e-2,
                payload_bytes: 3.0e8,
                goodput_gbps: 30.0,
                p99_lat_s: 5.0e-3,
                p99_chunk_s: 40.0e-6,
            },
            hist_record("sojourn", &[10_000, 20_000, 30_000, 40_000, 1_000_000]),
            hist_record("tag:1", &[30_000, 1_000_000]),
        ];
        let t = trace_of(records);
        let out = render(&t, &ExplainOpts::default());
        assert!(out.contains("blame, aggregated"), "{out}");
        assert!(out.contains("g1→g5"), "{out}");
        assert!(out.contains("ACCEPTED"), "{out}");
        assert!(out.contains("link:7"), "{out}");
        assert!(out.contains("slo_burn_pct"), "{out}");

        // link filter drops the other link's row
        let only7 = render(&t, &ExplainOpts { link: Some(7), ..Default::default() });
        assert!(only7.contains("g1→g5"), "{only7}");
        assert!(!only7.contains("g0→g4"), "{only7}");

        // epoch filter finds the window; a missing epoch says so
        let e0 = render(&t, &ExplainOpts { epoch: Some(0), ..Default::default() });
        assert!(e0.contains("blame @ epoch 0"), "{e0}");
        let e9 = render(&t, &ExplainOpts { epoch: Some(9), ..Default::default() });
        assert!(e9.contains("no attribution record at this epoch"), "{e9}");

        // tenant filter keeps tenant 1's decision detail
        let t1 = render(&t, &ExplainOpts { tenant: Some(1), ..Default::default() });
        assert!(t1.contains("tenant 1"), "{t1}");
    }
}
