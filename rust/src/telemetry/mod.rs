//! Execution-time telemetry: a typed, zero-overhead-when-disabled
//! trace subsystem threaded through the planner, coordinator,
//! orchestrator and both fabric backends.
//!
//! The paper's premise is that runtime traffic deviates from
//! expectations and the system must *observe* link utilization to
//! rebalance it — yet without this module the repro could only show
//! end-of-run aggregates. A [`Recorder`] sink collects
//! [`TraceRecord`]s at every decision point of the execution-time
//! loop; `--trace out.jsonl` on the experiment CLIs serializes them as
//! JSON lines, and `nimble report <trace.jsonl>` re-renders epoch
//! time-series, a per-link utilization heatmap, per-tenant series and
//! recovery curves from the trace alone (see [`report`]).
//!
//! ## Observer purity (the hard contract)
//!
//! Telemetry is a **pure observer** (DESIGN.md §15, in the spirit of
//! the §9/§14 bit-identity anchors):
//!
//! * a [`Recorder::disabled`] sink is bitwise inert — every `emit`
//!   is one branch on a `None`, no closure runs, no allocation;
//! * enabling it changes **no plan or simulation bytes** for any
//!   backend, scheduler, or planner thread count — recording reads
//!   state, never mutates it (`tests/telemetry_props.rs` pins this
//!   across the full matrix);
//! * the trace itself is deterministic modulo wall-clock fields
//!   (`*_wall_s`, which measure the host, not the simulation).
//!
//! ## JSONL schema (version 2)
//!
//! One JSON object per line, alphabetical keys, every line carrying
//! `"kind"`. Floats use the repo-wide shortest-roundtrip policy of
//! [`crate::util::json`], so a parsed trace reproduces recorded values
//! **bit-exactly** — `nimble report --check` recomputes headline
//! numbers from raw ingredients and asserts equality, not closeness.
//!
//! Version 2 (DESIGN.md §16) adds the `attribution` and `histogram`
//! kinds, and enriches `decision` with an optional `candidates` array
//! (per-candidate z, delta vs the carry, top-k binding constraints).
//! Forward compat: readers skip unknown kinds with a counted warning
//! instead of failing, so a v1 reader degrades gracefully on a v2
//! trace and vice versa.
//!
//! | `kind`      | emitted by | fields |
//! |-------------|-----------|--------|
//! | `meta`      | CLI entry | `schema`, `subcommand`, `backend`, `scheduler`, `threads`, `topo`, `nodes`, `links`, `gpus` |
//! | `run`       | experiment driver, once per labeled run | `run`, `cadence_s`, `t0_s` (first-fault time, `-1` if fault-free), `payload_bytes` |
//! | `epoch`     | replan/serve epoch loop | `run`, `epoch`, `t_s`, `goodput_gbps`, `congestion` (capacity-normalized max link utilization, **unclamped**), `deviation`, `replanned`, `preempted`, `util` (per-link, unclamped) |
//! | `decision`  | planner challenger audit | `run`, `t_s`, `tenant` (`-1` outside multi-tenant), `accepted`, `forced` (fault-forced replan), `z_carry`, `z_challenger` (capacity-normalized drain times), `margin`, `mwu_visits` (MWU iteration count for the challenger), `changed_pairs` |
//! | `fault`     | fault application | `run`, `t_s`, `desc` |
//! | `admit`     | orchestrator admission | `run`, `t_s`, `tenant`, `tenant_kind`, `weight`, `payload_bytes`, `channels` |
//! | `tenant`    | orchestrator results | `run`, `tenant`, `tenant_kind`, `weight`, `admit_s`, `finish_s`, `payload_bytes`, `goodput_gbps`, `p99_lat_s`, `p99_chunk_s` (`-1` on the fluid backend) |
//! | `summary`   | end of run | `run`, `makespan_s`, `payload_bytes`, `goodput_gbps`, `replans`, `preemptions`, `sim_events` |
//! | `fault_row` | `nimble faults` arms | `run`, `topo`, `scenario`, `arm`, `goodput_gbps`, `clean_gbps`, `retention`, `ttr_epochs`, `ttr_ms` (`-1` = no recovery / not applicable), `replans`, `preemptions` |
//! | `profile`   | end of run | `run`, `events`, `sched_pushes`, `sched_pops`, `solver_invocations`, `mwu_plans`, `mwu_visits`, `plan_wall_s`, `sim_wall_s` |
//! | `attribution` | monitor window (v2) | `run`, `t_s`, `epoch`, `links` (hottest links, each `{link, window_bytes, blame: [[tag, src, dst, bytes], …]}` — the full blame list per listed link, in sorted `(tag, src, dst)` key order, so summing the listed bytes in order reproduces `window_bytes` bit-exactly) |
//! | `histogram` | end of run (v2) | `run`, `scope` (`sojourn` \| `transit` \| `tag:<id>`), `total`, `max_ns`, `buckets` (sparse `[index, count]` pairs), `p50_ns`, `p95_ns`, `p99_ns` |
//! | `note`      | CLIs without deep instrumentation | `text` |
//!
//! Absent optional numerics are encoded as `-1` (never JSON `null`,
//! never NaN — NaN is not valid JSON), matching the bench convention.

pub mod explain;
pub mod report;

use crate::fabric::backend::{EngineProfile, TailStats, WindowAttr};
use crate::util::hist::LatencyHist;
use crate::util::json::{Json, JsonlWriter};
use std::io;
use std::sync::{Arc, Mutex};

/// Trace schema version stamped into every `meta` line.
pub const SCHEMA_VERSION: u64 = 2;

/// One link's blame row inside a [`TraceRecord::Attribution`] record:
/// the window bytes the link carried, decomposed per
/// `(tenant tag, src GPU, dst GPU)`. The decomposition lists **every**
/// contributor of the link in sorted key order, so summing `blame`
/// bytes in listed order reproduces `window_bytes` bit-exactly (the
/// conservation invariant `nimble explain --check` verifies).
#[derive(Clone, Debug, PartialEq)]
pub struct LinkBlame {
    pub link: usize,
    pub window_bytes: f64,
    pub blame: Vec<(u64, usize, usize, f64)>,
}

/// How many (hottest) links an `attribution` record lists per window.
pub const ATTR_TOP_LINKS: usize = 4;

impl LinkBlame {
    /// The `k` hottest links of a monitor window (bytes descending,
    /// link-index ascending on ties — deterministic), each carrying
    /// its **full** blame decomposition in the canonical sorted key
    /// order, so the `Σ blame == window_bytes` conservation invariant
    /// checks bit-exactly on every listed link.
    pub fn hottest(attr: &WindowAttr, k: usize) -> Vec<LinkBlame> {
        let mut idx: Vec<usize> =
            (0..attr.totals.len()).filter(|&l| attr.totals[l] > 0.0).collect();
        idx.sort_by(|&a, &b| {
            attr.totals[b]
                .partial_cmp(&attr.totals[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(k);
        idx.into_iter()
            .map(|l| LinkBlame {
                link: l,
                window_bytes: attr.totals[l],
                blame: attr.blame[l]
                    .iter()
                    .map(|&((tag, src, dst), b)| (tag, src, dst, b))
                    .collect(),
            })
            .collect()
    }
}

/// Emit the end-of-run `histogram` records for a tail-stats snapshot:
/// one record each for the `sojourn` and `transit` scopes plus one
/// `tag:<id>` scope per tenant tag, skipping empty histograms. No-op
/// on a disabled recorder.
pub fn emit_tail_histograms(rec: &Recorder, tail: &TailStats) {
    if !rec.on() {
        return;
    }
    let mut emit_one = |scope: String, h: &LatencyHist| {
        if h.is_empty() {
            return;
        }
        rec.emit(|| TraceRecord::Histogram {
            scope,
            total: h.total(),
            max_ns: h.max_ns(),
            buckets: h.nonzero(),
            p50_ns: h.quantile_ns(50.0),
            p95_ns: h.quantile_ns(95.0),
            p99_ns: h.quantile_ns(99.0),
        });
    };
    emit_one("sojourn".into(), &tail.sojourn);
    emit_one("transit".into(), &tail.transit);
    for (tag, h) in &tail.per_tag_sojourn {
        emit_one(format!("tag:{tag}"), h);
    }
}

/// One judged candidate inside a v2 `decision` record (mirrors the
/// planner's audit; see [`crate::planner::replan::CandidateAudit`]).
#[derive(Clone, Debug, PartialEq)]
pub struct DecisionCandidate {
    pub name: String,
    pub z_s: f64,
    pub delta_s: f64,
    /// Top-k binding constraints `(label, z_term)`, descending.
    pub binding: Vec<(String, f64)>,
}

/// One typed telemetry event. Serialized with [`TraceRecord::to_json`];
/// field-by-field schema in the [module docs](self).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceRecord {
    /// CLI invocation context (once per trace).
    Meta {
        subcommand: String,
        backend: String,
        scheduler: String,
        threads: usize,
        topo: String,
        nodes: usize,
        links: usize,
        gpus: usize,
    },
    /// Start of a labeled run; subsequent run-scoped records carry the
    /// label. `t0_s < 0.0` means fault-free.
    Run { cadence_s: f64, t0_s: f64, payload_bytes: f64 },
    /// One monitoring epoch of the execution-time loop.
    Epoch {
        epoch: u64,
        t_s: f64,
        goodput_gbps: f64,
        congestion: f64,
        deviation: f64,
        replanned: bool,
        preempted: usize,
        util: Vec<f64>,
    },
    /// Planner challenger audit: accepted/rejected with the
    /// drain-time evidence the decision was made on. `candidates`
    /// (schema v2, optional on read) names the binding constraints and
    /// drain-time delta behind each judged plan.
    Decision {
        t_s: f64,
        tenant: i64,
        accepted: bool,
        forced: bool,
        z_carry: f64,
        z_challenger: f64,
        margin: f64,
        mwu_visits: u64,
        changed_pairs: usize,
        candidates: Vec<DecisionCandidate>,
    },
    /// A fault applied to the running fabric.
    Fault { t_s: f64, desc: String },
    /// An admission decision by the orchestrator.
    Admit {
        t_s: f64,
        tenant: u64,
        tenant_kind: String,
        weight: f64,
        payload_bytes: f64,
        channels: usize,
    },
    /// Per-tenant outcome (orchestrator runs).
    Tenant {
        tenant: u64,
        tenant_kind: String,
        weight: f64,
        admit_s: f64,
        finish_s: f64,
        payload_bytes: f64,
        goodput_gbps: f64,
        p99_lat_s: f64,
        p99_chunk_s: f64,
    },
    /// End-of-run headline aggregates.
    Summary {
        makespan_s: f64,
        payload_bytes: f64,
        goodput_gbps: f64,
        replans: u64,
        preemptions: u64,
        sim_events: u64,
    },
    /// One `nimble faults` arm's headline row.
    FaultRow {
        topo: String,
        scenario: String,
        arm: String,
        goodput_gbps: f64,
        clean_gbps: f64,
        retention: f64,
        ttr_epochs: f64,
        ttr_ms: f64,
        replans: u64,
        preemptions: u64,
    },
    /// Engine self-profiling counters + planner work + phase wall time.
    /// The `*_wall_s` fields are the only non-deterministic ones in the
    /// schema.
    Profile {
        engine: EngineProfile,
        mwu_plans: u64,
        mwu_visits: u64,
        plan_wall_s: f64,
        sim_wall_s: f64,
    },
    /// Per-link blame decomposition of one monitor window (schema v2):
    /// the hottest links of the window, each carrying its full
    /// per-(tag, src, dst) byte decomposition.
    Attribution { t_s: f64, epoch: u64, links: Vec<LinkBlame> },
    /// One bounded streaming latency histogram (schema v2): sparse
    /// bucket counts ([`crate::util::hist::LatencyHist`]) plus the
    /// derived headline quantiles, for `--check`-style re-verification.
    Histogram {
        scope: String,
        total: u64,
        max_ns: u64,
        buckets: Vec<(usize, u64)>,
        p50_ns: u64,
        p95_ns: u64,
        p99_ns: u64,
    },
    /// Free-form marker for CLIs without deep instrumentation.
    Note { text: String },
}

impl TraceRecord {
    /// Serialize as one schema line, stamped with the current run
    /// label (empty outside a labeled run).
    pub fn to_json(&self, run: &str) -> Json {
        let runj = ("run", Json::str(run));
        match self {
            TraceRecord::Meta {
                subcommand,
                backend,
                scheduler,
                threads,
                topo,
                nodes,
                links,
                gpus,
            } => Json::obj(vec![
                ("kind", Json::str("meta")),
                ("schema", Json::num(SCHEMA_VERSION as f64)),
                ("subcommand", Json::str(subcommand.as_str())),
                ("backend", Json::str(backend.as_str())),
                ("scheduler", Json::str(scheduler.as_str())),
                ("threads", Json::num(*threads as f64)),
                ("topo", Json::str(topo.as_str())),
                ("nodes", Json::num(*nodes as f64)),
                ("links", Json::num(*links as f64)),
                ("gpus", Json::num(*gpus as f64)),
            ]),
            TraceRecord::Run { cadence_s, t0_s, payload_bytes } => Json::obj(vec![
                ("kind", Json::str("run")),
                runj,
                ("cadence_s", Json::num(*cadence_s)),
                ("t0_s", Json::num(*t0_s)),
                ("payload_bytes", Json::num(*payload_bytes)),
            ]),
            TraceRecord::Epoch {
                epoch,
                t_s,
                goodput_gbps,
                congestion,
                deviation,
                replanned,
                preempted,
                util,
            } => Json::obj(vec![
                ("kind", Json::str("epoch")),
                runj,
                ("epoch", Json::num(*epoch as f64)),
                ("t_s", Json::num(*t_s)),
                ("goodput_gbps", Json::num(*goodput_gbps)),
                ("congestion", Json::num(*congestion)),
                ("deviation", Json::num(*deviation)),
                ("replanned", Json::Bool(*replanned)),
                ("preempted", Json::num(*preempted as f64)),
                ("util", Json::arr(util.iter().map(|&u| Json::num(u)))),
            ]),
            TraceRecord::Decision {
                t_s,
                tenant,
                accepted,
                forced,
                z_carry,
                z_challenger,
                margin,
                mwu_visits,
                changed_pairs,
                candidates,
            } => Json::obj(vec![
                ("kind", Json::str("decision")),
                runj,
                ("t_s", Json::num(*t_s)),
                ("tenant", Json::num(*tenant as f64)),
                ("accepted", Json::Bool(*accepted)),
                ("forced", Json::Bool(*forced)),
                ("z_carry", Json::num(*z_carry)),
                ("z_challenger", Json::num(*z_challenger)),
                ("margin", Json::num(*margin)),
                ("mwu_visits", Json::num(*mwu_visits as f64)),
                ("changed_pairs", Json::num(*changed_pairs as f64)),
                (
                    "candidates",
                    Json::arr(candidates.iter().map(|c| {
                        Json::obj(vec![
                            ("name", Json::str(c.name.as_str())),
                            ("z_s", Json::num(c.z_s)),
                            ("delta_s", Json::num(c.delta_s)),
                            (
                                "binding",
                                Json::arr(c.binding.iter().map(|(label, v)| {
                                    Json::arr(
                                        [Json::str(label.as_str()), Json::num(*v)]
                                            .into_iter(),
                                    )
                                })),
                            ),
                        ])
                    })),
                ),
            ]),
            TraceRecord::Fault { t_s, desc } => Json::obj(vec![
                ("kind", Json::str("fault")),
                runj,
                ("t_s", Json::num(*t_s)),
                ("desc", Json::str(desc.as_str())),
            ]),
            TraceRecord::Admit {
                t_s,
                tenant,
                tenant_kind,
                weight,
                payload_bytes,
                channels,
            } => Json::obj(vec![
                ("kind", Json::str("admit")),
                runj,
                ("t_s", Json::num(*t_s)),
                ("tenant", Json::num(*tenant as f64)),
                ("tenant_kind", Json::str(tenant_kind.as_str())),
                ("weight", Json::num(*weight)),
                ("payload_bytes", Json::num(*payload_bytes)),
                ("channels", Json::num(*channels as f64)),
            ]),
            TraceRecord::Tenant {
                tenant,
                tenant_kind,
                weight,
                admit_s,
                finish_s,
                payload_bytes,
                goodput_gbps,
                p99_lat_s,
                p99_chunk_s,
            } => Json::obj(vec![
                ("kind", Json::str("tenant")),
                runj,
                ("tenant", Json::num(*tenant as f64)),
                ("tenant_kind", Json::str(tenant_kind.as_str())),
                ("weight", Json::num(*weight)),
                ("admit_s", Json::num(*admit_s)),
                ("finish_s", Json::num(*finish_s)),
                ("payload_bytes", Json::num(*payload_bytes)),
                ("goodput_gbps", Json::num(*goodput_gbps)),
                ("p99_lat_s", Json::num(*p99_lat_s)),
                ("p99_chunk_s", Json::num(*p99_chunk_s)),
            ]),
            TraceRecord::Summary {
                makespan_s,
                payload_bytes,
                goodput_gbps,
                replans,
                preemptions,
                sim_events,
            } => Json::obj(vec![
                ("kind", Json::str("summary")),
                runj,
                ("makespan_s", Json::num(*makespan_s)),
                ("payload_bytes", Json::num(*payload_bytes)),
                ("goodput_gbps", Json::num(*goodput_gbps)),
                ("replans", Json::num(*replans as f64)),
                ("preemptions", Json::num(*preemptions as f64)),
                ("sim_events", Json::num(*sim_events as f64)),
            ]),
            TraceRecord::FaultRow {
                topo,
                scenario,
                arm,
                goodput_gbps,
                clean_gbps,
                retention,
                ttr_epochs,
                ttr_ms,
                replans,
                preemptions,
            } => Json::obj(vec![
                ("kind", Json::str("fault_row")),
                runj,
                ("topo", Json::str(topo.as_str())),
                ("scenario", Json::str(scenario.as_str())),
                ("arm", Json::str(arm.as_str())),
                ("goodput_gbps", Json::num(*goodput_gbps)),
                ("clean_gbps", Json::num(*clean_gbps)),
                ("retention", Json::num(*retention)),
                ("ttr_epochs", Json::num(*ttr_epochs)),
                ("ttr_ms", Json::num(*ttr_ms)),
                ("replans", Json::num(*replans as f64)),
                ("preemptions", Json::num(*preemptions as f64)),
            ]),
            TraceRecord::Profile { engine, mwu_plans, mwu_visits, plan_wall_s, sim_wall_s } => {
                Json::obj(vec![
                    ("kind", Json::str("profile")),
                    runj,
                    ("events", Json::num(engine.events as f64)),
                    ("sched_pushes", Json::num(engine.sched_pushes as f64)),
                    ("sched_pops", Json::num(engine.sched_pops as f64)),
                    ("solver_invocations", Json::num(engine.solver_invocations as f64)),
                    ("mwu_plans", Json::num(*mwu_plans as f64)),
                    ("mwu_visits", Json::num(*mwu_visits as f64)),
                    ("plan_wall_s", Json::num(*plan_wall_s)),
                    ("sim_wall_s", Json::num(*sim_wall_s)),
                ])
            }
            TraceRecord::Attribution { t_s, epoch, links } => Json::obj(vec![
                ("kind", Json::str("attribution")),
                runj,
                ("t_s", Json::num(*t_s)),
                ("epoch", Json::num(*epoch as f64)),
                (
                    "links",
                    Json::arr(links.iter().map(|lb| {
                        Json::obj(vec![
                            ("link", Json::num(lb.link as f64)),
                            ("window_bytes", Json::num(lb.window_bytes)),
                            (
                                "blame",
                                Json::arr(lb.blame.iter().map(
                                    |&(tag, src, dst, bytes)| {
                                        Json::arr(
                                            [
                                                Json::num(tag as f64),
                                                Json::num(src as f64),
                                                Json::num(dst as f64),
                                                Json::num(bytes),
                                            ]
                                            .into_iter(),
                                        )
                                    },
                                )),
                            ),
                        ])
                    })),
                ),
            ]),
            TraceRecord::Histogram {
                scope,
                total,
                max_ns,
                buckets,
                p50_ns,
                p95_ns,
                p99_ns,
            } => Json::obj(vec![
                ("kind", Json::str("histogram")),
                runj,
                ("scope", Json::str(scope.as_str())),
                ("total", Json::num(*total as f64)),
                ("max_ns", Json::num(*max_ns as f64)),
                (
                    "buckets",
                    Json::arr(buckets.iter().map(|&(i, c)| {
                        Json::arr([Json::num(i as f64), Json::num(c as f64)].into_iter())
                    })),
                ),
                ("p50_ns", Json::num(*p50_ns as f64)),
                ("p95_ns", Json::num(*p95_ns as f64)),
                ("p99_ns", Json::num(*p99_ns as f64)),
            ]),
            TraceRecord::Note { text } => {
                Json::obj(vec![("kind", Json::str("note")), ("text", Json::str(text.as_str()))])
            }
        }
    }
}

/// Where recorded lines go: the in-memory buffer (tests, `--check`
/// pipelines) or an incremental JSONL file sink (`--trace PATH` on
/// long runs — trace memory stays O(1) instead of O(records)).
enum Sink {
    Mem(Vec<Json>),
    File {
        w: JsonlWriter<io::BufWriter<std::fs::File>>,
        /// First write error, surfaced at [`Recorder::finish`] (the
        /// emit path cannot return it).
        err: Option<io::Error>,
    },
}

struct Inner {
    run: String,
    sink: Sink,
}

/// The telemetry sink. `Clone` is cheap (an `Option<Arc>`); a cloned
/// recorder appends to the same trace. The default/[`disabled`]
/// recorder holds `None`, so every [`emit`] is a single branch and the
/// record-constructing closure never runs — zero overhead, zero
/// allocation, bitwise inert (the observer-purity contract, module
/// docs).
///
/// [`disabled`]: Recorder::disabled
/// [`emit`]: Recorder::emit
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Option<Arc<Mutex<Inner>>>,
}

impl Recorder {
    /// The no-op sink (what executors hold unless `--trace` is given).
    pub fn disabled() -> Self {
        Recorder { inner: None }
    }

    /// A live sink accumulating records in memory.
    pub fn enabled() -> Self {
        Recorder {
            inner: Some(Arc::new(Mutex::new(Inner {
                run: String::new(),
                sink: Sink::Mem(Vec::new()),
            }))),
        }
    }

    /// A live sink streaming each record to `path` as it is emitted
    /// (buffered JSONL). Bounds trace memory on long-horizon runs; call
    /// [`Recorder::finish`] at exit to flush and surface I/O errors.
    pub fn to_file(path: &str) -> io::Result<Self> {
        let w = JsonlWriter::create(path)?;
        Ok(Recorder {
            inner: Some(Arc::new(Mutex::new(Inner {
                run: String::new(),
                sink: Sink::File { w, err: None },
            }))),
        })
    }

    /// Whether records are being collected. Instrumentation sites that
    /// need to *compute* something purely for telemetry (a utilization
    /// snapshot, a wall-clock timestamp) gate on this.
    pub fn on(&self) -> bool {
        self.inner.is_some()
    }

    /// Set the run label stamped on subsequent run-scoped records.
    pub fn set_run(&self, label: &str) {
        if let Some(m) = &self.inner {
            m.lock().unwrap().run = label.to_string();
        }
    }

    /// Record one event. The closure only runs when the sink is live.
    /// File sinks write the line through immediately (buffered); any
    /// I/O error is stashed and surfaced by [`Recorder::finish`].
    pub fn emit(&self, f: impl FnOnce() -> TraceRecord) {
        if let Some(m) = &self.inner {
            let mut g = m.lock().unwrap();
            let line = f().to_json(&g.run);
            match &mut g.sink {
                Sink::Mem(lines) => lines.push(line),
                Sink::File { w, err } => {
                    if err.is_none() {
                        if let Err(e) = w.write(&line) {
                            *err = Some(e);
                        }
                    }
                }
            }
        }
    }

    /// Lines recorded so far (file sinks: lines streamed out).
    pub fn len(&self) -> usize {
        self.inner.as_ref().map_or(0, |m| match &m.lock().unwrap().sink {
            Sink::Mem(lines) => lines.len(),
            Sink::File { w, .. } => w.lines(),
        })
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take every recorded line out of the sink (oldest first). File
    /// sinks stream lines out as they are emitted, so there is nothing
    /// to drain — the trace lives in the file.
    pub fn drain(&self) -> Vec<Json> {
        match &self.inner {
            None => Vec::new(),
            Some(m) => match &mut m.lock().unwrap().sink {
                Sink::Mem(lines) => std::mem::take(lines),
                Sink::File { .. } => Vec::new(),
            },
        }
    }

    /// Snapshot the recorded lines without draining them (in-memory
    /// sinks only; file sinks return empty).
    pub fn lines(&self) -> Vec<Json> {
        self.inner.as_ref().map_or_else(Vec::new, |m| match &m.lock().unwrap().sink {
            Sink::Mem(lines) => lines.clone(),
            Sink::File { .. } => Vec::new(),
        })
    }

    /// Serialize every recorded line to `path` as JSONL (drains the
    /// sink); returns the number of lines written. In-memory sinks
    /// only — a file sink already streamed its lines (use
    /// [`Recorder::finish`] there).
    pub fn write_jsonl(&self, path: &str) -> io::Result<usize> {
        let mut w = JsonlWriter::create(path)?;
        for line in self.drain() {
            w.write(&line)?;
        }
        w.flush()?;
        Ok(w.lines())
    }

    /// Flush a file sink and surface any deferred write error; returns
    /// the total lines that went to the file (0 for memory/disabled
    /// sinks — their lines are still in the buffer).
    pub fn finish(&self) -> io::Result<usize> {
        match &self.inner {
            None => Ok(0),
            Some(m) => match &mut m.lock().unwrap().sink {
                Sink::Mem(_) => Ok(0),
                Sink::File { w, err } => {
                    if let Some(e) = err.take() {
                        return Err(e);
                    }
                    w.flush()?;
                    Ok(w.lines())
                }
            },
        }
    }
}

/// The `[telemetry]` config section: opt-in tracing without a
/// `--trace` flag. When `enable` is true and no `--trace PATH` is
/// given on the command line, experiment commands write their trace to
/// `path`. The flag always wins over the config file.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryCfg {
    /// Collect a trace even without `--trace` on the command line.
    pub enable: bool,
    /// Where the trace goes when enabled via config.
    pub path: String,
}

impl Default for TelemetryCfg {
    fn default() -> Self {
        TelemetryCfg { enable: false, path: "nimble-trace.jsonl".into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_never_runs_closures() {
        let rec = Recorder::disabled();
        assert!(!rec.on());
        rec.emit(|| unreachable!("disabled sink must not evaluate the record"));
        rec.set_run("ignored");
        assert!(rec.is_empty());
        assert!(rec.drain().is_empty());
    }

    #[test]
    fn enabled_recorder_stamps_run_labels() {
        let rec = Recorder::enabled();
        assert!(rec.on());
        rec.emit(|| TraceRecord::Note { text: "hello".into() });
        rec.set_run("flap");
        rec.emit(|| TraceRecord::Fault { t_s: 0.001, desc: "link down".into() });
        let lines = rec.lines();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].get("kind").as_str(), Some("note"));
        assert_eq!(lines[1].get("run").as_str(), Some("flap"));
        assert_eq!(lines[1].get("t_s").as_f64(), Some(0.001));
        // clones share the sink
        let clone = rec.clone();
        clone.emit(|| TraceRecord::Note { text: "shared".into() });
        assert_eq!(rec.len(), 3);
        // drain empties, preserves order
        let drained = rec.drain();
        assert_eq!(drained.len(), 3);
        assert!(rec.is_empty());
    }

    #[test]
    fn every_record_kind_serializes_and_roundtrips() {
        let records = vec![
            TraceRecord::Meta {
                subcommand: "faults".into(),
                backend: "fluid".into(),
                scheduler: "wheel".into(),
                threads: 1,
                topo: "flat".into(),
                nodes: 2,
                links: 34,
                gpus: 8,
            },
            TraceRecord::Run { cadence_s: 2.0e-4, t0_s: 0.004, payload_bytes: 1.5e9 },
            TraceRecord::Epoch {
                epoch: 3,
                t_s: 6.0e-4,
                goodput_gbps: 812.5,
                congestion: 1.25,
                deviation: 0.31,
                replanned: true,
                preempted: 4,
                util: vec![0.5, 1.25, 0.0],
            },
            TraceRecord::Decision {
                t_s: 6.0e-4,
                tenant: -1,
                accepted: true,
                forced: false,
                z_carry: 1.9e-3,
                z_challenger: 1.2e-3,
                margin: 0.1,
                mwu_visits: 640,
                changed_pairs: 7,
                candidates: vec![DecisionCandidate {
                    name: "carry".into(),
                    z_s: 1.9e-3,
                    delta_s: 0.0,
                    binding: vec![("link:4".into(), 1.9e-3)],
                }],
            },
            TraceRecord::Attribution {
                t_s: 6.0e-4,
                epoch: 3,
                links: vec![LinkBlame {
                    link: 4,
                    window_bytes: 3.0e6,
                    blame: vec![(0, 0, 1, 2.0e6), (7, 2, 1, 1.0e6)],
                }],
            },
            TraceRecord::Histogram {
                scope: "sojourn".into(),
                total: 64,
                max_ns: 123_456,
                buckets: vec![(40, 60), (100, 4)],
                p50_ns: 1_024,
                p95_ns: 98_304,
                p99_ns: 98_304,
            },
            TraceRecord::Fault { t_s: 0.004, desc: "LinkDown(12)".into() },
            TraceRecord::Admit {
                t_s: 0.0,
                tenant: 2,
                tenant_kind: "allreduce".into(),
                weight: 2.0,
                payload_bytes: 3.0e8,
                channels: 2,
            },
            TraceRecord::Tenant {
                tenant: 2,
                tenant_kind: "allreduce".into(),
                weight: 2.0,
                admit_s: 0.0,
                finish_s: 0.0123,
                payload_bytes: 3.0e8,
                goodput_gbps: 24.4,
                p99_lat_s: 1.1e-3,
                p99_chunk_s: -1.0,
            },
            TraceRecord::Summary {
                makespan_s: 0.0123,
                payload_bytes: 1.5e9,
                goodput_gbps: 975.6,
                replans: 2,
                preemptions: 9,
                sim_events: 123456,
            },
            TraceRecord::FaultRow {
                topo: "flat".into(),
                scenario: "flap".into(),
                arm: "replan".into(),
                goodput_gbps: 900.0,
                clean_gbps: 1000.0,
                retention: 0.9,
                ttr_epochs: 5.0,
                ttr_ms: 1.0,
                replans: 2,
                preemptions: 9,
            },
            TraceRecord::Profile {
                engine: EngineProfile {
                    events: 1000,
                    sched_pushes: 1100,
                    sched_pops: 1000,
                    solver_invocations: 0,
                },
                mwu_plans: 3,
                mwu_visits: 1920,
                plan_wall_s: 0.01,
                sim_wall_s: 0.2,
            },
            TraceRecord::Note { text: "shallow".into() },
        ];
        for r in records {
            let line = r.to_json("runlabel").to_string_compact();
            let back = Json::parse(&line).expect("every kind emits valid JSON");
            assert!(back.get("kind").as_str().is_some(), "missing kind: {line}");
        }
    }

    #[test]
    fn floats_in_records_roundtrip_bitwise() {
        let rec = Recorder::enabled();
        let g = 1234.567_890_123_4 / 3.0;
        rec.emit(|| TraceRecord::Summary {
            makespan_s: 1.0 / 3.0,
            payload_bytes: 9.87e15,
            goodput_gbps: g,
            replans: 1,
            preemptions: 0,
            sim_events: 2,
        });
        let line = rec.drain().pop().unwrap().to_string_compact();
        let back = Json::parse(&line).unwrap();
        assert_eq!(back.get("goodput_gbps").as_f64().unwrap().to_bits(), g.to_bits());
        assert_eq!(back.get("makespan_s").as_f64().unwrap().to_bits(), (1.0f64 / 3.0).to_bits());
    }

    #[test]
    fn file_sink_streams_incrementally() {
        let path = std::env::temp_dir().join("nimble_telemetry_stream_unit.jsonl");
        let p = path.to_str().unwrap();
        let rec = Recorder::to_file(p).unwrap();
        assert!(rec.on());
        rec.set_run("stream");
        rec.emit(|| TraceRecord::Note { text: "a".into() });
        rec.emit(|| TraceRecord::Note { text: "b".into() });
        // lines went to the file, not the buffer
        assert!(rec.lines().is_empty());
        assert!(rec.drain().is_empty());
        assert_eq!(rec.len(), 2);
        assert_eq!(rec.finish().unwrap(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            Json::parse(line).expect("streamed lines are valid JSON");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_jsonl_counts_lines() {
        let rec = Recorder::enabled();
        rec.emit(|| TraceRecord::Note { text: "a".into() });
        rec.emit(|| TraceRecord::Note { text: "b".into() });
        let path = std::env::temp_dir().join("nimble_telemetry_unit.jsonl");
        let n = rec.write_jsonl(path.to_str().unwrap()).unwrap();
        assert_eq!(n, 2);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_file(&path).ok();
        // writing drained the sink
        assert!(rec.is_empty());
    }
}
