//! Shared-constraint sets: aggregate capacity terms the planner prices
//! alongside individual links.
//!
//! The flat planner's congestion terms were hard-coded to the
//! src→rail→dst shape: per-link loads plus per-GPU / per-node endpoint
//! aggregates. Tiered fabrics add resources that are *shared across
//! links without being endpoints* — most importantly a leaf switch's
//! total core uplink (and downlink) bandwidth, which is what
//! oversubscription actually rations. This module generalizes those
//! into an explicit constraint set: each [`SharedTerm`] is a capacity
//! with a set of member links, and the MWU load table gains one
//! virtual entry per term (indices `links.len()..links.len()+terms`)
//! so Algorithm 1 prices them exactly like links — `F(load/cap)` with
//! the same monotone cost shape.
//!
//! **Flat topologies produce an empty set**, so every flat plan,
//! conflict-component split, and parallel-sweep script is bit-identical
//! to the pre-tier planner — the anchor the refactor is certified
//! against.

use crate::topology::{LinkId, LinkKind, Topology};

/// One aggregate capacity shared by several links.
#[derive(Clone, Debug)]
pub struct SharedTerm {
    /// Aggregate capacity in bytes/second.
    pub cap_bps: f64,
    /// Links whose load draws down this term.
    pub members: Vec<LinkId>,
}

/// The topology's full shared-constraint set plus a link → terms
/// reverse index for candidate resolution.
#[derive(Clone, Debug, Default)]
pub struct SharedConstraints {
    pub terms: Vec<SharedTerm>,
    /// `member_terms[link]` = indices of the terms `link` belongs to.
    member_terms: Vec<Vec<u32>>,
}

impl SharedConstraints {
    /// Derive the constraint set from the topology. Flat fabrics have
    /// no shared terms beyond what per-link caps and the endpoint
    /// bounds already express; tiered fabrics get one uplink and one
    /// downlink aggregate per leaf switch, coupling the spine links a
    /// leaf fans out to so the planner levels load across *leaves*,
    /// not just across individual spine edges.
    pub fn of(topo: &Topology) -> SharedConstraints {
        let Some(tier) = &topo.tier else {
            return SharedConstraints::default();
        };
        let mut terms: Vec<SharedTerm> = Vec::new();
        let agg_cap = tier.spines_per_rail as f64 * tier.uplink_gbps * 1e9;
        for pod in 0..tier.pods {
            for r in 0..topo.nics_per_node {
                let ups: Vec<LinkId> = (0..tier.spines_per_rail)
                    .map(|k| topo.spine_up(pod, r, k).expect("leaf uplink"))
                    .collect();
                let downs: Vec<LinkId> = (0..tier.spines_per_rail)
                    .map(|k| topo.spine_down(pod, r, k).expect("leaf downlink"))
                    .collect();
                terms.push(SharedTerm { cap_bps: agg_cap, members: ups });
                terms.push(SharedTerm { cap_bps: agg_cap, members: downs });
            }
        }
        let mut member_terms = vec![Vec::new(); topo.links.len()];
        for (ti, t) in terms.iter().enumerate() {
            for &l in &t.members {
                member_terms[l].push(ti as u32);
            }
        }
        SharedConstraints { terms, member_terms }
    }

    /// Like [`SharedConstraints::of`], but with each term's capacity
    /// recomputed from fault-scaled member links (`scale[l]` multiplies
    /// link `l`'s capacity): a leaf whose spine uplink died really does
    /// have less aggregate core bandwidth, and the planner must price
    /// that. Only called with link health installed, so the fault-free
    /// planner never leaves [`SharedConstraints::of`]'s exact values.
    pub fn of_scaled(topo: &Topology, scale: &[f64]) -> SharedConstraints {
        let mut s = SharedConstraints::of(topo);
        for term in &mut s.terms {
            term.cap_bps = term
                .members
                .iter()
                .map(|&l| topo.link(l).cap_gbps * scale[l] * 1e9)
                .sum::<f64>()
                // all members dead: keep the cap finite (1 byte/s) so
                // the cost arithmetic of fully-cut fallback paths stays
                // well-defined — effectively infinitely expensive.
                .max(1.0);
        }
        s
    }

    pub fn len(&self) -> usize {
        self.terms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.terms.is_empty()
    }

    /// Terms link `l` is a member of (empty on flat fabrics).
    pub fn terms_of(&self, l: LinkId) -> &[u32] {
        self.member_terms.get(l).map_or(&[], |v| v.as_slice())
    }

    /// Extend a per-link load vector with the per-term aggregate loads
    /// (the MWU warm-start shape: physical entries first, then one
    /// virtual entry per term holding the sum of its members' loads).
    pub fn extended_loads(&self, link_load: &[f64]) -> Vec<f64> {
        let mut out = Vec::with_capacity(link_load.len() + self.terms.len());
        out.extend_from_slice(link_load);
        for t in &self.terms {
            out.push(t.members.iter().map(|&l| link_load[l]).sum());
        }
        out
    }

    /// Max normalized term load (drain-time seconds) for a per-link
    /// load vector — the shared-aggregate part of the bottleneck
    /// objective `Z`. Zero on flat fabrics.
    pub fn max_norm_load(&self, link_load: &[f64]) -> f64 {
        let mut z = 0.0f64;
        for t in &self.terms {
            let load: f64 = t.members.iter().map(|&l| link_load[l]).sum();
            z = z.max(load / t.cap_bps);
        }
        z
    }

    /// Core-uplink utilization report: (term loads, caps) for the
    /// uplink-direction terms (even indices — see [`SharedConstraints::of`]).
    /// Used by `nimble scale` to report where tiered congestion lands.
    pub fn uplink_norm_loads(&self, link_load: &[f64]) -> Vec<f64> {
        self.terms
            .iter()
            .step_by(2)
            .map(|t| t.members.iter().map(|&l| link_load[l]).sum::<f64>() / t.cap_bps)
            .collect()
    }
}

/// Convenience for experiments: max over both per-link and shared-term
/// normalized loads — the tier-aware bottleneck objective.
pub fn bottleneck_norm_load(topo: &Topology, shared: &SharedConstraints, load: &[f64]) -> f64 {
    let mut z = 0.0f64;
    for l in &topo.links {
        z = z.max(load[l.id] / (l.cap_gbps * 1e9));
    }
    z.max(shared.max_norm_load(load))
}

/// Is this a link the shared terms could ever couple (core tier)?
/// Handy for reporting filters.
pub fn is_core_link(kind: LinkKind) -> bool {
    matches!(kind, LinkKind::SpineUp { .. } | LinkKind::SpineDown { .. })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn flat_topologies_have_no_terms() {
        for t in [Topology::paper(), Topology::cluster(4)] {
            let s = SharedConstraints::of(&t);
            assert!(s.is_empty());
            let load = vec![1.0; t.links.len()];
            assert_eq!(s.extended_loads(&load), load);
            assert_eq!(s.max_norm_load(&load), 0.0);
        }
    }

    #[test]
    fn fat_tree_terms_cover_every_core_link_once() {
        let t = Topology::fat_tree(8, 2.0);
        let s = SharedConstraints::of(&t);
        let tier = t.tier.as_ref().unwrap();
        // one up + one down term per (pod, rail)
        assert_eq!(s.len(), tier.pods * t.nics_per_node * 2);
        let mut seen = vec![0usize; t.links.len()];
        for term in &s.terms {
            assert_eq!(term.members.len(), tier.spines_per_rail);
            assert!((term.cap_bps
                - tier.spines_per_rail as f64 * tier.uplink_gbps * 1e9)
                .abs()
                < 1.0);
            for &l in &term.members {
                assert!(is_core_link(t.link(l).kind));
                seen[l] += 1;
            }
        }
        for l in &t.links {
            let expect = usize::from(is_core_link(l.kind));
            assert_eq!(seen[l.id], expect, "link {} covered {} times", l.id, seen[l.id]);
            for &ti in s.terms_of(l.id) {
                assert!(s.terms[ti as usize].members.contains(&l.id));
            }
        }
    }

    #[test]
    fn scaled_terms_sum_scaled_member_capacities() {
        let t = Topology::fat_tree(8, 2.0);
        let s0 = SharedConstraints::of(&t);
        let dead = s0.terms[0].members[0];
        let mut scale = vec![1.0; t.links.len()];
        scale[dead] = 0.0;
        let s = SharedConstraints::of_scaled(&t, &scale);
        assert_eq!(s.len(), s0.len());
        let full = s0.terms[0].cap_bps;
        assert!(
            (s.terms[0].cap_bps - (full - t.link(dead).cap_gbps * 1e9)).abs() < 1.0,
            "dead member not subtracted"
        );
        // the paired downlink term is untouched
        assert!((s.terms[1].cap_bps - full).abs() < 1.0);
        // every member dead ⇒ cap clamps to the 1 B/s floor
        let zeros = vec![0.0; t.links.len()];
        let all_dead = SharedConstraints::of_scaled(&t, &zeros);
        assert_eq!(all_dead.terms[0].cap_bps, 1.0);
    }

    #[test]
    fn extended_loads_sum_members() {
        let t = Topology::fat_tree(8, 2.0);
        let s = SharedConstraints::of(&t);
        let mut load = vec![0.0; t.links.len()];
        let term = &s.terms[0];
        load[term.members[0]] = 3.0;
        load[term.members[1]] = 4.0;
        let ext = s.extended_loads(&load);
        assert_eq!(ext.len(), t.links.len() + s.len());
        assert_eq!(ext[t.links.len()], 7.0);
        assert!((s.max_norm_load(&load) - 7.0 / term.cap_bps).abs() < 1e-18);
    }
}
