//! Joint multi-tenant planning: Algorithm 1 over the **union** of all
//! live tenants' demands on one shared load table.
//!
//! [`Planner::plan_joint`] is the planner half of the multi-tenant
//! orchestrator ([`crate::orchestrator`]): it solves the
//! capacity-normalized min-congestion problem across every tenant at
//! once instead of per job, so tenants route around each other's
//! *planned* residuals rather than rediscovering them through the
//! monitor. Three deliberate differences from the per-job sweep
//! ([`Planner::plan_seeded`]):
//!
//! * **Shared cost basis** — all tenants' visits accumulate into one
//!   link-load table (plus the optional warm-start `initial`, used for
//!   pressure *external* to the planned tenants).
//! * **Per-tenant MWU weight scaling** — a tenant's per-visit routed
//!   fraction is `λ · weight / max_weight`, so heavier tenants claim
//!   their paths in fewer, earlier, larger chunks (planning-time
//!   priority; the execution-time share enforcement is the channel
//!   allocation in [`crate::orchestrator::executor`]).
//! * **Differential endpoint costs** — every candidate's cost also
//!   tracks the *relay* GPUs' injection/receive aggregates
//!   ([`path_relay_endpoints`]). Only differential terms enter: a
//!   pair's source/destination/node aggregates are common to all of
//!   its candidates, and a saturated common constraint would flatten
//!   every candidate cost and pile the residual onto the first
//!   candidate. Relaying through an endpoint-busy GPU, by contrast, is
//!   a choice the joint solve can and does avoid.
//!
//! ## Link-disjoint group decomposition
//!
//! The drain sweep touches an entry's candidate links, their shared
//! virtual-constraint slots and their relay endpoint slots — nothing
//! else. Union-find over that slot space splits the entry list into
//! groups that provably share no load-table cell, so each group's
//! sweep reads and writes values no other group ever sees: solving the
//! groups independently is exactly the serial sweep restricted to each
//! group (the only deviation is the `1e-6`-byte drain threshold, which
//! applies per group instead of globally). Groups solve on scoped
//! worker threads when [`PlannerCfg::threads`] > 1 and merge in
//! canonical group order (first-appearance of each group in the
//! tenant-major entry list), so plans are **byte-identical for every
//! thread count** — the same invariance contract as the PR-3 parallel
//! sweep, pinned by `joint_thread_count_invariance` below. The
//! bottleneck cost metric is always used — `CostModel::sum_cost` is a
//! single-job ablation knob and is ignored by the joint solve.

use super::mwu::{next_volume, Planner, PlannerCfg};
use super::plan::{Assignment, Demand, Plan};
use super::replan::DrainCaps;
use crate::topology::{GpuId, LinkKind, Path, PathKind, Topology};
use std::collections::BTreeMap;
use std::time::Instant;

/// One tenant's slice of a joint planning problem.
#[derive(Clone, Debug)]
pub struct TenantDemands {
    /// Stable tenant id (the orchestrator uses the job id).
    pub tenant: usize,
    /// Fairness weight (≥ 0, finite); scales the tenant's MWU λ.
    pub weight: f64,
    pub demands: Vec<Demand>,
    /// Hysteresis seeds: the path kind each pair currently flies on.
    pub incumbent_kinds: Option<BTreeMap<(GpuId, GpuId), PathKind>>,
}

impl TenantDemands {
    pub fn new(tenant: usize, weight: f64, demands: Vec<Demand>) -> Self {
        TenantDemands { tenant, weight, demands, incumbent_kinds: None }
    }
}

/// Outcome of one joint solve.
#[derive(Clone, Debug)]
pub struct JointPlan {
    /// Per-tenant plans, keyed by [`TenantDemands::tenant`]. Each
    /// plan's `link_load` is only that tenant's own added load.
    pub per_tenant: BTreeMap<usize, Plan>,
    /// Sum of all tenants' added link loads (the accept metric's view).
    pub combined_link_load: Vec<f64>,
}

/// Number of virtual endpoint slots ([`joint_endpoint_inv_caps`]).
pub fn joint_endpoint_slots(topo: &Topology) -> usize {
    2 * topo.num_gpus()
}

/// Inverse capacities of the virtual endpoint constraints: per-GPU
/// injection (slots `0..G`) and per-GPU receive (slots `G..2G`), from
/// the same [`DrainCaps`] anchors the replan accept metric uses.
pub fn joint_endpoint_inv_caps(topo: &Topology, caps: &DrainCaps) -> Vec<f64> {
    let g = topo.num_gpus();
    let mut inv = Vec::with_capacity(2 * g);
    for _ in 0..g {
        inv.push(1.0 / (caps.inject_gbps * 1e9));
    }
    for _ in 0..g {
        inv.push(1.0 / (caps.recv_gbps * 1e9));
    }
    inv
}

/// Virtual-endpoint slots a path *differentially* consumes: every
/// interior (relay) GPU's injection and receive aggregate. Source
/// injection, destination receive and node-rail aggregates are common
/// to every candidate of a pair and deliberately excluded (they cannot
/// inform a routing choice — see the module docs).
pub fn path_relay_endpoints(topo: &Topology, path: &Path) -> Vec<usize> {
    let g = topo.num_gpus();
    let mut out = Vec::new();
    for &h in &path.hops {
        let nxt = topo.link(h).dst;
        // switch vertices on tiered fabrics are fixed-function
        // forwarders, not GPUs — they have no injection/receive budget
        if nxt != path.dst && !topo.is_switch(nxt) {
            out.push(nxt); // relay injects onward
            out.push(g + nxt); // relay receives
        }
    }
    out
}

/// Per-candidate hot-loop data for the joint sweep: real hops plus the
/// differential endpoint slots.
struct JointCand {
    hops: Vec<(usize, f64, f64)>, // (link, inv_cap_bps, inflate)
    endpoints: Vec<usize>,
    penalty: f64,
}

#[inline]
fn joint_path_cost(
    cfg: &PlannerCfg,
    load: &[f64],
    ep_load: &[f64],
    ep_inv: &[f64],
    c: &JointCand,
) -> f64 {
    let mut worst = 0.0f64;
    for &(h, inv, _) in &c.hops {
        let n = load[h] * inv;
        if n > worst {
            worst = n;
        }
    }
    for &e in &c.endpoints {
        let n = ep_load[e] * ep_inv[e];
        if n > worst {
            worst = n;
        }
    }
    cfg.cost.shape.apply(worst) + c.penalty
}

impl<'a> Planner<'a> {
    /// One joint solve over `tenants` (see the module docs).
    ///
    /// `initial` warm-starts the link costs with pressure *external* to
    /// the planned tenants (the orchestrator passes the monitor's
    /// deadbanded excess, or the in-flight residual routing at
    /// admission time); `ep_initial` does the same for the virtual
    /// endpoint slots. Deterministic: identical inputs yield
    /// byte-identical plans for every thread count (link-disjoint
    /// groups solve independently and merge in canonical order — see
    /// the module docs).
    pub fn plan_joint(
        &mut self,
        tenants: &[TenantDemands],
        initial: Option<&[f64]>,
        caps: &DrainCaps,
        ep_initial: Option<&[f64]>,
    ) -> JointPlan {
        let t0 = Instant::now();
        let shared = self.shared().clone();
        let topo = self.topo();
        let cfg = self.cfg().clone();
        let eps = cfg.epsilon_bytes.max(1.0);
        let num_links = topo.links.len();
        let ext_len = num_links + shared.len();

        // like the single-tenant MWU, the load table carries one
        // virtual entry per shared-constraint term (empty on flat).
        // These are the warm-start *base* tables: every group's sweep
        // starts from a copy and only ever touches its own slots.
        let load0 = match initial {
            Some(init) => {
                assert_eq!(init.len(), num_links);
                shared.extended_loads(init)
            }
            None => vec![0.0f64; ext_len],
        };
        let ep_inv = joint_endpoint_inv_caps(topo, caps);
        let ep_load0 = match ep_initial {
            Some(init) => {
                assert_eq!(init.len(), ep_inv.len());
                init.to_vec()
            }
            None => vec![0.0f64; ep_inv.len()],
        };
        let w_max = if tenants.is_empty() {
            1.0
        } else {
            tenants.iter().map(|t| t.weight).fold(f64::NEG_INFINITY, f64::max)
        };

        // tenant-major, pair-sorted entry list
        let mut order: Vec<(usize, (GpuId, GpuId))> = Vec::new();
        let mut totals: Vec<f64> = Vec::new();
        let mut lambdas: Vec<f64> = Vec::new();
        for (ti, t) in tenants.iter().enumerate() {
            let mut pairs: BTreeMap<(GpuId, GpuId), f64> = BTreeMap::new();
            for d in &t.demands {
                if d.bytes > 0.0 {
                    assert_ne!(d.src, d.dst, "self-demand ({}, {})", d.src, d.dst);
                    *pairs.entry((d.src, d.dst)).or_insert(0.0) += d.bytes;
                }
            }
            let lam = cfg.lambda * (t.weight / w_max);
            for (key, bytes) in pairs {
                order.push((ti, key));
                totals.push(bytes);
                lambdas.push(lam);
            }
        }

        let mut cands_by_entry: Vec<Vec<Path>> = Vec::with_capacity(order.len());
        let mut info_by_entry: Vec<Vec<JointCand>> = Vec::with_capacity(order.len());
        for (ei, &(_, (s, d))) in order.iter().enumerate() {
            let cands = self.candidates_for(s, d, totals[ei]).to_vec();
            let infos = cands
                .iter()
                .map(|p| {
                    let mut hops: Vec<(usize, f64, f64)> = p
                        .hops
                        .iter()
                        .enumerate()
                        .map(|(hi, &h)| {
                            let link = topo.link(h);
                            let inflate = if hi > 0
                                && matches!(link.kind, LinkKind::NvLink)
                            {
                                cfg.cost.relay_inflation
                            } else {
                                1.0
                            };
                            (h, 1.0 / (link.cap_gbps * 1e9), inflate)
                        })
                        .collect();
                    for &h in &p.hops {
                        for &ti in shared.terms_of(h) {
                            let term = &shared.terms[ti as usize];
                            hops.push((num_links + ti as usize, 1.0 / term.cap_bps, 1.0));
                        }
                    }
                    JointCand {
                        hops,
                        endpoints: path_relay_endpoints(topo, p),
                        penalty: cfg.cost.detour_penalty(topo, p, totals[ei]),
                    }
                })
                .collect();
            cands_by_entry.push(cands);
            info_by_entry.push(infos);
        }

        let mut incumbent: Vec<usize> = vec![usize::MAX; order.len()];
        for (ei, &(ti, key)) in order.iter().enumerate() {
            if let Some(seed) = &tenants[ti].incumbent_kinds {
                if let Some(kind) = seed.get(&key) {
                    if let Some(ci) =
                        cands_by_entry[ei].iter().position(|p| p.kind == *kind)
                    {
                        incumbent[ei] = ci;
                    }
                }
            }
        }

        // ---- link-disjoint group decomposition (module docs) ----
        // union-find over the joint slot space: links + shared virtual
        // terms (0..ext_len, as the candidate hop lists already encode
        // them) and relay endpoint slots (ext_len..ext_len + 2G)
        let n_slots = ext_len + ep_inv.len();
        let mut parent: Vec<u32> = vec![u32::MAX; n_slots]; // MAX = untouched root
        fn find(parent: &mut [u32], mut s: usize) -> usize {
            while parent[s] != u32::MAX && parent[s] as usize != s {
                let gp = parent[parent[s] as usize];
                if gp != u32::MAX {
                    parent[s] = gp; // path halving
                }
                s = parent[s] as usize;
            }
            s
        }
        fn slots_of(c: &JointCand, ext_len: usize) -> impl Iterator<Item = usize> + '_ {
            c.hops
                .iter()
                .map(|&(h, _, _)| h)
                .chain(c.endpoints.iter().map(move |&e| ext_len + e))
        }
        for infos in &info_by_entry {
            let mut first: Option<usize> = None;
            for c in infos {
                for s in slots_of(c, ext_len) {
                    let r = find(&mut parent, s);
                    match first {
                        None => {
                            parent[r] = r as u32;
                            first = Some(r);
                        }
                        Some(f) => {
                            let rf = find(&mut parent, f);
                            parent[r] = rf as u32;
                            first = Some(rf);
                        }
                    }
                }
            }
        }
        // group entries by root, in first-appearance (canonical) order
        let mut group_of_root: BTreeMap<usize, usize> = BTreeMap::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (ei, infos) in info_by_entry.iter().enumerate() {
            let Some(c0) = infos.first() else { continue };
            let Some(s0) = slots_of(c0, ext_len).next() else { continue };
            let root = find(&mut parent, s0);
            let gi = *group_of_root.entry(root).or_insert_with(|| {
                groups.push(Vec::new());
                groups.len() - 1
            });
            groups[gi].push(ei);
        }

        // one group's drain sweep, exactly the serial sweep restricted
        // to the group's entries (per-entry λ, hysteresis, the lot)
        struct GroupOut {
            flows: Vec<(usize, Vec<f64>)>, // (entry, per-candidate bytes)
            added: Vec<f64>,
            added_by_tenant: Vec<Vec<f64>>,
            visits: u64,
        }
        let drain_group = |entries: &[usize]| -> GroupOut {
            let mut load = load0.clone();
            let mut ep_load = ep_load0.clone();
            let mut added = vec![0.0f64; ext_len];
            let mut added_by_tenant: Vec<Vec<f64>> =
                tenants.iter().map(|_| vec![0.0f64; ext_len]).collect();
            let mut flows: Vec<Vec<f64>> = entries
                .iter()
                .map(|&ei| vec![0.0; info_by_entry[ei].len()])
                .collect();
            let mut inc: Vec<usize> = entries.iter().map(|&ei| incumbent[ei]).collect();
            let mut remaining: Vec<f64> = entries.iter().map(|&ei| totals[ei]).collect();
            let mut r_tot: f64 = 0.0;
            for r in &remaining {
                r_tot += r;
            }
            let mut active: Vec<usize> = (0..entries.len()).collect();
            let mut visits = 0u64;
            while r_tot > 1e-6 && !active.is_empty() {
                let mut ai = 0;
                while ai < active.len() {
                    let li = active[ai];
                    let ei = entries[li];
                    let infos = &info_by_entry[ei];
                    visits += 1;
                    let f_route =
                        next_volume(remaining[li], eps, lambdas[ei], infos.len());
                    let mut best_i = 0usize;
                    let mut best_c = f64::INFINITY;
                    for (i, c) in infos.iter().enumerate() {
                        let pc = joint_path_cost(&cfg, &load, &ep_load, &ep_inv, c);
                        if pc < best_c {
                            best_c = pc;
                            best_i = i;
                        }
                    }
                    if inc[li] != usize::MAX && inc[li] != best_i {
                        let inc_c = joint_path_cost(
                            &cfg,
                            &load,
                            &ep_load,
                            &ep_inv,
                            &infos[inc[li]],
                        );
                        if inc_c.is_finite()
                            && best_c >= inc_c * (1.0 - cfg.cost.hysteresis)
                        {
                            best_i = inc[li];
                        }
                    }
                    inc[li] = best_i;
                    let ti = order[ei].0;
                    for &(h, _, inflate) in &infos[best_i].hops {
                        load[h] += f_route * inflate;
                        added[h] += f_route;
                        added_by_tenant[ti][h] += f_route;
                    }
                    for &e in &infos[best_i].endpoints {
                        ep_load[e] += f_route;
                    }
                    flows[li][best_i] += f_route;
                    remaining[li] -= f_route;
                    r_tot -= f_route;
                    if remaining[li] <= 0.0 {
                        active.swap_remove(ai);
                    } else {
                        ai += 1;
                    }
                }
            }
            GroupOut {
                flows: entries.iter().copied().zip(flows).collect(),
                added,
                added_by_tenant,
                visits,
            }
        };

        // solve the groups — scoped workers when configured, and the
        // merge below is in canonical group order either way
        let outs: Vec<GroupOut> = if cfg.threads > 1 && groups.len() > 1 {
            let mut slots: Vec<Option<GroupOut>> =
                (0..groups.len()).map(|_| None).collect();
            let per = groups.len().div_ceil(cfg.threads.min(groups.len()));
            let drain = &drain_group;
            std::thread::scope(|scope| {
                for (gs, os) in groups.chunks(per).zip(slots.chunks_mut(per)) {
                    scope.spawn(move || {
                        for (g, o) in gs.iter().zip(os.iter_mut()) {
                            *o = Some(drain(g));
                        }
                    });
                }
            });
            slots.into_iter().map(|o| o.expect("group solved")).collect()
        } else {
            groups.iter().map(|g| drain_group(g)).collect()
        };

        // merge: groups are slot-disjoint, so elementwise sums place
        // each group's exact values (everything else contributes +0.0)
        let mut added = vec![0.0f64; ext_len];
        let mut added_by_tenant: Vec<Vec<f64>> =
            tenants.iter().map(|_| vec![0.0f64; ext_len]).collect();
        let mut flows_by_entry: Vec<Vec<f64>> =
            info_by_entry.iter().map(|c| vec![0.0; c.len()]).collect();
        let mut visits = 0u64;
        for o in outs {
            for (ei, f) in o.flows {
                flows_by_entry[ei] = f;
            }
            for (a, v) in added.iter_mut().zip(&o.added) {
                *a += v;
            }
            for (ti, row) in o.added_by_tenant.iter().enumerate() {
                for (a, v) in added_by_tenant[ti].iter_mut().zip(row) {
                    *a += v;
                }
            }
            visits += o.visits;
        }
        self.note_plan(visits);

        let plan_time_s = t0.elapsed().as_secs_f64();
        added.truncate(num_links);
        let mut per_tenant: BTreeMap<usize, Plan> = BTreeMap::new();
        for (ti, t) in tenants.iter().enumerate() {
            let mut ll = added_by_tenant[ti].clone();
            ll.truncate(num_links);
            per_tenant.insert(
                t.tenant,
                Plan { assignments: BTreeMap::new(), link_load: ll, plan_time_s },
            );
        }
        for (ei, &(ti, key)) in order.iter().enumerate() {
            let parts: Vec<(Path, f64)> = flows_by_entry[ei]
                .iter()
                .enumerate()
                .filter(|(_, &b)| b > 0.0)
                .map(|(ci, &b)| (cands_by_entry[ei][ci].clone(), b))
                .collect();
            if !parts.is_empty() {
                per_tenant
                    .get_mut(&tenants[ti].tenant)
                    .expect("tenant plan staged")
                    .assignments
                    .insert(key, Assignment { parts });
            }
        }
        JointPlan { per_tenant, combined_link_load: added }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::PlannerCfg;

    const MB: f64 = 1024.0 * 1024.0;

    fn caps() -> DrainCaps {
        DrainCaps::default()
    }

    /// Joint plans conserve every tenant's demand and are
    /// deterministic, byte for byte.
    #[test]
    fn joint_conserves_and_is_deterministic() {
        let t = Topology::paper();
        let a = vec![Demand::new(0, 1, 384.0 * MB), Demand::new(2, 1, 128.0 * MB)];
        let b = vec![Demand::new(4, 7, 256.0 * MB), Demand::new(2, 3, 96.0 * MB)];
        let tenants = vec![
            TenantDemands::new(10, 1.0, a.clone()),
            TenantDemands::new(11, 4.0, b.clone()),
        ];
        let run = |_: usize| {
            Planner::new(&t, PlannerCfg::default()).plan_joint(&tenants, None, &caps(), None)
        };
        let j1 = run(0);
        let j2 = run(1);
        j1.per_tenant[&10].validate(&t, &a).unwrap();
        j1.per_tenant[&11].validate(&t, &b).unwrap();
        assert_eq!(j1.per_tenant[&10].canonical_string(), j2.per_tenant[&10].canonical_string());
        assert_eq!(j1.per_tenant[&11].canonical_string(), j2.per_tenant[&11].canonical_string());
        // combined load is the sum of the per-tenant loads
        for (i, &c) in j1.combined_link_load.iter().enumerate() {
            let s = j1.per_tenant[&10].link_load[i] + j1.per_tenant[&11].link_load[i];
            assert!((c - s).abs() < 1e-6, "link {i}: {c} vs {s}");
        }
    }

    /// Two tenants hammering the same destination from different
    /// sources end up routed *around* each other: the joint bottleneck
    /// is no worse than either tenant planning alone on top of the
    /// other's load.
    #[test]
    fn joint_routes_tenants_around_each_other() {
        let t = Topology::paper();
        let a = vec![Demand::new(0, 1, 512.0 * MB)];
        let b = vec![Demand::new(2, 1, 512.0 * MB)];
        let tenants =
            vec![TenantDemands::new(0, 1.0, a.clone()), TenantDemands::new(1, 1.0, b)];
        let joint = Planner::new(&t, PlannerCfg::default())
            .plan_joint(&tenants, None, &caps(), None);
        // sequential baseline: tenant 0 alone, then tenant 1 on top
        let mut p = Planner::new(&t, PlannerCfg::default());
        let p0 = p.plan(&a);
        let p1 = p.plan_with_initial(&[Demand::new(2, 1, 512.0 * MB)], Some(&p0.link_load));
        let mut seq = vec![0.0; t.links.len()];
        for (i, s) in seq.iter_mut().enumerate() {
            *s = p0.link_load[i] + p1.link_load[i];
        }
        let max_norm = |loads: &[f64]| {
            loads
                .iter()
                .enumerate()
                .map(|(i, &l)| l / (t.link(i).cap_gbps * 1e9))
                .fold(0.0f64, f64::max)
        };
        assert!(
            max_norm(&joint.combined_link_load) <= max_norm(&seq) * 1.01,
            "joint bottleneck {} worse than sequential {}",
            max_norm(&joint.combined_link_load),
            max_norm(&seq)
        );
        // both tenants spread multi-path
        assert!(joint.per_tenant[&0].assignments[&(0, 1)].path_count() > 1);
        assert!(joint.per_tenant[&1].assignments[&(2, 1)].path_count() > 1);
    }

    /// Weight scaling: λ is scaled per tenant, and conservation still
    /// holds for extreme weight ratios.
    #[test]
    fn joint_weight_scaling_conserves() {
        let t = Topology::paper();
        let a = vec![Demand::new(0, 1, 512.0 * MB)];
        let b = vec![Demand::new(2, 3, 512.0 * MB)];
        let tenants = vec![
            TenantDemands::new(0, 1.0, a.clone()),
            TenantDemands::new(1, 4.0, b.clone()),
        ];
        let j = Planner::new(&t, PlannerCfg::default())
            .plan_joint(&tenants, None, &caps(), None);
        j.per_tenant[&0].validate(&t, &a).unwrap();
        j.per_tenant[&1].validate(&t, &b).unwrap();
    }

    /// Incumbent seeding: a seeded pair keeps its current path unless a
    /// challenger clearly wins (the anti-churn hysteresis).
    #[test]
    fn joint_respects_incumbent_seeds() {
        let t = Topology::paper();
        let demands = vec![Demand::new(0, 1, 8.0 * MB)];
        let mut seeds = BTreeMap::new();
        seeds.insert((0usize, 1usize), PathKind::IntraTwoHop { via: 2 });
        let mut td = TenantDemands::new(0, 1.0, demands);
        td.incumbent_kinds = Some(seeds);
        let j = Planner::new(&t, PlannerCfg::default())
            .plan_joint(&[td], None, &caps(), None);
        let a = &j.per_tenant[&0].assignments[&(0, 1)];
        // the seeded relay path carries bytes (it was not abandoned)
        assert!(a
            .parts
            .iter()
            .any(|(p, b)| p.kind == PathKind::IntraTwoHop { via: 2 } && *b > 0.0));
    }

    /// Thread count must not change a single byte of a joint plan: the
    /// group decomposition is input-determined and the merge is in
    /// canonical group order.
    #[test]
    fn joint_thread_count_invariance() {
        let t = Topology::paper();
        let tenants = vec![
            // tenants 0/1 overlap on node 0 (one group), tenant 2 is
            // node-1-internal (its own group)
            TenantDemands::new(0, 1.0, vec![Demand::new(0, 1, 384.0 * MB)]),
            TenantDemands::new(1, 2.0, vec![Demand::new(2, 1, 256.0 * MB)]),
            TenantDemands::new(2, 1.0, vec![Demand::new(4, 5, 512.0 * MB)]),
        ];
        let run = |threads: usize| {
            let cfg = PlannerCfg { threads, ..Default::default() };
            Planner::new(&t, cfg).plan_joint(&tenants, None, &caps(), None)
        };
        let j1 = run(1);
        for threads in [2, 8] {
            let j = run(threads);
            for (k, p) in &j1.per_tenant {
                assert_eq!(
                    p.canonical_string(),
                    j.per_tenant[k].canonical_string(),
                    "tenant {k} plan diverged at threads={threads}"
                );
            }
            for (x, y) in j1.combined_link_load.iter().zip(&j.combined_link_load) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    /// Group decomposition semantics: a tenant whose candidates share
    /// no link/endpoint slot with anyone else gets byte-identically the
    /// plan it would get planned alone (equal weights keep λ equal).
    #[test]
    fn joint_disjoint_tenants_solve_independently() {
        let t = Topology::paper();
        let a = TenantDemands::new(0, 1.0, vec![Demand::new(0, 1, 256.0 * MB)]);
        let b = TenantDemands::new(1, 1.0, vec![Demand::new(4, 6, 256.0 * MB)]);
        let joint = Planner::new(&t, PlannerCfg::default())
            .plan_joint(&[a.clone(), b.clone()], None, &caps(), None);
        let solo = Planner::new(&t, PlannerCfg::default())
            .plan_joint(&[a], None, &caps(), None);
        assert_eq!(
            joint.per_tenant[&0].canonical_string(),
            solo.per_tenant[&0].canonical_string(),
            "disjoint tenant's plan was perturbed by an unrelated tenant"
        );
    }

    /// Differential endpoint bookkeeping: relay endpoints are the only
    /// virtual slots a path consumes.
    #[test]
    fn relay_endpoints_are_differential() {
        let t = Topology::paper();
        let direct = crate::topology::path::candidates(&t, 0, 1, false).remove(0);
        assert!(path_relay_endpoints(&t, &direct).is_empty());
        let cands = crate::topology::path::candidates(&t, 0, 1, true);
        let relay = cands
            .iter()
            .find(|p| matches!(p.kind, PathKind::IntraTwoHop { .. }))
            .expect("relay candidate");
        let eps = path_relay_endpoints(&t, relay);
        assert_eq!(eps.len(), 2, "relay consumes its in and out aggregate");
        let g = t.num_gpus();
        assert!(eps[0] < g && eps[1] >= g);
        // inter-node rail path: the rail-adjacent GPUs are relays
        let inter = crate::topology::path::candidates(&t, 0, 5, true);
        assert!(inter.iter().any(|p| !path_relay_endpoints(&t, p).is_empty()));
    }
}
