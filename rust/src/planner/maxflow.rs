//! Dinic max-flow substrate.
//!
//! Used to bound what any routing scheme can achieve: for the traffic
//! aimed at a single destination (the paper's hotspot scenario), the
//! max-flow from a super-source to the hot GPU is an upper bound on
//! deliverable throughput — the planner's plans are checked against it
//! in the property suite, and the Fig 7 analysis uses it to show
//! NIMBLE sits near the achievable ceiling.
//!
//! Generic small-graph implementation (f64 capacities, adjacency
//! lists); the fabric graphs here have tens of vertices.

/// Directed flow network on vertices `0..n`.
pub struct FlowNet {
    n: usize,
    // edge arrays: to[i], cap[i]; paired edges i^1 are residuals
    to: Vec<usize>,
    cap: Vec<f64>,
    head: Vec<Vec<usize>>, // per-vertex edge indices
}

impl FlowNet {
    pub fn new(n: usize) -> FlowNet {
        FlowNet { n, to: Vec::new(), cap: Vec::new(), head: vec![Vec::new(); n] }
    }

    /// Add a directed edge u→v with capacity c (and residual v→u of 0).
    pub fn add_edge(&mut self, u: usize, v: usize, c: f64) {
        assert!(u < self.n && v < self.n);
        let e = self.to.len();
        self.to.push(v);
        self.cap.push(c);
        self.head[u].push(e);
        self.to.push(u);
        self.cap.push(0.0);
        self.head[v].push(e + 1);
    }

    /// Max flow from s to t (Dinic). Returns total flow value.
    pub fn max_flow(&mut self, s: usize, t: usize) -> f64 {
        assert_ne!(s, t);
        let mut total = 0.0f64;
        loop {
            // BFS level graph
            let mut level = vec![usize::MAX; self.n];
            level[s] = 0;
            let mut q = std::collections::VecDeque::from([s]);
            while let Some(u) = q.pop_front() {
                for &e in &self.head[u] {
                    let v = self.to[e];
                    if self.cap[e] > 1e-12 && level[v] == usize::MAX {
                        level[v] = level[u] + 1;
                        q.push_back(v);
                    }
                }
            }
            if level[t] == usize::MAX {
                return total;
            }
            // DFS blocking flow with iteration pointers
            let mut it = vec![0usize; self.n];
            loop {
                let pushed = self.dfs(s, t, f64::INFINITY, &level, &mut it);
                if pushed <= 1e-12 {
                    break;
                }
                total += pushed;
            }
        }
    }

    fn dfs(&mut self, u: usize, t: usize, f: f64, level: &[usize], it: &mut [usize]) -> f64 {
        if u == t {
            return f;
        }
        while it[u] < self.head[u].len() {
            let e = self.head[u][it[u]];
            let v = self.to[e];
            if self.cap[e] > 1e-12 && level[v] == level[u] + 1 {
                let d = self.dfs(v, t, f.min(self.cap[e]), level, it);
                if d > 1e-12 {
                    self.cap[e] -= d;
                    self.cap[e ^ 1] += d;
                    return d;
                }
            }
            it[u] += 1;
        }
        0.0
    }
}

use crate::topology::{GpuId, LinkKind, Topology};

/// Max deliverable rate (GB/s) from a set of sources (with per-source
/// demand weights ignored — pure capacity) to a single destination
/// GPU, over rail-matched links only. Vertices: GPUs (+ switches on
/// tiered fabrics) + super-source.
pub fn max_rate_to_destination(topo: &Topology, sources: &[GpuId], dst: GpuId) -> f64 {
    let g = topo.num_gpus();
    let s_super = g + topo.num_switches();
    let mut net = FlowNet::new(s_super + 1);
    for l in &topo.links {
        if matches!(l.kind, LinkKind::CrossRail { .. }) {
            continue; // NIMBLE never uses mismatched rails
        }
        net.add_edge(l.src, l.dst, l.cap_gbps);
    }
    for &s in sources {
        if s != dst {
            net.add_edge(s_super, s, f64::INFINITY);
        }
    }
    net.max_flow(s_super, dst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn textbook_max_flow() {
        // classic CLRS-style example, max flow = 23
        let mut net = FlowNet::new(6);
        let edges = [
            (0, 1, 16.0),
            (0, 2, 13.0),
            (1, 2, 10.0),
            (2, 1, 4.0),
            (1, 3, 12.0),
            (3, 2, 9.0),
            (2, 4, 14.0),
            (4, 3, 7.0),
            (3, 5, 20.0),
            (4, 5, 4.0),
        ];
        for (u, v, c) in edges {
            net.add_edge(u, v, c);
        }
        let f = net.max_flow(0, 5);
        assert!((f - 23.0).abs() < 1e-9, "f={f}");
    }

    #[test]
    fn disconnected_is_zero() {
        let mut net = FlowNet::new(4);
        net.add_edge(0, 1, 5.0);
        net.add_edge(2, 3, 5.0);
        assert_eq!(net.max_flow(0, 3), 0.0);
    }

    #[test]
    fn parallel_paths_add() {
        let mut net = FlowNet::new(4);
        net.add_edge(0, 1, 3.0);
        net.add_edge(1, 3, 3.0);
        net.add_edge(0, 2, 4.0);
        net.add_edge(2, 3, 4.0);
        assert!((net.max_flow(0, 3) - 7.0).abs() < 1e-9);
    }

    /// Intra-node incast ceiling: 3 peers → 1 GPU is bounded by the
    /// destination's total in-capacity — 3 NVLink edges plus its rail
    /// (max-flow may legally detour through the other node, a path the
    /// planner does not use; the bound is an upper bound either way).
    #[test]
    fn intra_incast_ceiling() {
        let t = Topology::paper();
        let rate = max_rate_to_destination(&t, &[0, 1, 2], 3);
        assert!((rate - (3.0 * 120.0 + 45.1)).abs() < 1e-6, "rate={rate}");
    }

    /// Cross-node hotspot ceiling: node-0 sources into GPU 4 pass the
    /// 4 rails (4×45.1) but must land on GPU 4 whose in-degree is
    /// 3 NVLink + rail 0 — rails 1–3 relay through peers.
    #[test]
    fn inter_hotspot_ceiling() {
        let t = Topology::paper();
        let rate = max_rate_to_destination(&t, &[0, 1, 2, 3], 4);
        // bounded by the rails: 180.4; landing capacity 3·120+45.1 ≫
        assert!((rate - 4.0 * 45.1).abs() < 1e-6, "rate={rate}");
    }

    /// With peers on the destination node also sending, the ceiling is
    /// the destination's total in-capacity.
    #[test]
    fn full_incast_ceiling() {
        let t = Topology::paper();
        let all: Vec<usize> = (0..8).filter(|&g| g != 4).collect();
        let rate = max_rate_to_destination(&t, &all, 4);
        assert!((rate - (3.0 * 120.0 + 45.1)).abs() < 1e-6, "rate={rate}");
    }
}
