//! Algorithm 1 — link load balancing with iterative approximation.
//!
//! Faithful implementation of the paper's multiplicative-weights /
//! Garg–Könemann-inspired scheme: sweep over all pairs with remaining
//! demand, route a λ-fraction (rounded to the ε chunk granularity)
//! onto the currently cheapest candidate path, update link loads and
//! costs, repeat until all demand is placed. After `n` visits a pair
//! has `(1−λ)^n` of its demand left, which is what yields the
//! approximation guarantee of the fractional MCF scheme.
//!
//! Extras the paper calls out and we implement:
//! * **hysteresis** — an alternative must beat the incumbent path by a
//!   relative margin before the pair switches paths between visits;
//! * **size-aware penalty** in the cost (`CostModel::detour_penalty`)
//!   so small messages stay single-path;
//! * candidate caching per pair (the topology is static).
//!
//! ## Deterministic parallel sweep (`PlannerCfg::threads`)
//!
//! With `threads > 1` the sweep fans out over `std::thread::scope`
//! (zero new deps) while staying **byte-identical to the serial sweep
//! for every thread count**. Two observations make that possible:
//!
//! 1. The per-visit routed volume `f_route` depends only on the pair's
//!    residual — never on link loads — so the serial sweep's exact
//!    visit sequence (which pair, how many bytes, in what order) is a
//!    *load-independent script* computable by a cheap pre-pass.
//! 2. A pair's routing decision reads only the links its candidates
//!    touch, so pairs in different **link-disjoint components** of the
//!    demand set cannot influence each other.
//!
//! The pre-pass replays the serial drain bookkeeping to produce the
//! script, the script is split per component, and workers execute the
//! component scripts concurrently (candidate enumeration for uncached
//! pairs is also fanned out). Results merge in fixed component order;
//! since components share no links, every merged value has exactly one
//! contributor and the merge order cannot perturb a single bit. Thread
//! count only changes which worker replays which script — the plan is
//! the same. A fully-coupled demand set (e.g. all-to-all over shared
//! rails) is one component and sweeps serially; parallelism pays on
//! decomposable traffic (per-node batches, concurrent jobs) and in the
//! candidate precompute. DESIGN.md §9 records this contract.

use super::constraints::SharedConstraints;
use super::cost::{CostModel, CostShape};
use super::plan::{Assignment, Demand, Plan};
use crate::topology::path::{candidates, live_candidates};
use crate::topology::{GpuId, Path, PathKind, Topology};
use std::collections::BTreeMap;
use std::time::Instant;

/// Planner configuration (Algorithm 1's λ and ε plus the cost model).
#[derive(Clone, Debug)]
pub struct PlannerCfg {
    /// Flow fraction routed per visit (λ).
    pub lambda: f64,
    /// Chunk granularity in bytes (ε).
    pub epsilon_bytes: f64,
    /// Cost model `F` + penalties + hysteresis.
    pub cost: CostModel,
    /// Allow multi-path at all (false ⇒ always the default path —
    /// used for baseline comparisons and tiny messages).
    pub multipath: bool,
    /// Worker threads for the sweep and the candidate precompute.
    /// Plans are byte-identical for every value (see the module docs);
    /// 1 (the default) keeps the fully serial pre-threads code path.
    pub threads: usize,
}

impl Default for PlannerCfg {
    fn default() -> Self {
        PlannerCfg {
            lambda: 0.25,
            epsilon_bytes: 512.0 * 1024.0,
            cost: CostModel::default(),
            multipath: true,
            threads: 1,
        }
    }
}

/// Per-link capacity health the fault-recovery replan path feeds the
/// planner ([`Planner::set_link_health`]): `scale[l]` multiplies link
/// `l`'s capacity (1.0 healthy, 0.0 dead), and `live[l]` is the
/// enumeration mask derived from it. Dead links are **masked out of
/// candidate enumeration**, not infinitely priced — no load level can
/// route bytes onto a link that cannot move them (DESIGN.md §13).
#[derive(Clone, Debug)]
pub struct LinkHealth {
    /// Capacity multiplier per physical link, in `(0, 1]` ∪ {0}.
    pub scale: Vec<f64>,
    /// `scale[l] > 0.0` — the candidate-enumeration liveness mask.
    pub live: Vec<bool>,
}

impl LinkHealth {
    pub fn from_scale(scale: Vec<f64>) -> Self {
        let live = scale.iter().map(|&s| s > 0.0).collect();
        LinkHealth { scale, live }
    }
}

pub struct Planner<'a> {
    topo: &'a Topology,
    cfg: PlannerCfg,
    /// Cached candidate paths per (src,dst) pair.
    cand_cache: BTreeMap<(GpuId, GpuId), Vec<Path>>,
    /// Shared aggregate terms (leaf uplink/downlink capacity on tiered
    /// fabrics; empty — and therefore inert — on flat ones). Each term
    /// is one virtual entry at the tail of the MWU load table.
    shared: SharedConstraints,
    /// Current fault-induced capacity health. `None` (the default, and
    /// the only state fault-free runs ever see) keeps every code path
    /// byte-identical to the pre-fault planner.
    health: Option<LinkHealth>,
    /// Plans produced (single-tenant sweeps and joint solves alike) —
    /// telemetry self-profiling, never read by the planning math.
    plans: u64,
    /// Algorithm-1 visits in the most recent plan. The visit count is
    /// a pure function of the demand set and λ/ε (the script is
    /// load-independent), so it is identical for every thread count.
    last_visits: u64,
    /// Cumulative visits across this planner's lifetime.
    total_visits: u64,
}

impl<'a> Planner<'a> {
    pub fn new(topo: &'a Topology, cfg: PlannerCfg) -> Self {
        let shared = SharedConstraints::of(topo);
        Planner {
            topo,
            cfg,
            cand_cache: BTreeMap::new(),
            shared,
            health: None,
            plans: 0,
            last_visits: 0,
            total_visits: 0,
        }
    }

    /// Fold one finished plan into the self-profiling counters.
    pub(crate) fn note_plan(&mut self, visits: u64) {
        self.plans += 1;
        self.last_visits = visits;
        self.total_visits += visits;
    }

    /// Plans produced so far (telemetry `profile.mwu_plans`).
    pub fn mwu_plans(&self) -> u64 {
        self.plans
    }

    /// Algorithm-1 visits of the most recent plan (the decision
    /// record's `mwu_visits`).
    pub fn mwu_last_visits(&self) -> u64 {
        self.last_visits
    }

    /// Cumulative visits across every plan this planner produced.
    pub fn mwu_total_visits(&self) -> u64 {
        self.total_visits
    }

    /// Install (or clear) the per-link capacity health the next plans
    /// route against. Dead links (`scale == 0`) are masked out of
    /// candidate enumeration, degraded links are re-priced at their
    /// scaled capacity, and tiered shared terms are rebuilt from scaled
    /// member capacities. Clears the candidate cache — enumeration
    /// depends on the mask.
    pub fn set_link_health(&mut self, scale: Option<Vec<f64>>) {
        self.cand_cache.clear();
        match scale {
            Some(s) => {
                assert_eq!(s.len(), self.topo.links.len(), "health vector length");
                self.shared = SharedConstraints::of_scaled(self.topo, &s);
                self.health = Some(LinkHealth::from_scale(s));
            }
            None => {
                self.shared = SharedConstraints::of(self.topo);
                self.health = None;
            }
        }
    }

    /// The currently-installed link health, if any.
    pub fn health(&self) -> Option<&LinkHealth> {
        self.health.as_ref()
    }

    pub fn cfg(&self) -> &PlannerCfg {
        &self.cfg
    }

    /// The topology this planner routes over.
    pub fn topo(&self) -> &'a Topology {
        self.topo
    }

    /// The shared-constraint set this planner prices (empty on flat).
    pub fn shared(&self) -> &SharedConstraints {
        &self.shared
    }

    pub(crate) fn candidates_for(&mut self, s: GpuId, d: GpuId, msg_bytes: f64) -> &[Path] {
        let multipath =
            self.cfg.multipath && msg_bytes > self.cfg.cost.multipath_min_bytes;
        let key = cache_key(self.topo.num_gpus(), s, d, multipath);
        let topo = self.topo;
        let health = self.health.as_ref();
        self.cand_cache.entry(key).or_insert_with(|| match health {
            Some(h) => live_candidates(topo, s, d, multipath, &h.live),
            None => candidates(topo, s, d, multipath),
        })
    }

    /// Materialize candidate paths and hot-loop info for every pair.
    /// With `threads > 1`, candidate enumeration for pairs missing from
    /// the cache fans out over fixed contiguous partitions; the results
    /// are pure functions of the static topology and merge in partition
    /// order, so the cache ends up exactly as a serial fill would leave
    /// it.
    fn resolve_candidates(
        &mut self,
        order: &[(GpuId, GpuId)],
        totals: &[f64],
    ) -> (Vec<Vec<Path>>, Vec<Vec<Cand>>) {
        if self.cfg.threads > 1 {
            let g = self.topo.num_gpus();
            let mut seen: std::collections::BTreeSet<(GpuId, GpuId)> = Default::default();
            let mut missing: Vec<(GpuId, GpuId, bool)> = Vec::new();
            for (pi, &(s, d)) in order.iter().enumerate() {
                let multipath =
                    self.cfg.multipath && totals[pi] > self.cfg.cost.multipath_min_bytes;
                let key = cache_key(g, s, d, multipath);
                if !self.cand_cache.contains_key(&key) && seen.insert(key) {
                    missing.push((s, d, multipath));
                }
            }
            if !missing.is_empty() {
                let topo = self.topo;
                let live = self.health.as_ref().map(|h| h.live.as_slice());
                let workers = self.cfg.threads.min(missing.len());
                let chunk = (missing.len() + workers - 1) / workers;
                let mut parts: Vec<Vec<((GpuId, GpuId), Vec<Path>)>> = Vec::new();
                std::thread::scope(|sc| {
                    let mut handles = Vec::new();
                    for slice in missing.chunks(chunk) {
                        handles.push(sc.spawn(move || {
                            slice
                                .iter()
                                .map(|&(s, d, multipath)| {
                                    let key = cache_key(g, s, d, multipath);
                                    let paths = match live {
                                        Some(lv) => {
                                            live_candidates(topo, s, d, multipath, lv)
                                        }
                                        None => candidates(topo, s, d, multipath),
                                    };
                                    (key, paths)
                                })
                                .collect::<Vec<_>>()
                        }));
                    }
                    for h in handles {
                        parts.push(h.join().expect("candidate worker panicked"));
                    }
                });
                for part in parts {
                    for (key, paths) in part {
                        self.cand_cache.insert(key, paths);
                    }
                }
            }
        }
        // Precompute per-candidate hot-loop data; the sweep then
        // touches only flat arrays.
        let cfg = self.cfg.clone();
        let mut cands_by_pair: Vec<Vec<Path>> = Vec::with_capacity(order.len());
        let mut info_by_pair: Vec<Vec<Cand>> = Vec::with_capacity(order.len());
        let num_links = self.topo.links.len();
        for (pi, &(s, d)) in order.iter().enumerate() {
            let cands = self.candidates_for(s, d, totals[pi]).to_vec();
            let infos = cands
                .iter()
                .map(|p| {
                    let mut hops: Vec<(usize, f64, f64)> = p
                        .hops
                        .iter()
                        .enumerate()
                        .map(|(hi, &h)| {
                            let link = self.topo.link(h);
                            let inflate = if hi > 0
                                && matches!(link.kind, crate::topology::LinkKind::NvLink)
                            {
                                cfg.cost.relay_inflation
                            } else {
                                1.0
                            };
                            // Degraded links are priced at their scaled
                            // capacity (the clamp keeps the fully-cut
                            // fallback's arithmetic finite); with no
                            // health installed this is the exact
                            // pre-fault expression.
                            let inv_cap = match &self.health {
                                Some(hl) => {
                                    1.0 / (link.cap_gbps * hl.scale[h].max(1e-6) * 1e9)
                                }
                                None => 1.0 / (link.cap_gbps * 1e9),
                            };
                            (h, inv_cap, inflate)
                        })
                        .collect();
                    // Shared aggregate terms the path draws down become
                    // virtual hops (indices past the physical links) so
                    // the sweep prices and charges them like links. Flat
                    // fabrics emit none — `hops` is exactly the old list.
                    for &h in &p.hops {
                        for &ti in self.shared.terms_of(h) {
                            let term = &self.shared.terms[ti as usize];
                            hops.push((num_links + ti as usize, 1.0 / term.cap_bps, 1.0));
                        }
                    }
                    Cand {
                        hops,
                        penalty: cfg.cost.detour_penalty(self.topo, p, totals[pi]),
                    }
                })
                .collect();
            cands_by_pair.push(cands);
            info_by_pair.push(infos);
        }
        (cands_by_pair, info_by_pair)
    }

    /// Run Algorithm 1 over the demand set (cold start: `L_e ← 0`).
    pub fn plan(&mut self, demands: &[Demand]) -> Plan {
        self.plan_with_initial(demands, None)
    }

    /// Run Algorithm 1 warm-started from observed link loads (the
    /// execution-time adaptation loop: the monitor's estimates seed
    /// `L_e` so this round's routing avoids links other traffic is
    /// already pressing on). `Plan::link_load` reports only the load
    /// *added* by this plan, keeping `validate()` exact.
    pub fn plan_with_initial(&mut self, demands: &[Demand], initial: Option<&[f64]>) -> Plan {
        self.plan_seeded(demands, initial, None)
    }

    /// Full warm start for the execution-time re-planning loop: besides
    /// the observed initial loads, seed each pair's hysteresis
    /// *incumbent* with the path it is already flying on (identified by
    /// [`PathKind`], which is unique per pair). A seeded pair keeps its
    /// current path unless a challenger beats it by the configured
    /// hysteresis margin — the anti-churn property §I asks for.
    pub fn plan_seeded(
        &mut self,
        demands: &[Demand],
        initial: Option<&[f64]>,
        incumbent_kinds: Option<&BTreeMap<(GpuId, GpuId), PathKind>>,
    ) -> Plan {
        let t0 = Instant::now();
        let cfg = self.cfg.clone();
        let eps = cfg.epsilon_bytes.max(1.0);

        // L_e ← initial (cost basis); `added` tracks this plan's own
        // load. Both vectors carry the physical links first, then one
        // virtual entry per shared aggregate term (none on flat, so
        // this is exactly the pre-tier table there).
        let ext_len = self.topo.links.len() + self.shared.len();
        let load = match initial {
            Some(init) => {
                assert_eq!(init.len(), self.topo.links.len());
                self.shared.extended_loads(init)
            }
            None => vec![0.0f64; ext_len],
        };
        let mut added = vec![0.0f64; ext_len];
        // r_{s,d} ← d_{s,d}; aggregate duplicate pairs
        let mut pairs: BTreeMap<(GpuId, GpuId), f64> = BTreeMap::new();
        for d in demands {
            if d.bytes > 0.0 {
                assert_ne!(d.src, d.dst, "self-demand ({}, {})", d.src, d.dst);
                *pairs.entry((d.src, d.dst)).or_insert(0.0) += d.bytes;
            }
        }
        let order: Vec<(GpuId, GpuId)> = pairs.keys().cloned().collect();
        let totals: Vec<f64> = order.iter().map(|k| pairs[k]).collect();

        let (cands_by_pair, info_by_pair) = self.resolve_candidates(&order, &totals);

        // Flows^(s,d): byte volume per candidate index (no per-visit
        // allocation or path cloning).
        let mut flows_by_pair: Vec<Vec<f64>> =
            info_by_pair.iter().map(|c| vec![0.0; c.len()]).collect();
        // hysteresis state: incumbent candidate per pair (optionally
        // seeded from the paths currently in flight)
        let mut incumbent: Vec<usize> = vec![usize::MAX; order.len()];
        if let Some(seed) = incumbent_kinds {
            for (pi, key) in order.iter().enumerate() {
                if let Some(kind) = seed.get(key) {
                    if let Some(ci) =
                        cands_by_pair[pi].iter().position(|p| p.kind == *kind)
                    {
                        incumbent[pi] = ci;
                    }
                }
            }
        }

        // A fully-coupled demand set is one conflict component and
        // cannot fan out — take the serial path without the script /
        // worker overhead (the result is byte-identical either way).
        let components = if cfg.threads > 1 && order.len() > 1 {
            // components split on the extended table: pairs sharing only
            // a leaf aggregate (not a physical link) still couple
            let comp_of_pair = conflict_components(&info_by_pair, ext_len);
            let n_comps =
                comp_of_pair.iter().copied().max().map_or(0, |m| m as usize + 1);
            (n_comps > 1).then_some((comp_of_pair, n_comps))
        } else {
            None
        };
        let visits = match components {
            None => {
                // serial sweep: immediate load updates, global drain
                // state (the pre-threads code path)
                let mut load = load;
                let mut visits = 0u64;
                drive_drain_schedule(&totals, eps, cfg.lambda, &info_by_pair, |pi, f_route| {
                    visits += 1;
                    route_visit(
                        &cfg.cost,
                        &info_by_pair[pi],
                        &mut incumbent[pi],
                        f_route,
                        &mut load,
                        &mut added,
                        &mut flows_by_pair[pi],
                    );
                });
                visits
            }
            Some((comp_of_pair, n_comps)) => sweep_parallel(
                &cfg,
                eps,
                &info_by_pair,
                &totals,
                &incumbent,
                &load,
                &comp_of_pair,
                n_comps,
                &mut added,
                &mut flows_by_pair,
            ),
        };
        self.note_plan(visits);

        // `Plan::link_load` reports physical links only; the virtual
        // tail was bookkeeping for the sweep's cost basis.
        added.truncate(self.topo.links.len());
        let mut assignments = BTreeMap::new();
        for (pi, key) in order.iter().enumerate() {
            let parts: Vec<(Path, f64)> = flows_by_pair[pi]
                .iter()
                .enumerate()
                .filter(|(_, &b)| b > 0.0)
                .map(|(ci, &b)| (cands_by_pair[pi][ci].clone(), b))
                .collect();
            if !parts.is_empty() {
                assignments.insert(*key, Assignment { parts });
            }
        }
        Plan {
            assignments,
            link_load: added,
            plan_time_s: t0.elapsed().as_secs_f64(),
        }
    }
}

/// Candidate-cache key: folds the multipath decision in via a sentinel
/// pair ordering (`s + num_gpus` never collides with a real source id),
/// so both variants live under distinct keys.
#[inline]
fn cache_key(num_gpus: usize, s: GpuId, d: GpuId, multipath: bool) -> (GpuId, GpuId) {
    if multipath {
        (s, d)
    } else {
        (s + num_gpus, d)
    }
}

/// Precomputed per-candidate hot-loop data: hop link ids with
/// 1/(cap·1e9) and relay inflation factors, plus the (msg-size
/// dependent but load-independent) detour penalty.
struct Cand {
    hops: Vec<(usize, f64, f64)>, // (link, inv_cap_bps, inflate)
    penalty: f64,
}

/// F is monotone, so max_e F(norm_e) = F(max_e norm_e): the bottleneck
/// metric tracks the max normalized load only (the sum_cost ablation
/// applies F per hop instead).
#[inline]
fn path_cost(shape: CostShape, sum_cost: bool, load: &[f64], c: &Cand) -> f64 {
    if sum_cost {
        let mut agg = 0.0;
        for &(h, inv, _) in &c.hops {
            agg += shape.apply(load[h] * inv);
        }
        agg + c.penalty
    } else {
        let mut worst = 0.0f64;
        for &(h, inv, _) in &c.hops {
            let n = load[h] * inv;
            if n > worst {
                worst = n;
            }
        }
        shape.apply(worst) + c.penalty
    }
}

/// Algorithm 1's per-visit volume: the full residual below the chunk
/// granularity ε (and for single-candidate pairs, whose every chunk
/// must land on that one path anyway), else ⌊r·λ⌋_ε, at least ε so the
/// sweep always progresses. **Load-independent** — the property the
/// parallel sweep's visit script rests on.
#[inline]
pub(crate) fn next_volume(r: f64, eps: f64, lambda: f64, n_cands: usize) -> f64 {
    if r < eps || n_cands == 1 {
        r
    } else {
        ((r * lambda / eps).floor() * eps).max(eps).min(r)
    }
}

/// Drive Algorithm 1's drain bookkeeping, calling `visit(pi, f_route)`
/// for every visit in exactly the serial sweep's order (repeated passes
/// over the active pair list, drained pairs swap-removed). This single
/// driver is shared by the serial sweep (routing each visit
/// immediately) and the parallel pre-pass (recording the visit script),
/// so the two can never diverge operation-for-operation — the
/// byte-identity contract of `PlannerCfg::threads` rests on it.
fn drive_drain_schedule<F: FnMut(usize, f64)>(
    totals: &[f64],
    eps: f64,
    lambda: f64,
    info_by_pair: &[Vec<Cand>],
    mut visit: F,
) {
    let mut remaining = totals.to_vec();
    let mut r_tot: f64 = remaining.iter().sum();
    let mut active: Vec<usize> = (0..totals.len()).collect();
    while r_tot > 1e-6 && !active.is_empty() {
        let mut ai = 0;
        while ai < active.len() {
            let pi = active[ai];
            let f_route = next_volume(remaining[pi], eps, lambda, info_by_pair[pi].len());
            visit(pi, f_route);
            remaining[pi] -= f_route;
            r_tot -= f_route;
            if remaining[pi] <= 0.0 {
                active.swap_remove(ai);
            } else {
                ai += 1;
            }
        }
    }
}

/// One Algorithm-1 visit of a pair: select the least-cost candidate
/// (bottleneck metric, with hysteresis — the incumbent survives unless
/// the challenger wins by the configured margin), then place `f_route`
/// bytes on it. Shared verbatim by the serial sweep and the parallel
/// per-component script replay, which is what keeps them bit-identical.
#[inline]
fn route_visit(
    cost: &CostModel,
    infos: &[Cand],
    incumbent: &mut usize,
    f_route: f64,
    load: &mut [f64],
    added: &mut [f64],
    flows: &mut [f64],
) {
    let mut best_i = 0usize;
    let mut best_c = f64::INFINITY;
    for (i, c) in infos.iter().enumerate() {
        let pc = path_cost(cost.shape, cost.sum_cost, load, c);
        if pc < best_c {
            best_c = pc;
            best_i = i;
        }
    }
    let inc = *incumbent;
    if inc != usize::MAX && inc != best_i {
        let inc_c = path_cost(cost.shape, cost.sum_cost, load, &infos[inc]);
        if inc_c.is_finite() && best_c >= inc_c * (1.0 - cost.hysteresis) {
            best_i = inc;
        }
    }
    *incumbent = best_i;
    for &(h, _, inflate) in &infos[best_i].hops {
        load[h] += f_route * inflate;
        added[h] += f_route;
    }
    flows[best_i] += f_route;
}

/// Partition pairs into components that share no candidate links
/// (union-find keyed by first-seen link owner). Deterministic:
/// component ids are assigned in order of each component's smallest
/// pair index. Pairs in different components provably cannot read or
/// write each other's link loads during the sweep.
fn conflict_components(info_by_pair: &[Vec<Cand>], num_links: usize) -> Vec<u32> {
    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize];
            x = parent[x as usize];
        }
        x
    }
    let n = info_by_pair.len();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    let mut link_owner: Vec<u32> = vec![u32::MAX; num_links];
    for pi in 0..n {
        for c in &info_by_pair[pi] {
            for &(h, _, _) in &c.hops {
                if link_owner[h] == u32::MAX {
                    link_owner[h] = pi as u32;
                } else {
                    let a = find(&mut parent, pi as u32);
                    let b = find(&mut parent, link_owner[h]);
                    if a != b {
                        // roots always point at the smaller index, so a
                        // component's root is its smallest member
                        parent[a.max(b) as usize] = a.min(b);
                    }
                }
            }
        }
    }
    let mut ids: Vec<u32> = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut out = vec![0u32; n];
    for pi in 0..n {
        let r = find(&mut parent, pi as u32) as usize;
        if ids[r] == u32::MAX {
            ids[r] = next;
            next += 1;
        }
        out[pi] = ids[r];
    }
    out
}

/// The parallel sweep: replay the serial drain bookkeeping
/// ([`drive_drain_schedule`]) to obtain the exact visit script
/// (`next_volume` is load-independent), split it across the
/// link-disjoint components, execute the component scripts on a fixed
/// worker partition (worker *w* takes components *w*, *w+T*, …) and
/// merge the results in component order. Every merged entry has
/// exactly one contributing component, so the outcome is byte-identical
/// to the serial sweep for any worker count. Returns the total visit
/// count (the summed script lengths — exactly the serial sweep's
/// visit count, since the driver generating the scripts is shared).
#[allow(clippy::too_many_arguments)]
fn sweep_parallel(
    cfg: &PlannerCfg,
    eps: f64,
    info_by_pair: &[Vec<Cand>],
    totals: &[f64],
    incumbent0: &[usize],
    base_load: &[f64],
    comp_of_pair: &[u32],
    n_comps: usize,
    added: &mut [f64],
    flows_by_pair: &mut [Vec<f64>],
) -> u64 {
    // the load-independent visit script, split per component as it is
    // generated (= the serial visit sequence, in order, per component)
    let mut scripts: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n_comps];
    drive_drain_schedule(totals, eps, cfg.lambda, info_by_pair, |pi, f_route| {
        scripts[comp_of_pair[pi] as usize].push((pi as u32, f_route));
    });
    let visits: u64 = scripts.iter().map(|s| s.len() as u64).sum();
    // execute component scripts on the fixed worker partition
    let workers = cfg.threads.min(n_comps).max(1);
    type CompOut = (Vec<(usize, f64)>, Vec<(usize, Vec<f64>)>);
    let mut comp_results: Vec<Option<CompOut>> = (0..n_comps).map(|_| None).collect();
    std::thread::scope(|s| {
        let scripts = &scripts;
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            handles.push(s.spawn(move || {
                let mut out: Vec<(usize, CompOut)> = Vec::new();
                let mut ci = w;
                while ci < scripts.len() {
                    out.push((
                        ci,
                        run_component_script(
                            cfg,
                            info_by_pair,
                            incumbent0,
                            base_load,
                            &scripts[ci],
                        ),
                    ));
                    ci += workers;
                }
                out
            }));
        }
        for h in handles {
            for (ci, res) in h.join().expect("sweep worker panicked") {
                comp_results[ci] = Some(res);
            }
        }
    });
    // merge in component order
    for res in comp_results.into_iter().flatten() {
        let (comp_added, comp_flows) = res;
        for (h, v) in comp_added {
            added[h] += v;
        }
        for (pi, fl) in comp_flows {
            flows_by_pair[pi] = fl;
        }
    }
    visits
}

/// Execute one component's visit script against a private copy of the
/// warm-start loads. Returns the sparse added-load contributions and
/// the per-pair flow splits of this component.
fn run_component_script(
    cfg: &PlannerCfg,
    info_by_pair: &[Vec<Cand>],
    incumbent0: &[usize],
    base_load: &[f64],
    script: &[(u32, f64)],
) -> (Vec<(usize, f64)>, Vec<(usize, Vec<f64>)>) {
    let mut load = base_load.to_vec();
    let mut added = vec![0.0f64; base_load.len()];
    let mut incumbent: BTreeMap<usize, usize> = BTreeMap::new();
    let mut flows: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    for &(pi, f_route) in script {
        let pi = pi as usize;
        let inc = incumbent.entry(pi).or_insert(incumbent0[pi]);
        let fl = flows
            .entry(pi)
            .or_insert_with(|| vec![0.0; info_by_pair[pi].len()]);
        route_visit(&cfg.cost, &info_by_pair[pi], inc, f_route, &mut load, &mut added, fl);
    }
    (
        added.into_iter().enumerate().filter(|&(_, v)| v != 0.0).collect(),
        flows.into_iter().collect(),
    )
}

/// Analytic lower bound on the normalized min-max objective `Z`
/// (drain-time seconds): every byte leaving a GPU must traverse its
/// out-links, every byte arriving must traverse its in-links, and
/// inter-node bytes must cross the node's rails. No routing can beat
/// these aggregates.
pub fn lower_bound_norm_load(topo: &Topology, demands: &[Demand]) -> f64 {
    let g = topo.num_gpus();
    let mut out = vec![0.0f64; g];
    let mut inb = vec![0.0f64; g];
    let mut node_out = vec![0.0f64; topo.nodes];
    let mut node_in = vec![0.0f64; topo.nodes];
    for d in demands {
        out[d.src] += d.bytes;
        inb[d.dst] += d.bytes;
        if !topo.same_node(d.src, d.dst) {
            node_out[topo.node_of(d.src)] += d.bytes;
            node_in[topo.node_of(d.dst)] += d.bytes;
        }
    }
    let mut z: f64 = 0.0;
    for gi in 0..g {
        // capacity out of / into a GPU (rail-matched links only; cross
        // rail links are baseline-only and not counted as capacity)
        let cap_out: f64 = topo
            .out_links(gi)
            .filter(|l| !matches!(l.kind, crate::topology::LinkKind::CrossRail { .. }))
            .map(|l| l.cap_gbps * 1e9)
            .sum();
        let cap_in: f64 = topo
            .in_links(gi)
            .filter(|l| !matches!(l.kind, crate::topology::LinkKind::CrossRail { .. }))
            .map(|l| l.cap_gbps * 1e9)
            .sum();
        z = z.max(out[gi] / cap_out).max(inb[gi] / cap_in);
    }
    let rails_cap = topo.nics_per_node as f64 * topo.rail_gbps * 1e9;
    for n in 0..topo.nodes {
        z = z.max(node_out[n] / rails_cap).max(node_in[n] / rails_cap);
    }
    // Tiered fabrics: inter-pod bytes must cross the pod's core
    // uplinks, whose aggregate is oversubscribed below the rails. This
    // is the bound the spine tier adds and the flat terms cannot see.
    if let Some(tier) = &topo.tier {
        let mut pod_out = vec![0.0f64; tier.pods];
        let mut pod_in = vec![0.0f64; tier.pods];
        for d in demands {
            let (pa, pb) =
                (topo.pod_of(topo.node_of(d.src)), topo.pod_of(topo.node_of(d.dst)));
            if pa != pb {
                pod_out[pa] += d.bytes;
                pod_in[pb] += d.bytes;
            }
        }
        let pod_core_cap = topo.nics_per_node as f64
            * tier.spines_per_rail as f64
            * tier.uplink_gbps
            * 1e9;
        for p in 0..tier.pods {
            z = z.max(pod_out[p] / pod_core_cap).max(pod_in[p] / pod_core_cap);
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::PathKind;

    const MB: f64 = 1024.0 * 1024.0;

    fn planner(topo: &Topology) -> Planner<'_> {
        Planner::new(topo, PlannerCfg::default())
    }

    #[test]
    fn plan_conserves_demand() {
        let t = Topology::paper();
        let mut p = planner(&t);
        let demands = vec![
            Demand::new(0, 1, 256.0 * MB),
            Demand::new(2, 1, 64.0 * MB),
            Demand::new(0, 5, 128.0 * MB),
        ];
        let plan = p.plan(&demands);
        plan.validate(&t, &demands).unwrap();
    }

    #[test]
    fn small_message_stays_single_path() {
        let t = Topology::paper();
        let mut p = planner(&t);
        let demands = vec![Demand::new(0, 1, 0.5 * MB)];
        let plan = p.plan(&demands);
        let a = &plan.assignments[&(0, 1)];
        assert_eq!(a.path_count(), 1);
        assert_eq!(a.parts[0].0.kind, PathKind::IntraDirect);
    }

    #[test]
    fn large_message_spreads_across_paths() {
        let t = Topology::paper();
        let mut p = planner(&t);
        let demands = vec![Demand::new(0, 1, 512.0 * MB)];
        let plan = p.plan(&demands);
        let a = &plan.assignments[&(0, 1)];
        assert!(a.path_count() >= 2, "expected multi-path, got {}", a.path_count());
        // direct carries the most (cheapest path, no penalty)
        let direct = a
            .parts
            .iter()
            .find(|(p, _)| p.kind == PathKind::IntraDirect)
            .map(|(_, b)| *b)
            .unwrap();
        // MWU levels the three paths (equal link caps), so the split
        // is near-uniform; direct must not be starved.
        for (p, b) in &a.parts {
            if p.kind != PathKind::IntraDirect {
                assert!(direct >= *b * 0.9, "direct {direct} vs {:?} {b}", p.kind);
            }
        }
    }

    #[test]
    fn inter_node_skew_uses_all_rails() {
        let t = Topology::paper();
        let mut p = planner(&t);
        // all four GPUs of node 0 send a lot to GPU 4 — the hotspot
        let demands: Vec<Demand> =
            (0..4).map(|s| Demand::new(s, 4, 256.0 * MB)).collect();
        let plan = p.plan(&demands);
        plan.validate(&t, &demands).unwrap();
        // every rail should carry some load
        for r in 0..4 {
            let l = t.rail(0, 1, r).unwrap();
            assert!(plan.link_load[l] > 0.0, "rail {r} unused");
        }
    }

    #[test]
    fn near_lower_bound_on_skewed_intra() {
        let t = Topology::paper();
        let mut p = planner(&t);
        // 3 senders → 1 destination on one node: lower bound is set by
        // the destination's in-capacity (3 NVLink edges).
        let demands: Vec<Demand> =
            (0..3).map(|s| Demand::new(s, 3, 300.0 * MB)).collect();
        let plan = p.plan(&demands);
        plan.validate(&t, &demands).unwrap();
        let z = plan.max_norm_load(&t);
        let lb = lower_bound_norm_load(&t, &demands);
        assert!(z >= lb - 1e-9);
        assert!(z <= lb * 1.35, "z={z} lb={lb}: too far from optimal");
    }

    #[test]
    fn balanced_traffic_stays_direct_dominant() {
        let t = Topology::paper();
        let mut p = planner(&t);
        // all-to-all uniform on node 0: direct links are already
        // balanced, detours should carry nothing (or almost nothing).
        let mut demands = Vec::new();
        for s in 0..4 {
            for d in 0..4 {
                if s != d {
                    demands.push(Demand::new(s, d, 32.0 * MB));
                }
            }
        }
        let plan = p.plan(&demands);
        plan.validate(&t, &demands).unwrap();
        for (key, a) in &plan.assignments {
            let direct: f64 = a
                .parts
                .iter()
                .filter(|(p, _)| !CostModel::is_detour(&t, p))
                .map(|(_, b)| b)
                .sum();
            assert!(
                direct / a.total_bytes() > 0.95,
                "pair {key:?} detoured {:.1}%",
                100.0 * (1.0 - direct / a.total_bytes())
            );
        }
    }

    #[test]
    fn lower_bound_simple_cases() {
        let t = Topology::paper();
        // single intra pair: bound = bytes / (3·120 GB/s out-cap +
        // rail) — dominated by in/out aggregates, must be ≤ direct time
        let d = vec![Demand::new(0, 1, 120e9)];
        let lb = lower_bound_norm_load(&t, &d);
        assert!(lb > 0.0 && lb < 1.0);
        // inter-node: node rails bound
        let d2: Vec<Demand> = (0..4).map(|s| Demand::new(s, s + 4, 45.1e9)).collect();
        let lb2 = lower_bound_norm_load(&t, &d2);
        assert!((lb2 - 1.0).abs() < 1e-6, "lb2={lb2}");
    }

    #[test]
    fn deterministic_plans() {
        let t = Topology::paper();
        let demands = vec![Demand::new(0, 1, 100.0 * MB), Demand::new(2, 1, 80.0 * MB)];
        let p1 = Planner::new(&t, PlannerCfg::default()).plan(&demands);
        let p2 = Planner::new(&t, PlannerCfg::default()).plan(&demands);
        assert_eq!(p1.link_load, p2.link_load);
    }

    /// A demand set that splits into two link-disjoint components (each
    /// node's intra pairs; no inter-node pair to couple them) routes
    /// byte-identically at every thread count — this is the workload
    /// shape that actually executes the component-parallel machinery
    /// (fully-coupled sets short-circuit to the serial path).
    #[test]
    fn thread_count_never_changes_the_plan() {
        let t = Topology::paper();
        let demands = vec![
            Demand::new(0, 1, 512.0 * MB),
            Demand::new(2, 3, 300.0 * MB),
            Demand::new(4, 5, 512.0 * MB),
            Demand::new(6, 7, 96.0 * MB),
            Demand::new(0, 1, 64.0 * MB),
        ];
        let reference = Planner::new(&t, PlannerCfg::default()).plan(&demands);
        reference.validate(&t, &demands).unwrap();
        for threads in [2, 3, 8] {
            let cfg = PlannerCfg { threads, ..PlannerCfg::default() };
            let plan = Planner::new(&t, cfg).plan(&demands);
            assert_eq!(
                plan.canonical_string(),
                reference.canonical_string(),
                "threads={threads} diverged from serial"
            );
        }
    }

    /// Tiered fabric: when several sender nodes contend for a pod's
    /// shared spine tier, the plan levels load across every core spine
    /// instead of hammering one. (A single sender node is bound by its
    /// own leaf uplink, which both spine choices share — there the
    /// spine pick is cost-neutral and the incumbent sticks, so this
    /// spreading claim needs pod-wide contention to be observable.)
    #[test]
    fn fat_tree_plan_spreads_over_spines() {
        let t = Topology::fat_tree(8, 2.0);
        let mut p = planner(&t);
        // every node of pod 0 → its pod-1 partner, all eight GPUs each
        let demands: Vec<Demand> = (0..4)
            .flat_map(|n| {
                (0..8).map(move |l| Demand::new(n * 8 + l, (n + 4) * 8 + l, 256.0 * MB))
            })
            .collect();
        let plan = p.plan(&demands);
        plan.validate(&t, &demands).unwrap();
        let tier = t.tier.as_ref().unwrap();
        for r in 0..t.nics_per_node {
            for k in 0..tier.spines_per_rail {
                let l = t.spine_up(0, r, k).unwrap();
                assert!(plan.link_load[l] > 0.0, "spine ({r},{k}) unused");
            }
        }
        // the shared-term objective is consistent with the link loads
        let shared = p.shared().clone();
        assert!(shared.max_norm_load(&plan.link_load) > 0.0);
    }

    /// The PR-3 determinism contract survives the constraint-set
    /// generalization: plans on tiered fabrics are byte-identical for
    /// every thread count too.
    #[test]
    fn fat_tree_thread_count_never_changes_the_plan() {
        let t = Topology::fat_tree(8, 2.0);
        let demands = vec![
            Demand::new(0, 1, 512.0 * MB),   // intra-node, pod 0
            Demand::new(32, 33, 300.0 * MB), // intra-node, pod 1
            Demand::new(2, 40, 256.0 * MB),  // cross-pod
            Demand::new(10, 50, 96.0 * MB),  // cross-pod
        ];
        let reference = Planner::new(&t, PlannerCfg::default()).plan(&demands);
        reference.validate(&t, &demands).unwrap();
        for threads in [2, 8] {
            let cfg = PlannerCfg { threads, ..PlannerCfg::default() };
            let plan = Planner::new(&t, cfg).plan(&demands);
            assert_eq!(
                plan.canonical_string(),
                reference.canonical_string(),
                "threads={threads} diverged on fat-tree"
            );
        }
    }

    /// Link health: dead links are masked out of the plan entirely,
    /// degraded links are re-priced (and shed most of their load), and
    /// clearing the health restores the healthy plan bit-for-bit.
    #[test]
    fn link_health_masks_dead_and_reprices_degraded() {
        let t = Topology::paper();
        let demands = vec![Demand::new(0, 4, 512.0 * MB)];
        let baseline = Planner::new(&t, PlannerCfg::default()).plan(&demands);
        let dead = t.rail(0, 1, 0).unwrap();
        assert!(baseline.link_load[dead] > 0.0, "home rail idle on healthy plan");

        let mut p = Planner::new(&t, PlannerCfg::default());
        let mut scale = vec![1.0; t.links.len()];
        scale[dead] = 0.0;
        p.set_link_health(Some(scale.clone()));
        let masked = p.plan(&demands);
        masked.validate(&t, &demands).unwrap();
        assert_eq!(masked.link_load[dead], 0.0, "dead link must carry nothing");

        scale[dead] = 0.1;
        p.set_link_health(Some(scale));
        let degraded = p.plan(&demands);
        degraded.validate(&t, &demands).unwrap();
        assert!(
            degraded.link_load[dead] < baseline.link_load[dead],
            "degraded rail kept its healthy share: {} vs {}",
            degraded.link_load[dead],
            baseline.link_load[dead]
        );

        p.set_link_health(None);
        assert_eq!(p.plan(&demands).canonical_string(), baseline.canonical_string());
    }

    /// The same contract holds on the warm-started path the replan
    /// challenger uses (initial loads + incumbent seeding).
    #[test]
    fn thread_count_invariant_with_warm_start() {
        let t = Topology::paper();
        let demands = vec![
            Demand::new(0, 1, 384.0 * MB),
            Demand::new(2, 1, 128.0 * MB),
            Demand::new(4, 7, 256.0 * MB),
        ];
        let mut initial = vec![0.0; t.links.len()];
        initial[t.nvlink(0, 1).unwrap()] = 2.5e9;
        initial[t.nvlink(4, 7).unwrap()] = 1.0e9;
        let mut seeds = BTreeMap::new();
        seeds.insert((0usize, 1usize), PathKind::IntraTwoHop { via: 2 });
        seeds.insert((4usize, 7usize), PathKind::IntraDirect);
        let reference = Planner::new(&t, PlannerCfg::default()).plan_seeded(
            &demands,
            Some(&initial),
            Some(&seeds),
        );
        for threads in [2, 8] {
            let cfg = PlannerCfg { threads, ..PlannerCfg::default() };
            let plan = Planner::new(&t, cfg).plan_seeded(&demands, Some(&initial), Some(&seeds));
            assert_eq!(
                plan.canonical_string(),
                reference.canonical_string(),
                "warm-started threads={threads} diverged"
            );
        }
    }
}
