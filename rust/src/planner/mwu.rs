//! Algorithm 1 — link load balancing with iterative approximation.
//!
//! Faithful implementation of the paper's multiplicative-weights /
//! Garg–Könemann-inspired scheme: sweep over all pairs with remaining
//! demand, route a λ-fraction (rounded to the ε chunk granularity)
//! onto the currently cheapest candidate path, update link loads and
//! costs, repeat until all demand is placed. After `n` visits a pair
//! has `(1−λ)^n` of its demand left, which is what yields the
//! approximation guarantee of the fractional MCF scheme.
//!
//! Extras the paper calls out and we implement:
//! * **hysteresis** — an alternative must beat the incumbent path by a
//!   relative margin before the pair switches paths between visits;
//! * **size-aware penalty** in the cost (`CostModel::detour_penalty`)
//!   so small messages stay single-path;
//! * candidate caching per pair (the topology is static).

use super::cost::CostModel;
use super::plan::{Assignment, Demand, Plan};
use crate::topology::path::candidates;
use crate::topology::{GpuId, Path, PathKind, Topology};
use std::collections::BTreeMap;
use std::time::Instant;

/// Planner configuration (Algorithm 1's λ and ε plus the cost model).
#[derive(Clone, Debug)]
pub struct PlannerCfg {
    /// Flow fraction routed per visit (λ).
    pub lambda: f64,
    /// Chunk granularity in bytes (ε).
    pub epsilon_bytes: f64,
    /// Cost model `F` + penalties + hysteresis.
    pub cost: CostModel,
    /// Allow multi-path at all (false ⇒ always the default path —
    /// used for baseline comparisons and tiny messages).
    pub multipath: bool,
}

impl Default for PlannerCfg {
    fn default() -> Self {
        PlannerCfg {
            lambda: 0.25,
            epsilon_bytes: 512.0 * 1024.0,
            cost: CostModel::default(),
            multipath: true,
        }
    }
}

pub struct Planner<'a> {
    topo: &'a Topology,
    cfg: PlannerCfg,
    /// Cached candidate paths per (src,dst) pair.
    cand_cache: BTreeMap<(GpuId, GpuId), Vec<Path>>,
}

impl<'a> Planner<'a> {
    pub fn new(topo: &'a Topology, cfg: PlannerCfg) -> Self {
        Planner { topo, cfg, cand_cache: BTreeMap::new() }
    }

    pub fn cfg(&self) -> &PlannerCfg {
        &self.cfg
    }

    /// The topology this planner routes over.
    pub fn topo(&self) -> &'a Topology {
        self.topo
    }

    fn candidates_for(&mut self, s: GpuId, d: GpuId, msg_bytes: f64) -> &[Path] {
        let multipath =
            self.cfg.multipath && msg_bytes > self.cfg.cost.multipath_min_bytes;
        // cache key folds the multipath decision in via a sentinel pair
        // ordering: store both variants under distinct keys.
        let key = if multipath { (s, d) } else { (s + self.topo.num_gpus(), d) };
        self.cand_cache
            .entry(key)
            .or_insert_with(|| candidates(self.topo, s, d, multipath))
    }

    /// Run Algorithm 1 over the demand set (cold start: `L_e ← 0`).
    pub fn plan(&mut self, demands: &[Demand]) -> Plan {
        self.plan_with_initial(demands, None)
    }

    /// Run Algorithm 1 warm-started from observed link loads (the
    /// execution-time adaptation loop: the monitor's estimates seed
    /// `L_e` so this round's routing avoids links other traffic is
    /// already pressing on). `Plan::link_load` reports only the load
    /// *added* by this plan, keeping `validate()` exact.
    pub fn plan_with_initial(&mut self, demands: &[Demand], initial: Option<&[f64]>) -> Plan {
        self.plan_seeded(demands, initial, None)
    }

    /// Full warm start for the execution-time re-planning loop: besides
    /// the observed initial loads, seed each pair's hysteresis
    /// *incumbent* with the path it is already flying on (identified by
    /// [`PathKind`], which is unique per pair). A seeded pair keeps its
    /// current path unless a challenger beats it by the configured
    /// hysteresis margin — the anti-churn property §I asks for.
    pub fn plan_seeded(
        &mut self,
        demands: &[Demand],
        initial: Option<&[f64]>,
        incumbent_kinds: Option<&BTreeMap<(GpuId, GpuId), PathKind>>,
    ) -> Plan {
        let t0 = Instant::now();
        let cfg = self.cfg.clone();
        let eps = cfg.epsilon_bytes.max(1.0);

        // L_e ← initial (cost basis); `added` tracks this plan's own load
        let mut load = match initial {
            Some(init) => {
                assert_eq!(init.len(), self.topo.links.len());
                init.to_vec()
            }
            None => vec![0.0f64; self.topo.links.len()],
        };
        let mut added = vec![0.0f64; self.topo.links.len()];
        // r_{s,d} ← d_{s,d}; aggregate duplicate pairs
        let mut pairs: BTreeMap<(GpuId, GpuId), f64> = BTreeMap::new();
        for d in demands {
            if d.bytes > 0.0 {
                assert_ne!(d.src, d.dst, "self-demand ({}, {})", d.src, d.dst);
                *pairs.entry((d.src, d.dst)).or_insert(0.0) += d.bytes;
            }
        }
        let order: Vec<(GpuId, GpuId)> = pairs.keys().cloned().collect();
        let totals: Vec<f64> = order.iter().map(|k| pairs[k]).collect();
        let mut remaining = totals.clone();
        let mut r_tot: f64 = remaining.iter().sum();

        // Precompute per-candidate hot-loop data: hop link ids with
        // 1/(cap·1e9) and relay inflation factors, plus the (msg-size
        // dependent but load-independent) detour penalty. The sweep
        // below then touches only flat arrays.
        struct Cand {
            hops: Vec<(usize, f64, f64)>, // (link, inv_cap_bps, inflate)
            penalty: f64,
        }
        let mut cands_by_pair: Vec<Vec<Path>> = Vec::with_capacity(order.len());
        let mut info_by_pair: Vec<Vec<Cand>> = Vec::with_capacity(order.len());
        for (pi, &(s, d)) in order.iter().enumerate() {
            let cands = self.candidates_for(s, d, totals[pi]).to_vec();
            let infos = cands
                .iter()
                .map(|p| Cand {
                    hops: p
                        .hops
                        .iter()
                        .enumerate()
                        .map(|(hi, &h)| {
                            let link = self.topo.link(h);
                            let inflate = if hi > 0
                                && matches!(link.kind, crate::topology::LinkKind::NvLink)
                            {
                                cfg.cost.relay_inflation
                            } else {
                                1.0
                            };
                            (h, 1.0 / (link.cap_gbps * 1e9), inflate)
                        })
                        .collect(),
                    penalty: cfg.cost.detour_penalty(self.topo, p, totals[pi]),
                })
                .collect();
            cands_by_pair.push(cands);
            info_by_pair.push(infos);
        }

        // Flows^(s,d): byte volume per candidate index (no per-visit
        // allocation or path cloning).
        let mut flows_by_pair: Vec<Vec<f64>> =
            info_by_pair.iter().map(|c| vec![0.0; c.len()]).collect();
        // hysteresis state: incumbent candidate per pair (optionally
        // seeded from the paths currently in flight)
        let mut incumbent: Vec<usize> = vec![usize::MAX; order.len()];
        if let Some(seed) = incumbent_kinds {
            for (pi, key) in order.iter().enumerate() {
                if let Some(kind) = seed.get(key) {
                    if let Some(ci) =
                        cands_by_pair[pi].iter().position(|p| p.kind == *kind)
                    {
                        incumbent[pi] = ci;
                    }
                }
            }
        }
        // active pair list (swap-removed as pairs drain)
        let mut active: Vec<usize> = (0..order.len()).collect();

        // F is monotone, so max_e F(norm_e) = F(max_e norm_e): the
        // inner loop tracks the max normalized load only (the sum_cost
        // ablation applies F per hop instead).
        let shape = cfg.cost.shape;
        let sum_cost = cfg.cost.sum_cost;
        let path_cost = |load: &[f64], c: &Cand| -> f64 {
            if sum_cost {
                let mut agg = 0.0;
                for &(h, inv, _) in &c.hops {
                    agg += shape.apply(load[h] * inv);
                }
                agg + c.penalty
            } else {
                let mut worst = 0.0f64;
                for &(h, inv, _) in &c.hops {
                    let n = load[h] * inv;
                    if n > worst {
                        worst = n;
                    }
                }
                shape.apply(worst) + c.penalty
            }
        };

        while r_tot > 1e-6 && !active.is_empty() {
            let mut ai = 0;
            while ai < active.len() {
                let pi = active[ai];
                let r = remaining[pi];
                // select least-cost candidate (bottleneck metric)
                let infos = &info_by_pair[pi];
                let mut best_i = 0usize;
                let mut best_c = f64::INFINITY;
                for (i, c) in infos.iter().enumerate() {
                    let cost = path_cost(&load, c);
                    if cost < best_c {
                        best_c = cost;
                        best_i = i;
                    }
                }
                // hysteresis: keep the incumbent unless the challenger
                // wins by the configured margin
                let inc = incumbent[pi];
                if inc != usize::MAX && inc != best_i {
                    let inc_c = path_cost(&load, &infos[inc]);
                    if inc_c.is_finite() && best_c >= inc_c * (1.0 - cfg.cost.hysteresis) {
                        best_i = inc;
                    }
                }
                incumbent[pi] = best_i;

                // f_route: residual if < ε, else ⌊r·λ⌋_ε (≥ ε to
                // guarantee progress). Single-candidate pairs place
                // their entire residual at once — every chunk must land
                // on that path anyway, so the final loads are identical
                // and the sweep skips their (1−λ)ⁿ tail.
                let f_route = if r < eps || infos.len() == 1 {
                    r
                } else {
                    ((r * cfg.lambda / eps).floor() * eps).max(eps).min(r)
                };
                for &(h, _, inflate) in &infos[best_i].hops {
                    load[h] += f_route * inflate;
                    added[h] += f_route;
                }
                flows_by_pair[pi][best_i] += f_route;
                remaining[pi] -= f_route;
                r_tot -= f_route;
                if remaining[pi] <= 0.0 {
                    active.swap_remove(ai);
                } else {
                    ai += 1;
                }
            }
        }

        let mut assignments = BTreeMap::new();
        for (pi, key) in order.iter().enumerate() {
            let parts: Vec<(Path, f64)> = flows_by_pair[pi]
                .iter()
                .enumerate()
                .filter(|(_, &b)| b > 0.0)
                .map(|(ci, &b)| (cands_by_pair[pi][ci].clone(), b))
                .collect();
            if !parts.is_empty() {
                assignments.insert(*key, Assignment { parts });
            }
        }
        Plan {
            assignments,
            link_load: added,
            plan_time_s: t0.elapsed().as_secs_f64(),
        }
    }
}

/// Analytic lower bound on the normalized min-max objective `Z`
/// (drain-time seconds): every byte leaving a GPU must traverse its
/// out-links, every byte arriving must traverse its in-links, and
/// inter-node bytes must cross the node's rails. No routing can beat
/// these aggregates.
pub fn lower_bound_norm_load(topo: &Topology, demands: &[Demand]) -> f64 {
    let g = topo.num_gpus();
    let mut out = vec![0.0f64; g];
    let mut inb = vec![0.0f64; g];
    let mut node_out = vec![0.0f64; topo.nodes];
    let mut node_in = vec![0.0f64; topo.nodes];
    for d in demands {
        out[d.src] += d.bytes;
        inb[d.dst] += d.bytes;
        if !topo.same_node(d.src, d.dst) {
            node_out[topo.node_of(d.src)] += d.bytes;
            node_in[topo.node_of(d.dst)] += d.bytes;
        }
    }
    let mut z: f64 = 0.0;
    for gi in 0..g {
        // capacity out of / into a GPU (rail-matched links only; cross
        // rail links are baseline-only and not counted as capacity)
        let cap_out: f64 = topo
            .out_links(gi)
            .filter(|l| !matches!(l.kind, crate::topology::LinkKind::CrossRail { .. }))
            .map(|l| l.cap_gbps * 1e9)
            .sum();
        let cap_in: f64 = topo
            .in_links(gi)
            .filter(|l| !matches!(l.kind, crate::topology::LinkKind::CrossRail { .. }))
            .map(|l| l.cap_gbps * 1e9)
            .sum();
        z = z.max(out[gi] / cap_out).max(inb[gi] / cap_in);
    }
    let rails_cap = topo.nics_per_node as f64 * topo.rail_gbps * 1e9;
    for n in 0..topo.nodes {
        z = z.max(node_out[n] / rails_cap).max(node_in[n] / rails_cap);
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::PathKind;

    const MB: f64 = 1024.0 * 1024.0;

    fn planner(topo: &Topology) -> Planner<'_> {
        Planner::new(topo, PlannerCfg::default())
    }

    #[test]
    fn plan_conserves_demand() {
        let t = Topology::paper();
        let mut p = planner(&t);
        let demands = vec![
            Demand::new(0, 1, 256.0 * MB),
            Demand::new(2, 1, 64.0 * MB),
            Demand::new(0, 5, 128.0 * MB),
        ];
        let plan = p.plan(&demands);
        plan.validate(&t, &demands).unwrap();
    }

    #[test]
    fn small_message_stays_single_path() {
        let t = Topology::paper();
        let mut p = planner(&t);
        let demands = vec![Demand::new(0, 1, 0.5 * MB)];
        let plan = p.plan(&demands);
        let a = &plan.assignments[&(0, 1)];
        assert_eq!(a.path_count(), 1);
        assert_eq!(a.parts[0].0.kind, PathKind::IntraDirect);
    }

    #[test]
    fn large_message_spreads_across_paths() {
        let t = Topology::paper();
        let mut p = planner(&t);
        let demands = vec![Demand::new(0, 1, 512.0 * MB)];
        let plan = p.plan(&demands);
        let a = &plan.assignments[&(0, 1)];
        assert!(a.path_count() >= 2, "expected multi-path, got {}", a.path_count());
        // direct carries the most (cheapest path, no penalty)
        let direct = a
            .parts
            .iter()
            .find(|(p, _)| p.kind == PathKind::IntraDirect)
            .map(|(_, b)| *b)
            .unwrap();
        // MWU levels the three paths (equal link caps), so the split
        // is near-uniform; direct must not be starved.
        for (p, b) in &a.parts {
            if p.kind != PathKind::IntraDirect {
                assert!(direct >= *b * 0.9, "direct {direct} vs {:?} {b}", p.kind);
            }
        }
    }

    #[test]
    fn inter_node_skew_uses_all_rails() {
        let t = Topology::paper();
        let mut p = planner(&t);
        // all four GPUs of node 0 send a lot to GPU 4 — the hotspot
        let demands: Vec<Demand> =
            (0..4).map(|s| Demand::new(s, 4, 256.0 * MB)).collect();
        let plan = p.plan(&demands);
        plan.validate(&t, &demands).unwrap();
        // every rail should carry some load
        for r in 0..4 {
            let l = t.rail(0, 1, r).unwrap();
            assert!(plan.link_load[l] > 0.0, "rail {r} unused");
        }
    }

    #[test]
    fn near_lower_bound_on_skewed_intra() {
        let t = Topology::paper();
        let mut p = planner(&t);
        // 3 senders → 1 destination on one node: lower bound is set by
        // the destination's in-capacity (3 NVLink edges).
        let demands: Vec<Demand> =
            (0..3).map(|s| Demand::new(s, 3, 300.0 * MB)).collect();
        let plan = p.plan(&demands);
        plan.validate(&t, &demands).unwrap();
        let z = plan.max_norm_load(&t);
        let lb = lower_bound_norm_load(&t, &demands);
        assert!(z >= lb - 1e-9);
        assert!(z <= lb * 1.35, "z={z} lb={lb}: too far from optimal");
    }

    #[test]
    fn balanced_traffic_stays_direct_dominant() {
        let t = Topology::paper();
        let mut p = planner(&t);
        // all-to-all uniform on node 0: direct links are already
        // balanced, detours should carry nothing (or almost nothing).
        let mut demands = Vec::new();
        for s in 0..4 {
            for d in 0..4 {
                if s != d {
                    demands.push(Demand::new(s, d, 32.0 * MB));
                }
            }
        }
        let plan = p.plan(&demands);
        plan.validate(&t, &demands).unwrap();
        for (key, a) in &plan.assignments {
            let direct: f64 = a
                .parts
                .iter()
                .filter(|(p, _)| !CostModel::is_detour(&t, p))
                .map(|(_, b)| b)
                .sum();
            assert!(
                direct / a.total_bytes() > 0.95,
                "pair {key:?} detoured {:.1}%",
                100.0 * (1.0 - direct / a.total_bytes())
            );
        }
    }

    #[test]
    fn lower_bound_simple_cases() {
        let t = Topology::paper();
        // single intra pair: bound = bytes / (3·120 GB/s out-cap +
        // rail) — dominated by in/out aggregates, must be ≤ direct time
        let d = vec![Demand::new(0, 1, 120e9)];
        let lb = lower_bound_norm_load(&t, &d);
        assert!(lb > 0.0 && lb < 1.0);
        // inter-node: node rails bound
        let d2: Vec<Demand> = (0..4).map(|s| Demand::new(s, s + 4, 45.1e9)).collect();
        let lb2 = lower_bound_norm_load(&t, &d2);
        assert!((lb2 - 1.0).abs() < 1e-6, "lb2={lb2}");
    }

    #[test]
    fn deterministic_plans() {
        let t = Topology::paper();
        let demands = vec![Demand::new(0, 1, 100.0 * MB), Demand::new(2, 1, 80.0 * MB)];
        let p1 = Planner::new(&t, PlannerCfg::default()).plan(&demands);
        let p2 = Planner::new(&t, PlannerCfg::default()).plan(&demands);
        assert_eq!(p1.link_load, p2.link_load);
    }
}
