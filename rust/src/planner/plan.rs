//! Routing plan types produced by the planner (Algorithm 1's
//! `Paths^(s,d)` / `Flows^(s,d)` outputs) plus validation of the
//! invariants the coordinator relies on.

use crate::topology::{GpuId, Path, Topology};
use std::collections::BTreeMap;

/// One traffic demand (a message or message aggregate) from `src` to `dst`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Demand {
    pub src: GpuId,
    pub dst: GpuId,
    pub bytes: f64,
}

impl Demand {
    pub fn new(src: GpuId, dst: GpuId, bytes: f64) -> Demand {
        Demand { src, dst, bytes }
    }
}

/// Flow assignment for one demand: byte volumes per concrete path.
#[derive(Clone, Debug, Default)]
pub struct Assignment {
    pub parts: Vec<(Path, f64)>,
}

impl Assignment {
    pub fn total_bytes(&self) -> f64 {
        self.parts.iter().map(|(_, b)| b).sum()
    }
    pub fn path_count(&self) -> usize {
        self.parts.len()
    }
}

/// The full routing plan.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    /// Keyed by (src, dst).
    pub assignments: BTreeMap<(GpuId, GpuId), Assignment>,
    /// Final per-link load in bytes (Algorithm 1's `L_e`).
    pub link_load: Vec<f64>,
    /// Planner wall time in seconds (reported in Table I).
    pub plan_time_s: f64,
}

impl Plan {
    /// The objective `Z` normalized by capacity: max over links of
    /// load/capacity, i.e. the bottleneck drain time in seconds.
    pub fn max_norm_load(&self, topo: &Topology) -> f64 {
        self.link_load
            .iter()
            .enumerate()
            .map(|(i, &l)| l / (topo.link(i).cap_gbps * 1e9))
            .fold(0.0, f64::max)
    }

    /// Validate the invariants Algorithm 1 guarantees:
    /// 1. conservation — per-pair flows sum to the demand;
    /// 2. every path is a valid connected (s,d) chain;
    /// 3. `link_load` is consistent with the assignments;
    /// 4. all flow parts are positive.
    pub fn validate(&self, topo: &Topology, demands: &[Demand]) -> Result<(), String> {
        let mut want: BTreeMap<(GpuId, GpuId), f64> = BTreeMap::new();
        for d in demands {
            *want.entry((d.src, d.dst)).or_insert(0.0) += d.bytes;
        }
        for (&(s, dst), a) in &self.assignments {
            let expect = want.remove(&(s, dst)).ok_or_else(|| {
                format!("assignment for ({s},{dst}) without a matching demand")
            })?;
            let got = a.total_bytes();
            if (got - expect).abs() > 1e-3 {
                return Err(format!(
                    "conservation violated for ({s},{dst}): routed {got}, demanded {expect}"
                ));
            }
            for (p, b) in &a.parts {
                if *b <= 0.0 {
                    return Err(format!("non-positive flow part {b} on ({s},{dst})"));
                }
                if p.src != s || p.dst != dst {
                    return Err(format!("path endpoints mismatch on ({s},{dst})"));
                }
                if !p.is_valid(topo) {
                    return Err(format!("invalid path for ({s},{dst}): {:?}", p.kind));
                }
            }
        }
        if let Some((&(s, d), _)) = want.iter().find(|(_, &b)| b > 0.0) {
            return Err(format!("demand ({s},{d}) received no assignment"));
        }
        // recompute link loads
        let mut loads = vec![0.0; topo.links.len()];
        for a in self.assignments.values() {
            for (p, b) in &a.parts {
                for &h in &p.hops {
                    loads[h] += b;
                }
            }
        }
        for (i, (&a, &b)) in loads.iter().zip(self.link_load.iter()).enumerate() {
            if (a - b).abs() > 1e-3 {
                return Err(format!("link {i} load mismatch: recomputed {a}, stored {b}"));
            }
        }
        Ok(())
    }

    /// Number of distinct paths used across all assignments.
    pub fn total_paths(&self) -> usize {
        self.assignments.values().map(|a| a.path_count()).sum()
    }

    /// Canonical lossless serialization of the routing decision: every
    /// pair, every path (kind + hop list) and every byte volume as raw
    /// f64 bits, plus the nonzero link loads. Two plans are
    /// byte-identical iff their canonical strings are equal — the
    /// comparison the planner determinism tests (thread-count
    /// invariance, config reproduction) are built on. `plan_time_s` is
    /// deliberately excluded: it is measurement, not decision.
    pub fn canonical_string(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        for (&(s, d), a) in &self.assignments {
            let _ = write!(out, "({s},{d}):");
            for (p, bytes) in &a.parts {
                let _ = write!(out, "[{:?}@{:?}={:016x}]", p.kind, p.hops, bytes.to_bits());
            }
            out.push('\n');
        }
        for (i, l) in self.link_load.iter().enumerate() {
            if *l != 0.0 {
                let _ = write!(out, "L{i}={:016x};", l.to_bits());
            }
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::path::candidates;
    use crate::topology::Topology;

    fn one_path_plan(topo: &Topology, s: GpuId, d: GpuId, bytes: f64) -> Plan {
        let p = candidates(topo, s, d, false).remove(0);
        let mut link_load = vec![0.0; topo.links.len()];
        for &h in &p.hops {
            link_load[h] += bytes;
        }
        let mut assignments = BTreeMap::new();
        assignments.insert((s, d), Assignment { parts: vec![(p, bytes)] });
        Plan { assignments, link_load, plan_time_s: 0.0 }
    }

    #[test]
    fn valid_plan_passes() {
        let t = Topology::paper();
        let plan = one_path_plan(&t, 0, 1, 1e6);
        plan.validate(&t, &[Demand::new(0, 1, 1e6)]).unwrap();
    }

    #[test]
    fn conservation_violation_detected() {
        let t = Topology::paper();
        let plan = one_path_plan(&t, 0, 1, 1e6);
        let err = plan.validate(&t, &[Demand::new(0, 1, 2e6)]).unwrap_err();
        assert!(err.contains("conservation"), "{err}");
    }

    #[test]
    fn missing_assignment_detected() {
        let t = Topology::paper();
        let plan = one_path_plan(&t, 0, 1, 1e6);
        let err = plan
            .validate(&t, &[Demand::new(0, 1, 1e6), Demand::new(2, 3, 5.0)])
            .unwrap_err();
        assert!(err.contains("no assignment"), "{err}");
    }

    #[test]
    fn stale_link_load_detected() {
        let t = Topology::paper();
        let mut plan = one_path_plan(&t, 0, 1, 1e6);
        plan.link_load[0] += 42.0;
        // hop 0 of the (0,1) direct path is link nvlink(0,1); corrupt a
        // different entry to be sure detection is load-table-wide.
        let err = plan.validate(&t, &[Demand::new(0, 1, 1e6)]).unwrap_err();
        assert!(err.contains("load mismatch"), "{err}");
    }

    #[test]
    fn max_norm_load_is_bottleneck_drain() {
        let t = Topology::paper();
        let plan = one_path_plan(&t, 0, 4, 45.1e9); // 45.1 GB over a 45.1 GB/s rail
        let z = plan.max_norm_load(&t);
        assert!((z - 1.0).abs() < 1e-9, "z={z}");
    }
}
