//! Brute-force exact solver for the paper's Integer Programming
//! formulation (§IV-B, eqs. 1–5) on tiny instances.
//!
//! The IP minimizes the max link load `Z` subject to flow conservation
//! and integrality in ε-chunks. Exact solutions are exponential — the
//! paper's reason for the MWU approximation — but on ≤3 pairs with a
//! handful of chunks we can enumerate every chunk→path assignment and
//! obtain the true optimum. The test-suite uses this to measure the
//! MWU optimality gap (also surfaced by `nimble ablate --exact-gap`).

use super::plan::Demand;
use crate::topology::path::candidates;
use crate::topology::{Path, Topology};

/// Exact minimum of the capacity-normalized max load, enumerating all
/// ways to place each pair's chunks on its candidate paths.
/// `chunks_per_pair` bounds the enumeration (demand split evenly).
///
/// Returns (optimal normalized max load in seconds, per-pair split) or
/// None if the instance is too large.
pub fn exact_min_max(
    topo: &Topology,
    demands: &[Demand],
    chunks_per_pair: usize,
) -> Option<(f64, Vec<Vec<f64>>)> {
    if demands.len() > 3 || chunks_per_pair > 8 {
        return None; // refuse instances that would blow up
    }
    let cands: Vec<Vec<Path>> = demands
        .iter()
        .map(|d| candidates(topo, d.src, d.dst, true))
        .collect();
    // per pair: enumerate compositions of `chunks_per_pair` over its
    // candidate paths
    let comps: Vec<Vec<Vec<usize>>> = cands
        .iter()
        .map(|c| compositions(chunks_per_pair, c.len()))
        .collect();

    let mut best = f64::INFINITY;
    let mut best_split: Vec<Vec<f64>> = Vec::new();
    let mut idx = vec![0usize; demands.len()];
    loop {
        // evaluate this joint assignment
        let mut load = vec![0.0f64; topo.links.len()];
        for (k, d) in demands.iter().enumerate() {
            let comp = &comps[k][idx[k]];
            let chunk = d.bytes / chunks_per_pair as f64;
            for (pi, &cnt) in comp.iter().enumerate() {
                if cnt == 0 {
                    continue;
                }
                for &h in &cands[k][pi].hops {
                    load[h] += chunk * cnt as f64;
                }
            }
        }
        let z = load
            .iter()
            .enumerate()
            .map(|(i, &l)| l / (topo.link(i).cap_gbps * 1e9))
            .fold(0.0, f64::max);
        if z < best {
            best = z;
            best_split = demands
                .iter()
                .enumerate()
                .map(|(k, d)| {
                    let chunk = d.bytes / chunks_per_pair as f64;
                    comps[k][idx[k]].iter().map(|&c| c as f64 * chunk).collect()
                })
                .collect();
        }
        // odometer increment
        let mut k = 0;
        loop {
            if k == demands.len() {
                return Some((best, best_split));
            }
            idx[k] += 1;
            if idx[k] < comps[k].len() {
                break;
            }
            idx[k] = 0;
            k += 1;
        }
    }
}

/// All ways to write `n` as an ordered sum of `parts` non-negative
/// integers.
fn compositions(n: usize, parts: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur = vec![0usize; parts];
    fn rec(n: usize, i: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if i == cur.len() - 1 {
            cur[i] = n;
            out.push(cur.clone());
            return;
        }
        for v in 0..=n {
            cur[i] = v;
            rec(n - v, i + 1, cur, out);
        }
    }
    if parts == 0 {
        return out;
    }
    rec(n, 0, &mut cur, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::mwu::{Planner, PlannerCfg};
    use crate::topology::Topology;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn composition_count() {
        // C(n+k-1, k-1): n=4, k=3 → 15
        assert_eq!(compositions(4, 3).len(), 15);
        for c in compositions(4, 3) {
            assert_eq!(c.iter().sum::<usize>(), 4);
        }
    }

    #[test]
    fn single_pair_optimum_spreads() {
        let t = Topology::paper();
        // one 360 MB intra-node message, 6 chunks, candidates
        // {direct, via-2, via-3}: optimum places 2 chunks per path
        // → max link load = 120 MB.
        let d = vec![Demand::new(0, 1, 360.0 * MB)];
        let (z, split) = exact_min_max(&t, &d, 6).unwrap();
        let expect = 120.0 * MB / 120e9;
        assert!((z - expect).abs() < 1e-9, "z={z} expect={expect}");
        assert_eq!(split[0].len(), 3);
        for &b in &split[0] {
            assert!((b - 120.0 * MB).abs() < 1.0);
        }
    }

    #[test]
    fn mwu_within_factor_of_exact() {
        let t = Topology::paper();
        let demands = vec![
            Demand::new(0, 1, 240.0 * MB),
            Demand::new(2, 1, 120.0 * MB),
        ];
        let (z_star, _) = exact_min_max(&t, &demands, 6).unwrap();
        let mut planner = Planner::new(&t, PlannerCfg::default());
        let plan = planner.plan(&demands);
        let z = plan.max_norm_load(&t);
        assert!(z >= z_star - 1e-9, "MWU beat the exact optimum?!");
        assert!(z <= z_star * 1.5, "gap too large: mwu={z} exact={z_star}");
    }

    #[test]
    fn too_large_instance_refused() {
        let t = Topology::paper();
        let d: Vec<Demand> = (0..4).map(|s| Demand::new(s, (s + 1) % 4, 1e6)).collect();
        assert!(exact_min_max(&t, &d, 4).is_none());
        assert!(exact_min_max(&t, &d[..1], 9).is_none());
    }
}
