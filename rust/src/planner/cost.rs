//! The link cost function `c_e = F(L_e)` of Algorithm 1 and the
//! path-cost metric.
//!
//! The paper's `F` is (a) **capacity-normalized** — load is divided by
//! link capacity so NVLink edges and NIC rails are comparable, (b)
//! **sharply increasing** with load to discourage congested links
//! (Garg–Könemann uses `exp`, the paper uses a custom hardware-aware
//! function), and (c) carries a **size-aware detour penalty** so that
//! multi-path splitting is suppressed for small messages (§V-B:
//! disabled ≤ 1 MB, fully amortized around 64 MB).
//!
//! Path cost is the **max** link cost along the path (not the sum):
//! the §IV-C pipeline makes a path's throughput equal to its
//! bottleneck link, so congestion on any one hop prices the whole
//! path (§IV-B).

use crate::topology::{Path, PathKind, Topology};

/// Shape of the load→cost curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostShape {
    /// `c = L/cap` — linear drain-time cost (NIMBLE's default: with
    /// incremental λ-assignment it directly greedily levels the
    /// normalized load, which is the min-max objective).
    Linear,
    /// `c = exp(alpha · L/cap) − 1` — classic Garg–Könemann weights.
    Exponential { alpha: f64 },
    /// `c = (L/cap)^p` — polynomial sharpening.
    Polynomial { p: f64 },
}

/// Cost model parameters (ablation targets; see `nimble ablate`).
#[derive(Clone, Debug)]
pub struct CostModel {
    pub shape: CostShape,
    /// Messages at or below this never use alternate paths (paper: 1 MB).
    pub multipath_min_bytes: f64,
    /// Message size by which detour *pipeline overhead* (extra
    /// launch/sync + relay fill) is amortized. Distinct from the 64 MB
    /// *bandwidth-saturation* knee — that lives in the fabric
    /// efficiency curve; this penalty only prices the fixed forwarding
    /// overhead, which is gone by a few MB (Fig 6c).
    pub amortize_bytes: f64,
    /// Scale of the detour penalty, in the same unit as link cost
    /// (seconds of equivalent drain time for Linear).
    pub penalty_scale: f64,
    /// Hysteresis margin: an alternative path must beat the incumbent
    /// by this relative factor before the planner switches (§I:
    /// "hysteresis-based load metrics to avoid oscillations").
    pub hysteresis: f64,
    /// Ablation: price paths by the SUM of link costs (Dijkstra-style)
    /// instead of the paper's bottleneck MAX (§IV-B discusses why max
    /// is right for the pipelined dataplane). Default false.
    pub sum_cost: bool,
    /// Hardware-aware load inflation for relay (detour) hops: a relay
    /// GPU's pass-through runs at ρ of NVLink rate, so bytes routed
    /// through a relay hop occupy the link 1/ρ longer. Part of the
    /// paper's "F designed according to hardware features" (§IV-B).
    pub relay_inflation: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            shape: CostShape::Linear,
            multipath_min_bytes: 1024.0 * 1024.0,
            amortize_bytes: 8.0 * 1024.0 * 1024.0,
            penalty_scale: 2.0e-4, // 0.2 ms equivalent drain time
            hysteresis: 0.05,
            sum_cost: false,
            relay_inflation: 1.0 / 0.776,
        }
    }
}

impl CostShape {
    /// Apply the (monotone) load→cost curve to a normalized load
    /// (drain-time seconds). Monotonicity is what lets the planner
    /// hot loop compute `max F(norm) = F(max norm)`.
    #[inline]
    pub fn apply(&self, norm: f64) -> f64 {
        match *self {
            CostShape::Linear => norm,
            CostShape::Exponential { alpha } => (alpha * norm).exp_m1(),
            CostShape::Polynomial { p } => norm.powf(p),
        }
    }
}

impl CostModel {
    /// `c_e = F(L_e)`: cost of a link carrying `load_bytes` with
    /// capacity `cap_gbps`.
    pub fn link_cost(&self, load_bytes: f64, cap_gbps: f64) -> f64 {
        self.shape.apply(load_bytes / (cap_gbps * 1e9))
    }

    /// Size-aware detour penalty for a candidate path: zero for the
    /// preferred (direct / source-rail) path, prohibitive for small
    /// messages, decaying as the message amortizes pipeline overhead.
    pub fn detour_penalty(&self, topo: &Topology, path: &Path, msg_bytes: f64) -> f64 {
        if !Self::is_detour(topo, path) {
            return 0.0;
        }
        if msg_bytes <= self.multipath_min_bytes {
            return f64::INFINITY;
        }
        // (amortize/S − 1)+ : 7× scale at 1 MB, 0 beyond amortize.
        let ramp = (self.amortize_bytes / msg_bytes - 1.0).max(0.0);
        // Only GPU forwarding stops pay pipeline overhead; switch hops
        // on tiered fabrics forward in hardware and cost nothing here.
        let extra_hops = path.relays(topo).len() as f64;
        self.penalty_scale * ramp * extra_hops.max(1.0)
    }

    /// A path is a detour when it is not the library's default
    /// least-hop choice: intra-node 2-hop, or an inter-node rail other
    /// than the source GPU's own rail (detected by whether the first
    /// hop already leaves through the source's own NIC — GPU-NIC
    /// affinity, §IV-B). On tiered fabrics the same rule reads as "the
    /// first hop is the source's leaf uplink".
    pub fn is_detour(topo: &Topology, path: &Path) -> bool {
        match path.kind {
            PathKind::IntraDirect => false,
            PathKind::IntraTwoHop { .. } => true,
            PathKind::InterRail { .. } => !matches!(
                topo.link(path.hops[0]).kind,
                crate::topology::LinkKind::Rail { .. }
            ),
            PathKind::InterCross { .. } => true,
            PathKind::InterLeaf { .. } | PathKind::InterSpine { .. } => !matches!(
                topo.link(path.hops[0]).kind,
                crate::topology::LinkKind::LeafUp { .. }
            ),
        }
    }

    /// Bottleneck path cost: max link cost + size-aware detour penalty.
    pub fn path_cost(
        &self,
        topo: &Topology,
        loads: &[f64],
        path: &Path,
        msg_bytes: f64,
    ) -> f64 {
        let mut agg = 0.0f64;
        for &h in &path.hops {
            let l = topo.link(h);
            let c = self.link_cost(loads[h], l.cap_gbps);
            agg = if self.sum_cost { agg + c } else { agg.max(c) };
        }
        agg + self.detour_penalty(topo, path, msg_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::path::candidates;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn linear_cost_is_drain_time() {
        let m = CostModel::default();
        // 120 MB on a 120 GB/s link ≈ 1.048 ms (binary MB vs GB=1e9)
        let c = m.link_cost(120.0 * MB, 120.0);
        assert!((c - 120.0 * MB / 120e9).abs() < 1e-12);
    }

    #[test]
    fn shapes_are_monotone_in_load() {
        for shape in [
            CostShape::Linear,
            CostShape::Exponential { alpha: 50.0 },
            CostShape::Polynomial { p: 3.0 },
        ] {
            let m = CostModel { shape, ..CostModel::default() };
            let mut prev = -1.0;
            for l in [0.0, 1.0 * MB, 10.0 * MB, 100.0 * MB] {
                let c = m.link_cost(l, 120.0);
                assert!(c >= prev, "{shape:?} not monotone");
                prev = c;
            }
        }
    }

    #[test]
    fn small_messages_never_detour() {
        let t = Topology::paper();
        let m = CostModel::default();
        let c = candidates(&t, 0, 1, true);
        assert_eq!(m.detour_penalty(&t, &c[0], 0.5 * MB), 0.0); // direct
        assert!(m.detour_penalty(&t, &c[1], 0.5 * MB).is_infinite()); // 2-hop
        assert!(m.detour_penalty(&t, &c[1], 1.0 * MB).is_infinite()); // == threshold
    }

    #[test]
    fn penalty_amortizes_with_size() {
        let t = Topology::paper();
        let m = CostModel::default();
        let two_hop = candidates(&t, 0, 1, true).remove(1);
        let p2 = m.detour_penalty(&t, &two_hop, 1.5 * MB);
        let p4 = m.detour_penalty(&t, &two_hop, 4.0 * MB);
        let p8 = m.detour_penalty(&t, &two_hop, 8.0 * MB);
        assert!(p2 > p4 && p4 > p8);
        assert_eq!(p8, 0.0, "amortized by 8 MB");
    }

    #[test]
    fn source_rail_is_not_a_detour() {
        let t = Topology::paper();
        // gpu1 → gpu6: rail 1 has no source-side hop (src's own NIC)
        let inter = candidates(&t, 1, 6, true);
        for p in &inter {
            match p.kind {
                PathKind::InterRail { rail: 1 } => assert!(!CostModel::is_detour(&t, p)),
                _ => assert!(CostModel::is_detour(&t, p), "{:?}", p.kind),
            }
        }
    }

    #[test]
    fn path_cost_is_bottleneck_plus_penalty() {
        let t = Topology::paper();
        let m = CostModel::default();
        let mut loads = vec![0.0; t.links.len()];
        let two_hop = candidates(&t, 0, 1, true).remove(1);
        loads[two_hop.hops[0]] = 100.0 * MB;
        loads[two_hop.hops[1]] = 10.0 * MB;
        let c = m.path_cost(&t, &loads, &two_hop, 128.0 * MB);
        let expect = m.link_cost(100.0 * MB, 120.0); // penalty = 0 at 128 MB
        assert!((c - expect).abs() < 1e-12);
    }
}
