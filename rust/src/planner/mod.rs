//! The NIMBLE planner (paper §IV-B): capacity-normalized
//! minimum-congestion routing via multiplicative-weights iterative
//! approximation (Algorithm 1), the incremental execution-time
//! [`replan`] entry point driving the monitor → replan → reroute loop,
//! the multi-tenant [`joint`] solve (one shared load table across all
//! live tenants, with per-tenant MWU weight scaling — the planner half
//! of [`crate::orchestrator`]), plus the validators used to check it —
//! a Dinic max-flow bound and a brute-force exact IP for tiny
//! instances.

pub mod constraints;
pub mod cost;
pub mod exact;
pub mod joint;
pub mod maxflow;
pub mod mwu;
pub mod plan;
pub mod replan;

pub use constraints::{SharedConstraints, SharedTerm};
pub use cost::{CostModel, CostShape};
pub use joint::{JointPlan, TenantDemands};
pub use mwu::{lower_bound_norm_load, LinkHealth, Planner, PlannerCfg};
pub use plan::{Assignment, Demand, Plan};
pub use replan::{carry_plan, DrainCaps, ReplanAudit, ReplanCfg, ReplanOutcome};
