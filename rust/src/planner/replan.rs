//! Incremental execution-time re-planning (paper §I / §IV: NIMBLE
//! "performs execution-time planning" instead of replaying a static
//! plan).
//!
//! [`Planner::replan`] is the planner half of the monitor → replan →
//! reroute loop: given the **incumbent** residual routing (what is
//! currently in flight), the monitor's **observed** per-link loads and
//! the **residual demands** still to deliver, it decides — with
//! hysteresis, so stable traffic does not churn — whether to keep the
//! incumbent or to adopt a challenger plan produced by a warm-started
//! MWU run ([`Planner::plan_seeded`]).
//!
//! Decision rule (deterministic):
//! 1. scale the incumbent's per-pair path splits onto the residual
//!    demands ([`carry_plan`]); when the residual demands equal the
//!    incumbent's exactly, the carry IS the incumbent, byte for byte;
//! 2. estimate external pressure as the observed load in excess of
//!    what the incumbent predicts ([`excess_over_plan`]);
//! 3. run Algorithm 1 on the residual demands, warm-started from the
//!    excess loads and with each pair's hysteresis incumbent seeded to
//!    its in-flight path;
//! 4. adopt the challenger only if it improves the bottleneck drain
//!    time `Z` by more than the relative hysteresis `margin`;
//!    otherwise return the carry unchanged (`replanned = false`).

use super::constraints::SharedConstraints;
use super::mwu::Planner;
use super::plan::{Assignment, Demand, Plan};
use crate::fabric::FabricParams;
use crate::topology::path::candidates;
use crate::topology::{GpuId, LinkKind, Path, PathKind, Topology};
use std::collections::BTreeMap;

/// Endpoint capacity anchors for the replan accept metric: the same
/// per-GPU injection/receive and per-node NIC aggregates the dataplane
/// enforces ([`FabricParams`]). Without them, a link-level reshuffle of
/// endpoint-bound traffic would claim drain-time improvements that are
/// not physically available — the classic plan-churn failure mode.
#[derive(Clone, Copy, Debug)]
pub struct DrainCaps {
    pub inject_gbps: f64,
    pub recv_gbps: f64,
    pub node_net_gbps: f64,
}

impl From<&FabricParams> for DrainCaps {
    fn from(p: &FabricParams) -> Self {
        DrainCaps {
            inject_gbps: p.inject_cap_gbps,
            recv_gbps: p.recv_cap_gbps,
            node_net_gbps: p.node_net_cap_gbps,
        }
    }
}

impl Default for DrainCaps {
    fn default() -> Self {
        // single source of truth: the fabric calibration defaults
        DrainCaps::from(&FabricParams::default())
    }
}

/// Execution-time re-planning configuration (`[replan]` in the TOML
/// config; see `configs/paper.toml`). Disabled by default so every
/// static experiment reproduces bit-identically.
#[derive(Clone, Debug)]
pub struct ReplanCfg {
    /// Master switch: when false the coordinator never preempts and the
    /// execution path is byte-identical to the static plan.
    pub enable: bool,
    /// Monitor sampling / replan-epoch cadence in virtual seconds.
    pub cadence_s: f64,
    /// Relative improvement in bottleneck drain time a challenger must
    /// deliver before the incumbent is abandoned (plan-churn
    /// hysteresis), and the deviation level reported as significant.
    pub margin: f64,
    /// Endpoint anchors for the accept metric; the executor syncs these
    /// from its `FabricParams` so planner and dataplane agree on what
    /// is endpoint-bound.
    pub caps: DrainCaps,
}

impl Default for ReplanCfg {
    fn default() -> Self {
        ReplanCfg {
            enable: false,
            cadence_s: 5.0e-4,
            margin: 0.1,
            caps: DrainCaps::default(),
        }
    }
}

/// Outcome of one replan decision.
#[derive(Clone, Debug)]
pub struct ReplanOutcome {
    /// The plan to fly for the residual demands: either the carry of
    /// the incumbent (`replanned == false`) or the adopted challenger.
    pub plan: Plan,
    /// True iff the challenger was adopted and some pair rerouted.
    pub replanned: bool,
    /// Max normalized gap between the observed and the planned
    /// link-load shapes: 0 when observation matches the plan in the
    /// same byte units. (Fed window-rate estimates, as the executor
    /// does, it reads as a traffic-*drift* indicator instead.)
    pub deviation: f64,
    /// Pairs whose path set or byte split materially changed.
    pub changed_pairs: Vec<(GpuId, GpuId)>,
    /// Decision-audit evidence (telemetry `decision` record): present
    /// whenever a challenger was actually planned and judged, `None`
    /// on the disabled fast path. Purely observational — nothing in
    /// the loop reads it back.
    pub audit: Option<ReplanAudit>,
}

/// The drain-time evidence one replan decision ran on
/// ([`ReplanOutcome::audit`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ReplanAudit {
    /// Capacity-normalized drain time of carrying the incumbent.
    pub z_carry: f64,
    /// Same metric for the challenger plan.
    pub z_challenger: f64,
    /// The accept margin the comparison used.
    pub margin: f64,
    /// True when dead-link pairs forced adoption regardless of z.
    pub forced: bool,
    /// Algorithm-1 visits the challenger sweep performed.
    pub mwu_visits: u64,
    /// Per-candidate evidence: z, delta against the carry, and the
    /// top-k binding constraints behind each number (`nimble explain`
    /// renders these as the "why" of the decision).
    pub candidates: Vec<CandidateAudit>,
}

/// One judged plan candidate inside a [`ReplanAudit`].
#[derive(Clone, Debug, PartialEq)]
pub struct CandidateAudit {
    /// `"carry"` or `"challenger"`.
    pub name: &'static str,
    /// Capacity-normalized drain time of this candidate (seconds).
    pub z_s: f64,
    /// `z_s − z_carry`: negative means the candidate drains faster
    /// than carrying the incumbent (0 for the carry itself).
    pub delta_s: f64,
    /// Top-[`TOP_K_BINDING`] binding constraints `(label, z_term)`,
    /// descending by drain term — which constraint(s) this candidate's
    /// drain time actually sits on.
    pub binding: Vec<(String, f64)>,
}

/// How many binding constraints each candidate audit retains.
pub const TOP_K_BINDING: usize = 3;

/// Identity of one drain-time constraint term — every max-term of
/// [`drain_time_z_scaled`], named. The `Ord` order (variant, index) is
/// the deterministic tie-break when equal terms compete for a top-k
/// slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ConsId {
    /// Per-link drain: `load / cap` of link `l`.
    Link(usize),
    /// Per-GPU injection aggregate (sum of outgoing non-CrossRail
    /// links, capped by the fabric's inject anchor).
    GpuOut(usize),
    /// Per-GPU receive aggregate.
    GpuIn(usize),
    /// Per-node NIC-out aggregate (rail + leaf-uplink load over the
    /// node's achievable rail capacity).
    NodeOut(usize),
    /// Per-node NIC-in aggregate.
    NodeIn(usize),
    /// Shared-constraint term `i` of the topology (tiered fabrics).
    Shared(usize),
}

impl ConsId {
    /// Stable textual label (`decision` trace records / `nimble
    /// explain`).
    pub fn label(&self) -> String {
        match self {
            ConsId::Link(l) => format!("link:{l}"),
            ConsId::GpuOut(g) => format!("gpu_out:{g}"),
            ConsId::GpuIn(g) => format!("gpu_in:{g}"),
            ConsId::NodeOut(n) => format!("node_out:{n}"),
            ConsId::NodeIn(n) => format!("node_in:{n}"),
            ConsId::Shared(i) => format!("shared:{i}"),
        }
    }
}

/// Scale the incumbent's per-pair path splits onto the residual
/// demands. Pairs the incumbent does not cover ride their default
/// single path. When a pair's residual equals its incumbent total the
/// split is reused exactly (scale factor 1.0 ⇒ byte-identical parts).
pub fn carry_plan(topo: &Topology, incumbent: &Plan, residual: &[Demand]) -> Plan {
    let mut pairs: BTreeMap<(GpuId, GpuId), f64> = BTreeMap::new();
    for d in residual {
        if d.bytes > 0.0 {
            *pairs.entry((d.src, d.dst)).or_insert(0.0) += d.bytes;
        }
    }
    let mut assignments = BTreeMap::new();
    let mut link_load = vec![0.0f64; topo.links.len()];
    for (key, bytes) in pairs {
        let parts: Vec<(Path, f64)> = match incumbent.assignments.get(&key) {
            Some(a) if a.total_bytes() > 0.0 => {
                let scale = bytes / a.total_bytes();
                a.parts
                    .iter()
                    .map(|(p, b)| (p.clone(), if scale == 1.0 { *b } else { b * scale }))
                    .filter(|(_, b)| *b > 0.0)
                    .collect()
            }
            _ => vec![(candidates(topo, key.0, key.1, false).remove(0), bytes)],
        };
        for (p, b) in &parts {
            for &h in &p.hops {
                link_load[h] += *b;
            }
        }
        assignments.insert(key, Assignment { parts });
    }
    Plan { assignments, link_load, plan_time_s: 0.0 }
}

/// Capacity-normalize a per-link byte vector and rescale it to peak 1,
/// returning `None` when it carries no load at all.
fn unit_shape(topo: &Topology, loads: &[f64]) -> Option<Vec<f64>> {
    let norm: Vec<f64> = loads
        .iter()
        .enumerate()
        .map(|(i, &l)| l / (topo.link(i).cap_gbps * 1e9))
        .collect();
    let peak = norm.iter().cloned().fold(0.0f64, f64::max);
    if peak <= 0.0 {
        return None;
    }
    Some(norm.iter().map(|n| n / peak).collect())
}

/// Max normalized gap between the observed and predicted link-load
/// shapes: 0 when execution matches the plan (up to a common scale),
/// 1 when load appears where none was planned (or vice versa).
pub fn shape_deviation(topo: &Topology, observed: &[f64], predicted: &[f64]) -> f64 {
    match (unit_shape(topo, observed), unit_shape(topo, predicted)) {
        (None, None) => 0.0,
        (None, Some(_)) | (Some(_), None) => 1.0,
        (Some(o), Some(p)) => o
            .iter()
            .zip(&p)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max),
    }
}

/// Observed load in excess of what the plan predicts, expressed in the
/// plan's byte magnitude (external pressure the challenger should route
/// around). Zero wherever execution matches the plan.
///
/// The observed vector is in *window* bytes while the plan is in
/// *residual* bytes, so a unit conversion is needed: the median of the
/// per-link `planned / observed` ratios over links carrying both. The
/// median is robust — a minority of pressured links cannot drag the
/// scale and hide their own excess (a peak-based scale would cancel
/// pressure landing exactly on the planned bottleneck).
pub fn excess_over_plan(observed: &[f64], predicted: &[f64]) -> Vec<f64> {
    let obs_any = observed.iter().any(|&o| o > 0.0);
    if !obs_any {
        return vec![0.0; observed.len()];
    }
    if !predicted.iter().any(|&p| p > 0.0) {
        // nothing was planned: everything observed is external
        return observed.to_vec();
    }
    let mut ratios: Vec<f64> = observed
        .iter()
        .zip(predicted)
        .filter(|(&o, &p)| o > 0.0 && p > 0.0)
        .map(|(&o, &p)| p / o)
        .collect();
    let scale = if ratios.is_empty() {
        1.0 // disjoint supports: compare raw magnitudes
    } else {
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ratios[ratios.len() / 2]
    };
    observed
        .iter()
        .zip(predicted)
        .map(|(&o, &p)| (o * scale - p).max(0.0))
        .collect()
}

/// Bottleneck drain-time estimate of `loads` stacked on `background`
/// (seconds): max over per-link drain, per-GPU in/out aggregates,
/// per-node NIC aggregates and the topology's shared-constraint terms
/// (leaf core uplinks on tiered fabrics) — the aggregates of
/// [`super::lower_bound_norm_load`] further capped by the fabric's
/// endpoint anchors ([`DrainCaps`]). Including the endpoint bounds is
/// the churn guard: a reshuffle of endpoint-bound traffic shows no
/// improvement here because none is physically available.
///
/// On flat topologies this computes exactly the pre-tier metric,
/// accumulation order and all: every link has GPU endpoints, node
/// aggregates cover the `Rail` links, and `shared` is empty.
pub(crate) fn drain_time_z(
    topo: &Topology,
    caps: &DrainCaps,
    shared: &SharedConstraints,
    loads: &[f64],
    background: &[f64],
) -> f64 {
    drain_time_z_scaled(topo, caps, shared, loads, background, None)
}

/// [`drain_time_z`] under fault-scaled link capacities: `scale[l]`
/// multiplies link `l`'s capacity in both the per-link terms and the
/// endpoint aggregates (the same clamp as the planner's hop pricing
/// keeps dead-link carries finite). Without this, a replan under a
/// degraded rail would price the carry at *healthy* capacity,
/// under-estimate its drain time, and reject the very challenger that
/// routes around the fault. `scale == None` is exactly the pre-fault
/// metric, accumulation order and all. The node-aggregate rail cap
/// stays topological (the per-link terms already catch a degraded
/// rail's own bottleneck).
pub(crate) fn drain_time_z_scaled(
    topo: &Topology,
    caps: &DrainCaps,
    shared: &SharedConstraints,
    loads: &[f64],
    background: &[f64],
    scale: Option<&[f64]>,
) -> f64 {
    fold_terms(&drain_time_terms(topo, caps, shared, loads, background, scale))
}

/// Reduce a term list back to the drain-time `z`. The terms are
/// emitted in exactly the accumulation order the pre-decomposition
/// metric used, so this fold is bit-identical to it.
pub(crate) fn fold_terms(terms: &[(ConsId, f64)]) -> f64 {
    terms.iter().fold(0.0f64, |z, &(_, v)| z.max(v))
}

/// The top-`k` binding constraints of a term list, `(label, z_term)`
/// descending by term; equal terms tie-break on [`ConsId`] order so
/// the selection is deterministic. Zero terms never bind.
pub(crate) fn top_binding(terms: &[(ConsId, f64)], k: usize) -> Vec<(String, f64)> {
    let mut live: Vec<(ConsId, f64)> =
        terms.iter().filter(|&&(_, v)| v > 0.0).cloned().collect();
    live.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    live.truncate(k);
    live.into_iter().map(|(id, v)| (id.label(), v)).collect()
}

/// The full constraint-term decomposition behind
/// [`drain_time_z_scaled`]: every `(constraint, load/cap)` max-term,
/// in the exact order the scalar metric accumulated them (so
/// [`fold_terms`] reproduces `z` bit-identically). This is what the
/// decision audit ranks to name the binding constraints.
pub(crate) fn drain_time_terms(
    topo: &Topology,
    caps: &DrainCaps,
    shared: &SharedConstraints,
    loads: &[f64],
    background: &[f64],
    scale: Option<&[f64]>,
) -> Vec<(ConsId, f64)> {
    let g = topo.num_gpus();
    let mut terms = Vec::with_capacity(topo.links.len() + 2 * g + 2 * topo.nodes);
    let mut out = vec![0.0f64; g];
    let mut inb = vec![0.0f64; g];
    let mut out_cap = vec![0.0f64; g];
    let mut in_cap = vec![0.0f64; g];
    let mut node_out = vec![0.0f64; topo.nodes];
    let mut node_in = vec![0.0f64; topo.nodes];
    for (i, l) in topo.links.iter().enumerate() {
        let load = loads[i] + background[i];
        let cap = match scale {
            Some(s) => l.cap_gbps * s[i].max(1e-6) * 1e9,
            None => l.cap_gbps * 1e9,
        };
        terms.push((ConsId::Link(i), load / cap));
        if !matches!(l.kind, LinkKind::CrossRail { .. }) {
            if l.src < g {
                out[l.src] += load;
                out_cap[l.src] += cap;
            }
            if l.dst < g {
                inb[l.dst] += load;
                in_cap[l.dst] += cap;
            }
        }
        match l.kind {
            LinkKind::Rail { .. } => {
                node_out[topo.node_of(l.src)] += load;
                node_in[topo.node_of(l.dst)] += load;
            }
            LinkKind::LeafUp { .. } => node_out[topo.node_of(l.src)] += load,
            LinkKind::LeafDown { .. } => node_in[topo.node_of(l.dst)] += load,
            _ => {}
        }
    }
    for gi in 0..g {
        if out_cap[gi] > 0.0 {
            terms.push((
                ConsId::GpuOut(gi),
                out[gi] / out_cap[gi].min(caps.inject_gbps * 1e9),
            ));
        }
        if in_cap[gi] > 0.0 {
            terms.push((
                ConsId::GpuIn(gi),
                inb[gi] / in_cap[gi].min(caps.recv_gbps * 1e9),
            ));
        }
    }
    let rails_cap = (topo.nics_per_node as f64 * topo.rail_gbps * 1e9)
        .min(caps.node_net_gbps * 1e9);
    for n in 0..topo.nodes {
        terms.push((ConsId::NodeOut(n), node_out[n] / rails_cap));
        terms.push((ConsId::NodeIn(n), node_in[n] / rails_cap));
    }
    for (i, t) in shared.terms.iter().enumerate() {
        let agg: f64 = t.members.iter().map(|&l| loads[l] + background[l]).sum();
        terms.push((ConsId::Shared(i), agg / t.cap_bps));
    }
    terms
}

/// Pairs whose routing materially differs between two plans over the
/// same pair set: a path kind appears/disappears, or a path's byte
/// share moves by more than 1% of the pair total.
pub(crate) fn diff_pairs(a: &Plan, b: &Plan) -> Vec<(GpuId, GpuId)> {
    let mut out = Vec::new();
    for (key, aa) in &a.assignments {
        let total = aa.total_bytes().max(1.0);
        let tol = total * 0.01;
        let to_map = |x: &Assignment| -> BTreeMap<PathKind, f64> {
            let mut m = BTreeMap::new();
            for (p, bytes) in &x.parts {
                *m.entry(p.kind).or_insert(0.0) += *bytes;
            }
            m
        };
        let ma = to_map(aa);
        match b.assignments.get(key) {
            None => out.push(*key),
            Some(ab) => {
                let mb = to_map(ab);
                let kinds: Vec<PathKind> =
                    ma.keys().chain(mb.keys()).cloned().collect();
                if kinds.iter().any(|k| {
                    (ma.get(k).unwrap_or(&0.0) - mb.get(k).unwrap_or(&0.0)).abs() > tol
                }) {
                    out.push(*key);
                }
            }
        }
    }
    for key in b.assignments.keys() {
        if !a.assignments.contains_key(key) {
            out.push(*key);
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

impl<'a> Planner<'a> {
    /// One replan decision of the execution-time loop. Deterministic:
    /// identical inputs yield an identical outcome, and when the
    /// residual demands and observed loads match the incumbent plan the
    /// result is the incumbent itself, byte for byte.
    pub fn replan(
        &mut self,
        incumbent: &Plan,
        observed_loads: &[f64],
        residual: &[Demand],
        rcfg: &ReplanCfg,
    ) -> ReplanOutcome {
        self.replan_with(incumbent, observed_loads, residual, rcfg, &[])
    }

    /// [`Planner::replan`] with **forced pairs**: pairs whose in-flight
    /// path crosses a dead link (the coordinator identifies them when a
    /// fault lands). A non-empty forced set waives the hysteresis
    /// acceptance test — recovery must not lose to anti-churn, a dead
    /// path's drain time is infinite regardless of what the z-estimate
    /// under clamped capacities says — but the challenger is still
    /// adopted only if it actually moves some pair. With replanning
    /// disabled the carry is returned even when pairs are forced: a
    /// static plan has no recovery path, which is exactly the contrast
    /// `nimble faults` measures.
    pub fn replan_forced(
        &mut self,
        incumbent: &Plan,
        observed_loads: &[f64],
        residual: &[Demand],
        rcfg: &ReplanCfg,
        forced: &[(GpuId, GpuId)],
    ) -> ReplanOutcome {
        self.replan_with(incumbent, observed_loads, residual, rcfg, forced)
    }

    fn replan_with(
        &mut self,
        incumbent: &Plan,
        observed_loads: &[f64],
        residual: &[Demand],
        rcfg: &ReplanCfg,
        forced: &[(GpuId, GpuId)],
    ) -> ReplanOutcome {
        let topo = self.topo();
        assert_eq!(observed_loads.len(), topo.links.len());
        let deviation = shape_deviation(topo, observed_loads, &incumbent.link_load);

        // residual totals per pair, to detect the exact no-op case
        let mut pairs: BTreeMap<(GpuId, GpuId), f64> = BTreeMap::new();
        for d in residual {
            if d.bytes > 0.0 {
                *pairs.entry((d.src, d.dst)).or_insert(0.0) += d.bytes;
            }
        }
        // no-op fast path: residuals still match the incumbent (up to
        // float noise from the fluid integration) ⇒ reuse it verbatim
        let exact_match = pairs.len() == incumbent.assignments.len()
            && pairs.iter().all(|(k, &b)| {
                incumbent
                    .assignments
                    .get(k)
                    .map_or(false, |a| (a.total_bytes() - b).abs() <= b * 1e-9)
            });
        let carry = if exact_match {
            incumbent.clone()
        } else {
            carry_plan(topo, incumbent, residual)
        };
        if !rcfg.enable {
            return ReplanOutcome {
                plan: carry,
                replanned: false,
                deviation,
                changed_pairs: Vec::new(),
                audit: None,
            };
        }

        // external pressure, with a deadband of margin × the plan's
        // peak link load: unit-conversion noise between the monitor's
        // window shape and the residual shape must not read as pressure
        let mut excess = excess_over_plan(observed_loads, &incumbent.link_load);
        let deadband =
            rcfg.margin * incumbent.link_load.iter().cloned().fold(0.0f64, f64::max);
        for e in excess.iter_mut() {
            *e = (*e - deadband).max(0.0);
        }
        // challenger: Algorithm 1 on the residuals, warm-started from
        // the external pressure and the in-flight (dominant) paths
        let seeds: BTreeMap<(GpuId, GpuId), PathKind> = incumbent
            .assignments
            .iter()
            .filter_map(|(k, a)| {
                a.parts
                    .iter()
                    .max_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
                    .map(|(p, _)| (*k, p.kind))
            })
            .collect();
        let challenger = self.plan_seeded(residual, Some(&excess), Some(&seeds));

        // z under the installed link health: fault-free runs have no
        // health and this is exactly the pre-fault drain_time_z.
        let hscale = self.health().map(|h| h.scale.clone());
        let shared = self.shared();
        let terms_carry = drain_time_terms(
            topo,
            &rcfg.caps,
            shared,
            &carry.link_load,
            &excess,
            hscale.as_deref(),
        );
        let terms_chal = drain_time_terms(
            topo,
            &rcfg.caps,
            shared,
            &challenger.link_load,
            &excess,
            hscale.as_deref(),
        );
        let z_carry = fold_terms(&terms_carry);
        let z_challenger = fold_terms(&terms_chal);
        let accept =
            !forced.is_empty() || z_challenger < z_carry * (1.0 - rcfg.margin);
        let audit = Some(ReplanAudit {
            z_carry,
            z_challenger,
            margin: rcfg.margin,
            forced: !forced.is_empty(),
            mwu_visits: self.mwu_last_visits(),
            candidates: vec![
                CandidateAudit {
                    name: "carry",
                    z_s: z_carry,
                    delta_s: 0.0,
                    binding: top_binding(&terms_carry, TOP_K_BINDING),
                },
                CandidateAudit {
                    name: "challenger",
                    z_s: z_challenger,
                    delta_s: z_challenger - z_carry,
                    binding: top_binding(&terms_chal, TOP_K_BINDING),
                },
            ],
        });
        if accept {
            let changed_pairs = diff_pairs(&carry, &challenger);
            if !changed_pairs.is_empty() {
                return ReplanOutcome {
                    plan: challenger,
                    replanned: true,
                    deviation,
                    changed_pairs,
                    audit,
                };
            }
        }
        ReplanOutcome {
            plan: carry,
            replanned: false,
            deviation,
            changed_pairs: Vec::new(),
            audit,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::PlannerCfg;

    const MB: f64 = 1024.0 * 1024.0;

    fn enabled() -> ReplanCfg {
        ReplanCfg { enable: true, ..ReplanCfg::default() }
    }

    /// Observed loads matching the incumbent + unchanged residuals ⇒
    /// the replan returns the incumbent byte-identically, twice.
    #[test]
    fn noop_when_execution_matches_plan() {
        let t = Topology::paper();
        let demands = vec![
            Demand::new(0, 1, 192.0 * MB),
            Demand::new(2, 1, 96.0 * MB),
            Demand::new(0, 5, 64.0 * MB),
        ];
        let mut planner = Planner::new(&t, PlannerCfg::default());
        let incumbent = planner.plan(&demands);
        // observed = exactly the plan's own loads (any common scale)
        let observed: Vec<f64> = incumbent.link_load.iter().map(|l| l * 0.25).collect();
        for _ in 0..2 {
            let out = planner.replan(&incumbent, &observed, &demands, &enabled());
            assert!(!out.replanned, "no-op case replanned");
            assert!(out.deviation < 1e-12, "deviation {}", out.deviation);
            assert_eq!(out.plan.link_load, incumbent.link_load);
            assert_eq!(out.plan.assignments.len(), incumbent.assignments.len());
            for (key, a) in &incumbent.assignments {
                let b = &out.plan.assignments[key];
                assert_eq!(a.parts.len(), b.parts.len());
                for ((pa, ba), (pb, bb)) in a.parts.iter().zip(&b.parts) {
                    assert_eq!(pa, pb);
                    assert_eq!(ba.to_bits(), bb.to_bits(), "bytes differ on {key:?}");
                }
            }
        }
    }

    /// Determinism: identical inputs produce identical decisions and
    /// byte-identical plans, including when a replan fires.
    #[test]
    fn replan_is_deterministic() {
        let t = Topology::paper();
        // incumbent routes a now-heavy pair on a single default path
        let stale = vec![Demand::new(2, 1, 2.0 * MB)];
        let mut planner = Planner::new(&t, PlannerCfg::default());
        let incumbent = planner.plan(&stale);
        let residual = vec![Demand::new(2, 1, 512.0 * MB)];
        let observed = incumbent.link_load.clone();
        let a = planner.replan(&incumbent, &observed, &residual, &enabled());
        let b = planner.replan(&incumbent, &observed, &residual, &enabled());
        assert_eq!(a.replanned, b.replanned);
        assert_eq!(a.changed_pairs, b.changed_pairs);
        assert_eq!(a.plan.link_load, b.plan.link_load);
        assert!(a.replanned, "heavy residual on one path should replan");
        assert!(
            a.plan.assignments[&(2, 1)].path_count() > 1,
            "challenger should go multi-path"
        );
    }

    /// Disabled replanning always carries the incumbent forward.
    #[test]
    fn disabled_never_replans() {
        let t = Topology::paper();
        let stale = vec![Demand::new(2, 1, 2.0 * MB)];
        let mut planner = Planner::new(&t, PlannerCfg::default());
        let incumbent = planner.plan(&stale);
        let residual = vec![Demand::new(2, 1, 512.0 * MB)];
        let out = planner.replan(
            &incumbent,
            &incumbent.link_load.clone(),
            &residual,
            &ReplanCfg::default(),
        );
        assert!(!out.replanned);
        assert_eq!(out.plan.assignments[&(2, 1)].path_count(), 1);
    }

    /// Carry scales splits onto residuals and defaults unknown pairs.
    #[test]
    fn carry_scales_and_defaults() {
        let t = Topology::paper();
        let mut planner = Planner::new(&t, PlannerCfg::default());
        let incumbent = planner.plan(&[Demand::new(0, 1, 512.0 * MB)]);
        let residual =
            vec![Demand::new(0, 1, 256.0 * MB), Demand::new(3, 2, 64.0 * MB)];
        let carry = carry_plan(&t, &incumbent, &residual);
        carry.validate(&t, &residual).unwrap();
        // splits preserved: each part halves with the pair total
        let inc = &incumbent.assignments[&(0, 1)];
        let car = &carry.assignments[&(0, 1)];
        assert_eq!(inc.parts.len(), car.parts.len());
        for ((pi, bi), (pc, bc)) in inc.parts.iter().zip(&car.parts) {
            assert_eq!(pi.kind, pc.kind);
            assert!((bc - bi * 0.5).abs() < 1e-6);
        }
        // unknown pair rides its default single path
        assert_eq!(carry.assignments[&(3, 2)].path_count(), 1);
    }

    /// External pressure on the planned bottleneck link triggers a
    /// reroute away from it.
    #[test]
    fn external_pressure_moves_traffic_away() {
        let t = Topology::paper();
        let demands = vec![Demand::new(0, 1, 256.0 * MB)];
        let mut planner = Planner::new(&t, PlannerCfg::default());
        let incumbent = planner.plan(&demands);
        let direct = t.nvlink(0, 1).unwrap();
        let planned_direct = incumbent.link_load[direct];
        assert!(planned_direct > 0.0);
        // observe the direct link at 4× its planned share
        let mut observed = incumbent.link_load.clone();
        observed[direct] *= 4.0;
        let out = planner.replan(&incumbent, &observed, &demands, &enabled());
        assert!(out.deviation > 0.1, "deviation {}", out.deviation);
        assert!(out.replanned, "pressure should force a reroute");
        let direct_bytes: f64 = out.plan.assignments[&(0, 1)]
            .parts
            .iter()
            .filter(|(p, _)| p.hops == vec![direct])
            .map(|(_, b)| *b)
            .sum();
        assert!(
            direct_bytes < planned_direct,
            "challenger kept {direct_bytes} on the pressured link (was {planned_direct})"
        );
    }

    /// A dead link forces a reroute even when the z-hysteresis would
    /// not fire, and the challenger carries nothing on the dead link.
    #[test]
    fn forced_replan_reroutes_off_dead_link() {
        let t = Topology::paper();
        let demands = vec![Demand::new(0, 4, 512.0 * MB)];
        let mut planner = Planner::new(&t, PlannerCfg::default());
        let incumbent = planner.plan(&demands);
        let dead = t.rail(0, 1, 0).unwrap();
        assert!(incumbent.link_load[dead] > 0.0, "incumbent must use the home rail");

        let mut scale = vec![1.0; t.links.len()];
        scale[dead] = 0.0;
        planner.set_link_health(Some(scale));
        let observed = incumbent.link_load.clone();
        let out = planner.replan_forced(
            &incumbent,
            &observed,
            &demands,
            &enabled(),
            &[(0, 4)],
        );
        assert!(out.replanned, "dead link must force a reroute");
        assert!(out.changed_pairs.contains(&(0, 4)));
        assert_eq!(out.plan.link_load[dead], 0.0, "challenger still uses dead link");
        out.plan.validate(&t, &demands).unwrap();
    }

    /// Forced pairs never override the master switch: a static plan has
    /// no recovery path (the contrast `nimble faults` measures).
    #[test]
    fn forced_replan_respects_disabled_cfg() {
        let t = Topology::paper();
        let demands = vec![Demand::new(0, 4, 512.0 * MB)];
        let mut planner = Planner::new(&t, PlannerCfg::default());
        let incumbent = planner.plan(&demands);
        let dead = t.rail(0, 1, 0).unwrap();
        let mut scale = vec![1.0; t.links.len()];
        scale[dead] = 0.0;
        planner.set_link_health(Some(scale));
        let out = planner.replan_forced(
            &incumbent,
            &incumbent.link_load.clone(),
            &demands,
            &ReplanCfg::default(),
            &[(0, 4)],
        );
        assert!(!out.replanned);
        assert!(out.plan.link_load[dead] > 0.0, "static carry keeps the dead path");
    }

    /// The scaled z metric prices degraded capacity; the unscaled
    /// delegate is the exact legacy value.
    #[test]
    fn scaled_drain_time_prices_degradation() {
        let t = Topology::paper();
        let caps = DrainCaps::default();
        let shared = SharedConstraints::of(&t);
        let rail = t.rail(0, 1, 0).unwrap();
        let mut loads = vec![0.0; t.links.len()];
        loads[rail] = 45.1e9; // one second of healthy rail drain
        let zero = vec![0.0; t.links.len()];
        let z0 = drain_time_z(&t, &caps, &shared, &loads, &zero);
        let z_none =
            drain_time_z_scaled(&t, &caps, &shared, &loads, &zero, None);
        assert_eq!(z0.to_bits(), z_none.to_bits());
        let mut scale = vec![1.0; t.links.len()];
        scale[rail] = 0.25;
        let z_deg =
            drain_time_z_scaled(&t, &caps, &shared, &loads, &zero, Some(&scale));
        assert!(
            z_deg >= z0 * 3.9,
            "quartered rail should ~4x its drain term: {z_deg} vs {z0}"
        );
    }

    /// The constraint-term decomposition folds back to exactly the
    /// scalar drain-time metric, and the loaded constraint tops the
    /// deterministic binding ranking.
    #[test]
    fn drain_terms_fold_to_z_and_rank_binding() {
        let t = Topology::paper();
        let caps = DrainCaps::default();
        let shared = SharedConstraints::of(&t);
        let rail = t.rail(0, 1, 0).unwrap();
        let mut loads = vec![0.0; t.links.len()];
        loads[rail] = 45.1e9; // one second of healthy rail drain
        let zero = vec![0.0; t.links.len()];
        let terms = drain_time_terms(&t, &caps, &shared, &loads, &zero, None);
        let z = drain_time_z(&t, &caps, &shared, &loads, &zero);
        assert_eq!(fold_terms(&terms).to_bits(), z.to_bits());
        let binding = top_binding(&terms, TOP_K_BINDING);
        assert!(!binding.is_empty());
        assert_eq!(binding[0].0, format!("link:{rail}"));
        assert_eq!(binding[0].1.to_bits(), z.to_bits());
        for w in binding.windows(2) {
            assert!(w[0].1 >= w[1].1, "binding list not descending");
        }
    }

    /// An enabled replan always carries per-candidate audit evidence
    /// whose z figures match the headline numbers.
    #[test]
    fn audit_carries_candidate_evidence() {
        let t = Topology::paper();
        let stale = vec![Demand::new(2, 1, 2.0 * MB)];
        let mut planner = Planner::new(&t, PlannerCfg::default());
        let incumbent = planner.plan(&stale);
        let residual = vec![Demand::new(2, 1, 512.0 * MB)];
        let observed = incumbent.link_load.clone();
        let out = planner.replan(&incumbent, &observed, &residual, &enabled());
        let audit = out.audit.expect("enabled replan must audit");
        assert_eq!(audit.candidates.len(), 2);
        let carry = &audit.candidates[0];
        let chal = &audit.candidates[1];
        assert_eq!(carry.name, "carry");
        assert_eq!(chal.name, "challenger");
        assert_eq!(carry.z_s.to_bits(), audit.z_carry.to_bits());
        assert_eq!(chal.z_s.to_bits(), audit.z_challenger.to_bits());
        assert_eq!(carry.delta_s, 0.0);
        assert_eq!(
            chal.delta_s.to_bits(),
            (audit.z_challenger - audit.z_carry).to_bits()
        );
        assert!(!carry.binding.is_empty() && carry.binding.len() <= TOP_K_BINDING);
        assert!(!chal.binding.is_empty());
    }

    #[test]
    fn shape_deviation_basics() {
        let t = Topology::paper();
        let zero = vec![0.0; t.links.len()];
        assert_eq!(shape_deviation(&t, &zero, &zero), 0.0);
        let mut a = zero.clone();
        a[0] = 5e8;
        assert_eq!(shape_deviation(&t, &a, &zero), 1.0);
        // same shape at a different scale ⇒ zero deviation
        let b: Vec<f64> = a.iter().map(|x| x * 3.0).collect();
        assert!(shape_deviation(&t, &a, &b) < 1e-12);
    }
}
