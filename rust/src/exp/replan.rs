//! Execution-time re-planning experiment (`nimble replan`): static
//! plan-once vs the closed monitor → replan → reroute loop, over a
//! time-varying skew workload.
//!
//! Both arms start every round from a plan that predates the round's
//! traffic — the static arm keeps the round-0 plan forever, the
//! re-planned arm carries the previous round's final plan and is
//! allowed to reroute mid-flight. With `[replan]` disabled the second
//! arm degenerates to the first, byte for byte.

use super::MB;
use crate::coordinator::replan::{ReplanExecutor, ReplanRun};
use crate::fabric::FabricParams;
use crate::metrics::Table;
use crate::planner::{Demand, Plan, Planner, PlannerCfg, ReplanCfg};
use crate::telemetry::{Recorder, TraceRecord};
use crate::topology::Topology;
use crate::workloads::dynamic::{MoeDrift, PhasedHotRows};

/// Which time-varying workload drives the comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Phase-shifting hot source row (§III-A irregular p2p drift).
    HotRows,
    /// MoE expert-popularity drift (§V-D), dispatch + combine.
    MoeDrift,
}

/// One round of the comparison.
#[derive(Clone, Debug)]
pub struct ReplanRow {
    pub round: usize,
    /// The round's hot GPU (source row or hot expert).
    pub hot: usize,
    pub static_s: f64,
    pub replanned_s: f64,
    pub replans: usize,
    pub preemptions: usize,
    /// Peak traffic-drift indicator over the round's epochs (see
    /// [`crate::coordinator::replan::EpochStat::deviation`]).
    pub deviation: f64,
}

impl ReplanRow {
    pub fn speedup(&self) -> f64 {
        self.static_s / self.replanned_s
    }
}

/// Sweep outcome: per-round rows plus aggregate goodput (GB/s) and the
/// fluid-engine event totals of each arm (preemption + re-issue grows
/// the re-planned arm's hot-path volume — the overhead the incremental
/// water-filler keeps cheap).
#[derive(Clone, Debug)]
pub struct ReplanSweep {
    pub rows: Vec<ReplanRow>,
    pub static_goodput_gbps: f64,
    pub replanned_goodput_gbps: f64,
    pub static_sim_events: u64,
    pub replanned_sim_events: u64,
}

fn round_demands(
    topo: &Topology,
    workload: Workload,
    hot_rows: &PhasedHotRows,
    moe: &MoeDrift,
    round: usize,
) -> (usize, Vec<Demand>) {
    match workload {
        Workload::HotRows => (hot_rows.hot_at(round), hot_rows.demands_at(topo, round)),
        Workload::MoeDrift => {
            let pop = moe.popularity_at(topo, round);
            let hot = pop
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            (hot, moe.demands_at(topo, round))
        }
    }
}

/// Run `rounds` rounds of `workload`, comparing the static round-0 plan
/// against the re-planned loop configured by `rcfg`.
pub fn sweep(
    topo: &Topology,
    params: &FabricParams,
    rcfg: &ReplanCfg,
    workload: Workload,
    rounds: usize,
    row_mb: f64,
) -> ReplanSweep {
    sweep_traced(topo, params, rcfg, workload, rounds, row_mb, &Recorder::disabled())
}

/// [`sweep`] with a telemetry sink: each round's arms run as labeled
/// trace runs `static/round{N}` / `replanned/round{N}`. With a
/// disabled recorder this *is* `sweep` (pure observer, DESIGN.md §15).
#[allow(clippy::too_many_arguments)]
pub fn sweep_traced(
    topo: &Topology,
    params: &FabricParams,
    rcfg: &ReplanCfg,
    workload: Workload,
    rounds: usize,
    row_mb: f64,
    rec: &Recorder,
) -> ReplanSweep {
    let hot_rows = PhasedHotRows::paper_default(topo, row_mb * MB);
    let moe = MoeDrift::paper_default(topo, 32_768);

    // the one plan the static arm ever computes
    let (_, d0) = round_demands(topo, workload, &hot_rows, &moe, 0);
    let p0 = Planner::new(topo, PlannerCfg::default()).plan(&d0);

    let static_cfg = ReplanCfg { enable: false, ..rcfg.clone() };
    let mut static_exec =
        ReplanExecutor::new(topo, params.clone(), PlannerCfg::default(), static_cfg)
            .with_recorder(rec.clone());
    let mut replan_exec =
        ReplanExecutor::new(topo, params.clone(), PlannerCfg::default(), rcfg.clone())
            .with_recorder(rec.clone());

    let mut incumbent: Plan = p0.clone();
    let mut rows = Vec::with_capacity(rounds);
    let mut payload_total = 0.0f64;
    let mut static_time = 0.0f64;
    let mut replanned_time = 0.0f64;
    let mut static_sim_events = 0u64;
    let mut replanned_sim_events = 0u64;
    for round in 0..rounds {
        let (hot, demands) = round_demands(topo, workload, &hot_rows, &moe, round);
        let round_payload = demands.iter().map(|d| d.bytes).sum::<f64>();
        payload_total += round_payload;

        rec.set_run(&format!("static/round{round}"));
        rec.emit(|| TraceRecord::Run {
            cadence_s: rcfg.cadence_s,
            t0_s: -1.0,
            payload_bytes: round_payload,
        });
        let s: ReplanRun = static_exec.execute(&p0, &demands);
        rec.set_run(&format!("replanned/round{round}"));
        rec.emit(|| TraceRecord::Run {
            cadence_s: rcfg.cadence_s,
            t0_s: -1.0,
            payload_bytes: round_payload,
        });
        let r: ReplanRun = replan_exec.execute(&incumbent, &demands);
        incumbent = r.final_plan.clone();

        static_time += s.report.makespan_s;
        replanned_time += r.report.makespan_s;
        static_sim_events += s.sim_events;
        replanned_sim_events += r.sim_events;
        rows.push(ReplanRow {
            round,
            hot,
            static_s: s.report.makespan_s,
            replanned_s: r.report.makespan_s,
            replans: r.replans,
            preemptions: r.preemptions,
            deviation: r
                .epochs
                .iter()
                .map(|e| e.deviation)
                .fold(0.0f64, f64::max),
        });
    }
    ReplanSweep {
        rows,
        static_goodput_gbps: payload_total / static_time.max(1e-12) / 1e9,
        replanned_goodput_gbps: payload_total / replanned_time.max(1e-12) / 1e9,
        static_sim_events,
        replanned_sim_events,
    }
}

pub fn render(
    topo: &Topology,
    params: &FabricParams,
    rcfg: &ReplanCfg,
    workload: Workload,
    rounds: usize,
    row_mb: f64,
) -> String {
    render_traced(topo, params, rcfg, workload, rounds, row_mb, &Recorder::disabled())
}

/// [`render`] with a telemetry sink (the `nimble replan --trace` path).
#[allow(clippy::too_many_arguments)]
pub fn render_traced(
    topo: &Topology,
    params: &FabricParams,
    rcfg: &ReplanCfg,
    workload: Workload,
    rounds: usize,
    row_mb: f64,
    rec: &Recorder,
) -> String {
    let sweep = sweep_traced(topo, params, rcfg, workload, rounds, row_mb, rec);
    let mut t = Table::new(&[
        "round",
        "hot",
        "static (ms)",
        "replanned (ms)",
        "speedup",
        "replans",
        "preempted",
        "peak drift",
    ]);
    for r in &sweep.rows {
        t.row(&[
            format!("{}", r.round),
            format!("{}", r.hot),
            format!("{:.3}", r.static_s * 1e3),
            format!("{:.3}", r.replanned_s * 1e3),
            format!("{:.2}", r.speedup()),
            format!("{}", r.replans),
            format!("{}", r.preemptions),
            format!("{:.2}", r.deviation),
        ]);
    }
    let name = match workload {
        Workload::HotRows => "phase-shifting hot rows",
        Workload::MoeDrift => "MoE expert-popularity drift",
    };
    format!(
        "Execution-time re-planning vs static plan ({name}, {} rounds, cadence {:.1} ms, margin {:.0}%{})\n{}\n\
         aggregate goodput: static {:.1} GB/s, re-planned {:.1} GB/s ({:.2}x)\n\
         fluid-engine events: static {}, re-planned {} (preempt/re-issue overhead the incremental solver absorbs)\n",
        rounds,
        rcfg.cadence_s * 1e3,
        rcfg.margin * 100.0,
        if rcfg.enable { "" } else { ", REPLAN DISABLED" },
        t.render(),
        sweep.static_goodput_gbps,
        sweep.replanned_goodput_gbps,
        sweep.replanned_goodput_gbps / sweep.static_goodput_gbps.max(1e-12),
        sweep.static_sim_events,
        sweep.replanned_sim_events,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled() -> ReplanCfg {
        ReplanCfg { enable: true, cadence_s: 5.0e-4, margin: 0.1, ..ReplanCfg::default() }
    }

    /// The acceptance claim: re-planned goodput strictly beats the
    /// static plan on the time-varying hot-row workload.
    #[test]
    fn replanned_goodput_beats_static_on_hot_rows() {
        let topo = Topology::paper();
        let params = FabricParams::default();
        let s = sweep(&topo, &params, &enabled(), Workload::HotRows, 4, 64.0);
        assert!(
            s.replanned_goodput_gbps > s.static_goodput_gbps,
            "re-planning did not help: {} vs {} GB/s",
            s.replanned_goodput_gbps,
            s.static_goodput_gbps
        );
        // round 0 is the planned phase: both arms match there
        let r0 = &s.rows[0];
        assert!((r0.speedup() - 1.0).abs() < 0.05, "round 0 speedup {}", r0.speedup());
        // at least one shifted round replans and wins outright
        assert!(
            s.rows.iter().skip(1).any(|r| r.replans > 0 && r.speedup() > 1.2),
            "no shifted round won: {:?}",
            s.rows.iter().map(ReplanRow::speedup).collect::<Vec<_>>()
        );
    }

    /// Disabled `[replan]` ⇒ both arms are the same path, byte for
    /// byte, on every round.
    #[test]
    fn disabled_replan_is_bit_identical_to_static() {
        let topo = Topology::paper();
        let params = FabricParams::default();
        let s = sweep(&topo, &params, &ReplanCfg::default(), Workload::HotRows, 3, 32.0);
        for r in &s.rows {
            assert_eq!(
                r.static_s.to_bits(),
                r.replanned_s.to_bits(),
                "round {} diverged with replanning disabled",
                r.round
            );
            assert_eq!(r.replans, 0);
            assert_eq!(r.preemptions, 0);
        }
        assert_eq!(
            s.static_goodput_gbps.to_bits(),
            s.replanned_goodput_gbps.to_bits()
        );
        assert_eq!(s.static_sim_events, s.replanned_sim_events);
    }

    /// The MoE drift workload also gains from re-planning (the combine
    /// phase's hot row is where the stale plan hurts).
    #[test]
    fn moe_drift_gains_from_replanning() {
        let topo = Topology::paper();
        let params = FabricParams::default();
        let s = sweep(&topo, &params, &enabled(), Workload::MoeDrift, 6, 64.0);
        assert!(
            s.replanned_goodput_gbps >= s.static_goodput_gbps * 0.99,
            "moe drift regressed: {} vs {}",
            s.replanned_goodput_gbps,
            s.static_goodput_gbps
        );
        assert!(
            s.rows.iter().any(|r| r.replans > 0),
            "moe drift never triggered a replan"
        );
    }
}
