//! Cluster-scale hot-path experiment (`nimble scale`): sweep the
//! topology scale axis (N nodes × 8 GPUs, 4 rails — see
//! [`Topology::cluster`]) with a skewed All-to-Allv and measure the
//! simulator and planner hot paths directly:
//!
//! * **events/sec** of the fluid engine under the incremental water-
//!   filler vs the pre-PR from-scratch reference solver
//!   ([`SolverKind`]) — same bit-exact trajectory, so the ratio is a
//!   pure solver speedup;
//! * **plan time** of the MWU planner at the configured thread count;
//! * **goodput** of the planned routing, as a sanity anchor that the
//!   faster solver still simulates the same physics.
//!
//! Every row can also be emitted as a machine-readable JSON line
//! ([`ScaleRow::json_line`]) so the perf trajectory is trackable across
//! PRs (`benches/scale_sweep.rs` prints them by default).

use super::MB;
use crate::baselines::{EcmpHash, Router};
use crate::coordinator::replan::ReplanExecutor;
use crate::fabric::fluid::{Flow, FluidSim, SimEngine, SolverKind};
use crate::fabric::packet::PacketSim;
use crate::fabric::{FabricParams, SchedulerKind};
use crate::metrics::Table;
use crate::planner::{Demand, Plan, Planner, PlannerCfg, ReplanCfg, SharedConstraints};
use crate::topology::Topology;
use crate::util::json::{json_line, Json};
use crate::util::rng::Rng;
use crate::workloads::skew::{hotspot_alltoallv_jittered, shifted_hotspot_alltoallv};
use std::time::Instant;

/// Which fabric shape the sweep instantiates at each node count.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScaleTopo {
    /// Flat rail-only cluster ([`Topology::cluster`]) — the historical
    /// sweep, kept bit-identical.
    Flat,
    /// Two-tier leaf–spine fat-tree ([`Topology::fat_tree`]) with the
    /// given core oversubscription ratio.
    FatTree { oversub: f64 },
}

impl ScaleTopo {
    pub fn build(&self, nodes: usize) -> Topology {
        match *self {
            ScaleTopo::Flat => Topology::cluster(nodes),
            ScaleTopo::FatTree { oversub } => Topology::fat_tree(nodes, oversub),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ScaleTopo::Flat => "flat",
            ScaleTopo::FatTree { .. } => "fat-tree",
        }
    }
}

/// Hot fraction of the skewed All-to-Allv driving the sweep.
pub const HOTSPOT_RATIO: f64 = 0.5;
/// Fixed jitter seed: per-pair payloads are jittered ±10% so flows
/// drain at distinct times — the event stream a real skewed collective
/// produces (uniform payloads collapse into a handful of simultaneous
/// completions and understate per-event solver cost).
pub const JITTER_SEED: u64 = 0x5CA1E;

/// The deterministic demand set for one flat scale point.
pub fn scale_demands(topo: &Topology, payload_bytes: f64) -> Vec<Demand> {
    let mut rng = Rng::new(JITTER_SEED);
    let (_, demands) =
        hotspot_alltoallv_jittered(topo, payload_bytes, HOTSPOT_RATIO, &mut rng);
    demands
}

/// The deterministic demand set for one tiered scale point: the skew
/// puts every rank's hot column on the same-local GPU half the cluster
/// away, so the hot traffic crosses the oversubscribed core instead of
/// piling onto one receiver NIC. A single-sink hotspot is bounded by
/// the hot node's ingress — a constraint no routing scheme can steer
/// around, which makes planned and ECMP goodput tie within noise and
/// tells us nothing about the core (DESIGN.md §12). Same
/// [`JITTER_SEED`] ±10% jitter as the flat sweep.
pub fn scale_demands_tiered(topo: &Topology, payload_bytes: f64) -> Vec<Demand> {
    let mut rng = Rng::new(JITTER_SEED);
    let mut demands =
        shifted_hotspot_alltoallv(topo, payload_bytes, HOTSPOT_RATIO, topo.nodes / 2);
    for d in demands.iter_mut() {
        d.bytes *= rng.range_f64(0.9, 1.1);
    }
    demands
}

/// Demand selection shared by [`run_one`] and the `--check` anchors.
pub fn demands_for(topo_kind: ScaleTopo, topo: &Topology, payload_bytes: f64) -> Vec<Demand> {
    match topo_kind {
        ScaleTopo::Flat => scale_demands(topo, payload_bytes),
        ScaleTopo::FatTree { .. } => scale_demands_tiered(topo, payload_bytes),
    }
}

/// One scale point's measurements.
#[derive(Clone, Debug)]
pub struct ScaleRow {
    pub nodes: usize,
    pub gpus: usize,
    pub links: usize,
    /// Distinct (src, dst) pairs in the demand set.
    pub pairs: usize,
    /// Flows the plan issues (pairs × their path splits).
    pub flows: usize,
    /// MWU planning wall time (seconds).
    pub plan_s: f64,
    /// Fluid-engine events (rate solves) — identical for both solvers.
    pub events: u64,
    /// Wall time of the incremental-solver run (seconds).
    pub incremental_s: f64,
    /// Wall time of the reference-solver run, when measured.
    pub reference_s: Option<f64>,
    /// Simulated makespan (virtual seconds).
    pub makespan_s: f64,
    /// Aggregate goodput of the round (GB/s).
    pub goodput_gbps: f64,
    /// Fabric shape label ("flat" | "fat-tree").
    pub topo: &'static str,
    /// Goodput of the ECMP hash-striping adversary on the identical
    /// demand set (tiered sweeps only).
    pub ecmp_goodput_gbps: Option<f64>,
    /// Fraction of the planned round the busiest leaf's core-uplink
    /// aggregate is busy (tiered sweeps only).
    pub core_uplink_util: Option<f64>,
}

impl ScaleRow {
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.incremental_s.max(1e-12)
    }

    pub fn reference_events_per_sec(&self) -> Option<f64> {
        self.reference_s.map(|s| self.events as f64 / s.max(1e-12))
    }

    /// Incremental-solver speedup over the reference solver.
    pub fn speedup(&self) -> Option<f64> {
        self.reference_s.map(|s| s / self.incremental_s.max(1e-12))
    }

    /// Planned-over-ECMP goodput ratio (tiered sweeps only).
    pub fn planned_over_ecmp(&self) -> Option<f64> {
        self.ecmp_goodput_gbps.map(|e| self.goodput_gbps / e.max(1e-12))
    }

    /// Machine-readable record for cross-PR perf tracking.
    pub fn json_line(&self) -> String {
        let mut fields = vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("gpus", Json::num(self.gpus as f64)),
            ("links", Json::num(self.links as f64)),
            ("pairs", Json::num(self.pairs as f64)),
            ("flows", Json::num(self.flows as f64)),
            ("events", Json::num(self.events as f64)),
            ("events_per_sec", Json::num(self.events_per_sec())),
            ("plan_us", Json::num(self.plan_s * 1e6)),
            ("sim_ms", Json::num(self.incremental_s * 1e3)),
            ("goodput_gbps", Json::num(self.goodput_gbps)),
            ("topo", Json::str(self.topo)),
        ];
        if let (Some(r), Some(sp)) = (self.reference_s, self.speedup()) {
            fields.push(("reference_sim_ms", Json::num(r * 1e3)));
            fields.push(("speedup_vs_reference", Json::num(sp)));
        }
        if let (Some(e), Some(ratio)) = (self.ecmp_goodput_gbps, self.planned_over_ecmp())
        {
            fields.push(("ecmp_goodput_gbps", Json::num(e)));
            fields.push(("planned_over_ecmp", Json::num(ratio)));
        }
        if let Some(u) = self.core_uplink_util {
            fields.push(("core_uplink_util", Json::num(u)));
        }
        json_line("scale", fields)
    }
}

/// The flow set a plan's assignments issue (one flow per path split) —
/// the same construction the disabled replan executor degenerates to.
pub fn plan_flows(plan: &Plan) -> Vec<Flow> {
    plan.assignments
        .values()
        .flat_map(|a| a.parts.iter().cloned())
        .map(|(p, bytes)| Flow::new(p, bytes))
        .collect()
}

/// Run one scale point: plan and fly a skewed All-to-Allv
/// (`payload_bytes` per rank, [`HOTSPOT_RATIO`] hot fraction; one
/// seeded hot sink on flat sweeps, cross-pod hot peers on tiered
/// sweeps — see [`demands_for`]) on `nodes` cluster nodes, under the
/// given fabric calibration and planner configuration (the CLI threads
/// `--config` through, like every other subcommand). With
/// `with_reference`, the identical flow set is re-simulated under the
/// reference solver and the two trajectories are asserted
/// bit-identical before the timing ratio is reported.
pub fn run_one(
    nodes: usize,
    payload_bytes: f64,
    params: &FabricParams,
    planner_cfg: &PlannerCfg,
    with_reference: bool,
    topo_kind: ScaleTopo,
) -> ScaleRow {
    let topo = topo_kind.build(nodes);
    let demands = demands_for(topo_kind, &topo, payload_bytes);
    let mut planner = Planner::new(&topo, planner_cfg.clone());
    let plan = planner.plan(&demands);
    plan.validate(&topo, &demands).expect("scale plan invalid");
    let flows = plan_flows(&plan);

    let run = |solver: SolverKind| {
        let mut engine = SimEngine::new(&topo, params.clone(), &flows);
        engine.set_solver(solver);
        let t = Instant::now();
        engine.run_to_completion();
        (t.elapsed().as_secs_f64(), engine.events(), engine.result())
    };
    let (incremental_s, events, sim) = run(SolverKind::Incremental);
    let reference_s = if with_reference {
        let (ref_s, ref_events, ref_sim) = run(SolverKind::Reference);
        assert_eq!(events, ref_events, "solver event counts diverged");
        assert_eq!(
            sim.makespan.to_bits(),
            ref_sim.makespan.to_bits(),
            "solver trajectories diverged"
        );
        assert_eq!(sim.link_bytes, ref_sim.link_bytes, "solver link bytes diverged");
        Some(ref_s)
    } else {
        None
    };

    let payload_total: f64 = demands.iter().map(|d| d.bytes).sum();
    // tiered rows carry the adversary comparison: the ECMP hash-striper
    // flies the identical demand set through the identical fluid fabric
    let (ecmp_goodput_gbps, core_uplink_util) = match topo_kind {
        ScaleTopo::Flat => (None, None),
        ScaleTopo::FatTree { .. } => {
            let ecmp_flows = EcmpHash::new().route_flows(&topo, &demands);
            let ecmp_sim = FluidSim::new(&topo, params.clone()).run(&ecmp_flows);
            let shared = SharedConstraints::of(&topo);
            let util = shared
                .uplink_norm_loads(&plan.link_load)
                .into_iter()
                .fold(0.0f64, f64::max)
                / sim.makespan.max(1e-12);
            (
                Some(payload_total / ecmp_sim.makespan.max(1e-12) / 1e9),
                Some(util),
            )
        }
    };
    ScaleRow {
        nodes,
        gpus: topo.num_gpus(),
        links: topo.links.len(),
        pairs: plan.assignments.len(),
        flows: flows.len(),
        plan_s: plan.plan_time_s,
        events,
        incremental_s,
        reference_s,
        makespan_s: sim.makespan,
        goodput_gbps: payload_total / sim.makespan.max(1e-12) / 1e9,
        topo: topo_kind.label(),
        ecmp_goodput_gbps,
        core_uplink_util,
    }
}

/// The scale twin of the replan guarantee: with `[replan]` disabled,
/// flying the scale workload through the [`ReplanExecutor`] is
/// bit-identical to the static one-shot fluid run of the same plan.
/// Returns the shared makespan.
pub fn check_static_bit_identity(
    nodes: usize,
    payload_bytes: f64,
    params: &FabricParams,
    planner_cfg: &PlannerCfg,
    topo_kind: ScaleTopo,
) -> f64 {
    let topo = topo_kind.build(nodes);
    let demands = demands_for(topo_kind, &topo, payload_bytes);
    let plan = Planner::new(&topo, planner_cfg.clone()).plan(&demands);
    let direct = FluidSim::new(&topo, params.clone()).run(&plan_flows(&plan));
    let run = ReplanExecutor::new(
        &topo,
        params.clone(),
        planner_cfg.clone(),
        ReplanCfg::default(),
    )
    .execute(&plan, &demands);
    assert_eq!(run.replans, 0);
    assert_eq!(
        run.report.makespan_s.to_bits(),
        direct.makespan.to_bits(),
        "replan-disabled run diverged from the static path at {nodes} nodes"
    );
    assert_eq!(run.sim.link_bytes, direct.link_bytes);
    direct.makespan
}

/// The tiered acceptance anchor (`--check` on fat-tree sweeps): under
/// the seeded cross-pod skewed All-to-Allv, planned multi-path routing
/// must deliver at least the ECMP hash-striper's aggregate goodput.
/// The margin comes from the core: the planner balances spine links
/// exactly while ECMP's hashed spine picks collide. Payloads well
/// above the multipath threshold (≥ 16 MB/rank; the CLI default is
/// 64 MB) keep the hot columns multi-path eligible — far below it the
/// comparison degenerates into per-flow saturation-efficiency noise.
/// Returns `(planned_gbps, ecmp_gbps)`.
pub fn check_planned_beats_ecmp(
    nodes: usize,
    payload_bytes: f64,
    oversub: f64,
    params: &FabricParams,
    planner_cfg: &PlannerCfg,
) -> (f64, f64) {
    let row = run_one(
        nodes,
        payload_bytes,
        params,
        planner_cfg,
        false,
        ScaleTopo::FatTree { oversub },
    );
    let ecmp = row.ecmp_goodput_gbps.expect("tiered row carries ecmp");
    assert!(
        row.goodput_gbps >= ecmp,
        "planned routing lost to ECMP at {nodes} nodes: {:.2} vs {ecmp:.2} GB/s",
        row.goodput_gbps,
    );
    (row.goodput_gbps, ecmp)
}

/// One packet-engine scheduler comparison (see [`check_packet_engine`]).
#[derive(Clone, Debug)]
pub struct PacketSmoke {
    pub nodes: usize,
    pub flows: usize,
    /// Packet-engine events — identical for both schedulers.
    pub events: u64,
    /// Wall time of the timing-wheel run (seconds).
    pub wheel_s: f64,
    /// Wall time of the binary-heap oracle run (seconds).
    pub heap_s: f64,
    /// Simulated makespan (virtual seconds), shared bit-for-bit.
    pub makespan_s: f64,
}

impl PacketSmoke {
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wheel_s.max(1e-12)
    }

    /// Timing-wheel speedup over the heap oracle on the same event stream.
    pub fn speedup(&self) -> f64 {
        self.heap_s / self.wheel_s.max(1e-12)
    }

    /// Machine-readable record for cross-PR perf tracking.
    pub fn json_line(&self) -> String {
        json_line(
            "packet_engine",
            vec![
                ("nodes", Json::num(self.nodes as f64)),
                ("flows", Json::num(self.flows as f64)),
                ("events", Json::num(self.events as f64)),
                ("events_per_sec", Json::num(self.events_per_sec())),
                ("sim_ms", Json::num(self.wheel_s * 1e3)),
                ("heap_sim_ms", Json::num(self.heap_s * 1e3)),
                ("speedup_vs_heap", Json::num(self.speedup())),
            ],
        )
    }
}

/// The packet-engine `--check` anchor: fly the planned scale workload
/// on the chunk-granular DES under both event schedulers and assert
/// the timing wheel reproduces the binary heap's run bit-for-bit —
/// event count, makespan bits, per-flow finish bits, per-link bytes
/// and tail samples (`tests/fabric_props.rs` pins the full trace; this
/// anchor re-proves it at cluster scale on every CI run). With
/// `min_speedup`, additionally gate the wheel's wall-clock advantage —
/// only meaningful in release builds, so the CLI passes it and the
/// debug-mode unit test does not.
pub fn check_packet_engine(
    nodes: usize,
    payload_bytes: f64,
    params: &FabricParams,
    planner_cfg: &PlannerCfg,
    topo_kind: ScaleTopo,
    min_speedup: Option<f64>,
) -> PacketSmoke {
    let topo = topo_kind.build(nodes);
    let demands = demands_for(topo_kind, &topo, payload_bytes);
    let plan = Planner::new(&topo, planner_cfg.clone()).plan(&demands);
    let flows = plan_flows(&plan);

    let run = |kind: SchedulerKind| {
        let mut p = params.clone();
        p.packet.scheduler = kind;
        let mut sim = PacketSim::new(&topo, p, &flows);
        let t = Instant::now();
        sim.run_to_completion().expect("fault-free packet run cannot stall");
        let wall = t.elapsed().as_secs_f64();
        let tail = sim.tail();
        (wall, sim.events(), sim.result(), tail)
    };
    let (wheel_s, events, wheel, wheel_tail) = run(SchedulerKind::Wheel);
    let (heap_s, heap_events, heap, heap_tail) = run(SchedulerKind::Heap);

    assert_eq!(events, heap_events, "scheduler event counts diverged");
    assert_eq!(
        wheel.makespan.to_bits(),
        heap.makespan.to_bits(),
        "scheduler trajectories diverged at {nodes} nodes"
    );
    assert_eq!(wheel.link_bytes, heap.link_bytes, "scheduler link bytes diverged");
    for (a, b) in wheel.flows.iter().zip(&heap.flows) {
        assert_eq!(
            a.finish_t.to_bits(),
            b.finish_t.to_bits(),
            "scheduler per-flow finishes diverged"
        );
    }
    assert_eq!(wheel_tail.delivered_chunks, heap_tail.delivered_chunks);
    assert_eq!(wheel_tail.sojourn, heap_tail.sojourn, "tail histograms diverged");

    let smoke = PacketSmoke {
        nodes,
        flows: flows.len(),
        events,
        wheel_s,
        heap_s,
        makespan_s: wheel.makespan,
    };
    if let Some(floor) = min_speedup {
        assert!(
            smoke.speedup() >= floor,
            "timing wheel under the {floor:.1}x floor vs heap at {nodes} nodes: \
             {:.2}x ({:.1} ms vs {:.1} ms over {} events)",
            smoke.speedup(),
            wheel_s * 1e3,
            heap_s * 1e3,
            events,
        );
    }
    smoke
}

/// Sweep the scale axis.
pub fn sweep(
    node_counts: &[usize],
    payload_bytes: f64,
    params: &FabricParams,
    planner_cfg: &PlannerCfg,
    with_reference: bool,
    topo_kind: ScaleTopo,
) -> Vec<ScaleRow> {
    node_counts
        .iter()
        .map(|&n| run_one(n, payload_bytes, params, planner_cfg, with_reference, topo_kind))
        .collect()
}

pub fn render(rows: &[ScaleRow], payload_bytes: f64, threads: usize) -> String {
    let tiered = rows.iter().any(|r| r.ecmp_goodput_gbps.is_some());
    let mut headers = vec![
        "nodes",
        "gpus",
        "pairs",
        "flows",
        "events",
        "plan (µs)",
        "sim (ms)",
        "ref (ms)",
        "events/s",
        "speedup",
        "goodput (GB/s)",
    ];
    if tiered {
        headers.extend(["ecmp (GB/s)", "vs ecmp", "core util"]);
    }
    let mut t = Table::new(&headers);
    for r in rows {
        let mut cells = vec![
            format!("{}", r.nodes),
            format!("{}", r.gpus),
            format!("{}", r.pairs),
            format!("{}", r.flows),
            format!("{}", r.events),
            format!("{:.1}", r.plan_s * 1e6),
            format!("{:.2}", r.incremental_s * 1e3),
            r.reference_s.map_or("-".into(), |s| format!("{:.2}", s * 1e3)),
            format!("{:.0}", r.events_per_sec()),
            r.speedup().map_or("-".into(), |s| format!("{s:.2}x")),
            format!("{:.1}", r.goodput_gbps),
        ];
        if tiered {
            cells.push(
                r.ecmp_goodput_gbps.map_or("-".into(), |g| format!("{g:.1}")),
            );
            cells.push(r.planned_over_ecmp().map_or("-".into(), |x| format!("{x:.2}x")));
            cells.push(r.core_uplink_util.map_or("-".into(), |u| format!("{u:.2}")));
        }
        t.row(&cells);
    }
    let topo_label = rows.first().map_or("flat", |r| r.topo);
    let skew_label = if tiered { "cross-pod hot peers" } else { "seeded hot sink" };
    format!(
        "Cluster-scale hot-path sweep ({topo_label} fabric, skewed All-to-Allv with {skew_label}, {:.0} MB/rank ±10% jitter, hot ratio {:.0}%, planner threads {})\n{}\
         speedup = incremental water-filler vs from-scratch reference solver, same bit-exact trajectory\n",
        payload_bytes / MB,
        HOTSPOT_RATIO * 100.0,
        threads,
        t.render(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The whole scale surface at a small size: plan validates, both
    /// solvers agree bitwise, and the disabled-replan executor matches
    /// the static path. The row plans at 2 threads while the executor
    /// check plans serially — equal makespans double as an end-to-end
    /// probe of the thread-count byte-identity contract.
    #[test]
    fn scale_point_is_consistent() {
        let params = FabricParams::default();
        let cfg = PlannerCfg { threads: 2, ..PlannerCfg::default() };
        let row = run_one(2, 8.0 * MB, &params, &cfg, true, ScaleTopo::Flat);
        assert_eq!(row.gpus, 16);
        assert!(row.events > 0);
        assert!(row.goodput_gbps > 0.0);
        assert!(row.reference_s.is_some());
        assert_eq!(row.topo, "flat");
        assert!(row.ecmp_goodput_gbps.is_none());
        let makespan = check_static_bit_identity(
            2,
            8.0 * MB,
            &params,
            &PlannerCfg::default(),
            ScaleTopo::Flat,
        );
        assert_eq!(
            makespan.to_bits(),
            row.makespan_s.to_bits(),
            "executor and scale row simulated different rounds"
        );
    }

    /// The packet-engine anchor holds at a small flat point: both
    /// schedulers replay the identical run, and the JSON line carries
    /// the tracked perf fields. No speedup floor here — wall-clock
    /// gates belong to release builds (`nimble scale --check` and
    /// `benches/packet_engine.rs`), not debug-mode unit tests.
    #[test]
    fn packet_smoke_schedulers_agree() {
        let smoke = check_packet_engine(
            2,
            4.0 * MB,
            &FabricParams::default(),
            &PlannerCfg::default(),
            ScaleTopo::Flat,
            None,
        );
        assert!(smoke.events > 0);
        assert!(smoke.makespan_s > 0.0);
        let j = Json::parse(&smoke.json_line()).unwrap();
        assert_eq!(j.get("exp").as_str(), Some("packet_engine"));
        assert_eq!(j.get("events").as_u64(), Some(smoke.events));
        assert!(j.get("speedup_vs_heap").as_f64().unwrap() > 0.0);
    }

    /// The JSON line parses back and carries the tracked fields.
    #[test]
    fn json_line_roundtrips() {
        let row = run_one(
            1,
            4.0 * MB,
            &FabricParams::default(),
            &PlannerCfg::default(),
            false,
            ScaleTopo::Flat,
        );
        let j = Json::parse(&row.json_line()).unwrap();
        assert_eq!(j.get("exp").as_str(), Some("scale"));
        assert_eq!(j.get("nodes").as_u64(), Some(1));
        assert_eq!(j.get("links").as_u64(), Some(row.links as u64));
        assert!(j.get("events_per_sec").as_f64().unwrap() > 0.0);
        assert!(j.get("plan_us").as_f64().unwrap() >= 0.0);
        assert_eq!(j.get("topo").as_str(), Some("flat"));
    }

    /// A tiered scale point: the row carries the ECMP comparison and
    /// core-uplink utilization, and under the cross-pod skew the
    /// planned routing does not lose to the hash-striping adversary.
    /// 16 MB/rank keeps the hot columns multi-path eligible — the
    /// regime the gate is about (see [`check_planned_beats_ecmp`]).
    #[test]
    fn fat_tree_point_beats_ecmp() {
        let params = FabricParams::default();
        let cfg = PlannerCfg::default();
        let row = run_one(
            8,
            16.0 * MB,
            &params,
            &cfg,
            false,
            ScaleTopo::FatTree { oversub: 2.0 },
        );
        assert_eq!(row.topo, "fat-tree");
        assert_eq!(row.gpus, 64);
        let ecmp = row.ecmp_goodput_gbps.expect("tiered row carries ecmp");
        assert!(ecmp > 0.0);
        assert!(
            row.goodput_gbps >= ecmp,
            "planned {:.2} GB/s lost to ecmp {ecmp:.2} GB/s",
            row.goodput_gbps
        );
        let util = row.core_uplink_util.expect("tiered row carries core util");
        assert!(util > 0.0 && util <= 1.0 + 1e-9, "util={util}");
        let j = Json::parse(&row.json_line()).unwrap();
        assert!(j.get("planned_over_ecmp").as_f64().unwrap() >= 1.0);
        // the --check entry point agrees
        check_planned_beats_ecmp(8, 16.0 * MB, 2.0, &params, &cfg);
    }
}
