//! Multi-tenant serving experiment (`nimble serve`): a seeded stream of
//! concurrent collective jobs on ONE shared fabric, comparing the
//! orchestrator (joint planning + weighted channels + cross-tenant
//! rebalancing) against independent per-job plans (`--no-joint`).
//!
//! The independent arm follows the `[replan]` config: disabled (the
//! shipped default) it flies static per-job plans — on a 1-job stream
//! that path is bit-identical to the PR-2
//! [`crate::coordinator::ReplanExecutor`]; enabled, each tenant runs
//! its own monitor → replan → reroute loop, treating the other tenants
//! as opaque background (§V-E semantics). The joint arm always
//! rebalances — it IS the orchestrator's execution-time loop.
//!
//! DESIGN.md §11 records the honest finding behind the headline
//! comparison: per-tenant *adaptive* replanning recovers most of the
//! aggregate-goodput gap on a max-min fabric (the fabric equalizes);
//! what the joint solve uniquely adds is weighted fairness, fewer
//! preemptions, and collision-free admission placement.

use crate::fabric::FabricParams;
use crate::metrics::Table;
use crate::orchestrator::{job_stream, MultiTenantExecutor, ServeRun, TenancyCfg};
use crate::planner::{PlannerCfg, ReplanCfg};
use crate::telemetry::{Recorder, TraceRecord};
use crate::topology::Topology;

/// Run one arm (joint or independent, per `tcfg.joint`).
pub fn run_arm(
    topo: &Topology,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    rcfg: &ReplanCfg,
    tcfg: &TenancyCfg,
) -> ServeRun {
    run_arm_traced(topo, params, pcfg, rcfg, tcfg, &Recorder::disabled(), "")
}

/// [`run_arm`] tracing as run `label`. Serve runs are fault-free from
/// the recovery clock's point of view (`t0_s = -1`); the `run` record
/// lands after the arm executes because the aggregate payload is only
/// known then ([`Trace::runs`] regroups by label, so order is
/// immaterial).
///
/// [`Trace::runs`]: crate::telemetry::report::Trace
pub fn run_arm_traced(
    topo: &Topology,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    rcfg: &ReplanCfg,
    tcfg: &TenancyCfg,
    rec: &Recorder,
    label: &str,
) -> ServeRun {
    let jobs = job_stream(topo, tcfg);
    rec.set_run(label);
    let run = MultiTenantExecutor::new(
        topo,
        params.clone(),
        pcfg.clone(),
        rcfg.clone(),
        tcfg.clone(),
    )
    .with_recorder(rec.clone())
    .execute(jobs);
    rec.emit(|| TraceRecord::Run {
        cadence_s: rcfg.cadence_s,
        t0_s: -1.0,
        payload_bytes: run.payload_bytes,
    });
    run
}

/// Per-tenant table plus the arm's summary lines.
pub fn render_arm(name: &str, run: &ServeRun) -> String {
    let has_chunk = run.tenants.iter().any(|t| t.p99_chunk_s.is_some());
    let mut headers = vec![
        "tenant", "kind", "w", "arrive (ms)", "admit (ms)", "finish (ms)",
        "goodput (GB/s)", "p99 lat (ms)",
    ];
    if has_chunk {
        headers.push("p99 chunk (µs)");
    }
    headers.push("reass");
    let mut t = Table::new(&headers);
    for tr in &run.tenants {
        let mut row = vec![
            format!("{}", tr.id),
            tr.kind.name().to_string(),
            format!("{}", tr.weight),
            format!("{:.2}", tr.arrival_s * 1e3),
            format!("{:.2}", tr.admit_s * 1e3),
            format!("{:.2}", tr.finish_s * 1e3),
            format!("{:.1}", tr.goodput_gbps),
            format!("{:.2}", tr.p99_lat_s * 1e3),
        ];
        if has_chunk {
            row.push(
                tr.p99_chunk_s
                    .map(|p| format!("{:.1}", p * 1e6))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        row.push(format!("{}", tr.peak_reassembly));
        t.row(&row);
    }
    format!(
        "[{name}] {} jobs, payload {:.2} GB\n{}\
         aggregate goodput {:.1} GB/s | weighted fairness {:.3} | makespan {:.2} ms | \
         replans {} | preemptions {} | peak reassembly {} | sim events {} ({:.2}M/s)\n",
        run.tenants.len(),
        run.payload_bytes / 1e9,
        t.render(),
        run.aggregate_goodput_gbps,
        run.weighted_fairness,
        run.makespan_s * 1e3,
        run.replans,
        run.preemptions,
        run.peak_reassembly,
        run.sim_events,
        run.events_per_sec() / 1e6,
    )
}

/// Render the full comparison (both arms) or one arm (`--no-joint`).
pub fn render(
    topo: &Topology,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    rcfg: &ReplanCfg,
    tcfg: &TenancyCfg,
) -> String {
    render_traced(topo, params, pcfg, rcfg, tcfg, &Recorder::disabled())
}

/// [`render`] with a telemetry sink (the `nimble serve --trace` path).
pub fn render_traced(
    topo: &Topology,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    rcfg: &ReplanCfg,
    tcfg: &TenancyCfg,
    rec: &Recorder,
) -> String {
    let mut out = render_stream(topo, params, tcfg);
    if !tcfg.joint {
        let indep = run_arm_traced(topo, params, pcfg, rcfg, tcfg, rec, "independent");
        out += &render_arm("independent per-job plans (--no-joint)", &indep);
        return out;
    }
    let (joint, indep) = run_comparison_traced(topo, params, pcfg, rcfg, tcfg, rec);
    out += &render_runs(rcfg, &joint, &indep);
    out
}

/// Execute both arms once: the joint orchestrator and the independent
/// per-job baseline (same stream, `joint` flag flipped).
pub fn run_comparison(
    topo: &Topology,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    rcfg: &ReplanCfg,
    tcfg: &TenancyCfg,
) -> (ServeRun, ServeRun) {
    run_comparison_traced(topo, params, pcfg, rcfg, tcfg, &Recorder::disabled())
}

/// [`run_comparison`] with a telemetry sink: the arms trace as runs
/// `joint` and `independent`.
pub fn run_comparison_traced(
    topo: &Topology,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    rcfg: &ReplanCfg,
    tcfg: &TenancyCfg,
    rec: &Recorder,
) -> (ServeRun, ServeRun) {
    let joint_cfg = TenancyCfg { joint: true, ..tcfg.clone() };
    let indep_cfg = TenancyCfg { joint: false, ..tcfg.clone() };
    let joint = run_arm_traced(topo, params, pcfg, rcfg, &joint_cfg, rec, "joint");
    let indep = run_arm_traced(topo, params, pcfg, rcfg, &indep_cfg, rec, "independent");
    (joint, indep)
}

/// Render both arms plus the headline delta from already-executed runs
/// (so `--check` does not have to simulate the arms twice).
pub fn render_runs(rcfg: &ReplanCfg, joint: &ServeRun, indep: &ServeRun) -> String {
    let mut out = String::new();
    out += &render_arm("joint orchestrator", joint);
    out.push('\n');
    out += &render_arm(
        if rcfg.enable {
            "independent per-job plans + per-tenant replan loop"
        } else {
            "independent per-job plans (static)"
        },
        indep,
    );
    out += &format!(
        "\njoint vs independent: goodput {:.1} vs {:.1} GB/s ({:+.1}%), \
         weighted fairness {:.3} vs {:.3} ({:+.1}%)\n",
        joint.aggregate_goodput_gbps,
        indep.aggregate_goodput_gbps,
        100.0 * (joint.aggregate_goodput_gbps / indep.aggregate_goodput_gbps.max(1e-12)
            - 1.0),
        joint.weighted_fairness,
        indep.weighted_fairness,
        100.0 * (joint.weighted_fairness / indep.weighted_fairness.max(1e-12) - 1.0),
    );
    out
}

/// Header + job table of the stream (shared by the report paths).
pub fn render_stream(topo: &Topology, params: &FabricParams, tcfg: &TenancyCfg) -> String {
    let jobs = job_stream(topo, tcfg);
    let mut out = format!(
        "nimble serve: {} seeded jobs (seed {}, mean gap {:.2} ms, max {} live), \
         {} backend\n\n",
        tcfg.jobs,
        tcfg.seed,
        tcfg.mean_gap_ms,
        tcfg.max_live,
        match params.backend {
            crate::fabric::BackendKind::Fluid => "fluid",
            crate::fabric::BackendKind::Packet => "packet",
        },
    );
    let mut t = Table::new(&["job", "kind", "weight", "arrival (ms)", "payload (MB)"]);
    for j in &jobs {
        t.row(&[
            format!("{}", j.id),
            j.kind.name().to_string(),
            format!("{}", j.weight),
            format!("{:.2}", j.arrival_s * 1e3),
            format!("{:.1}", j.payload(topo) / (1024.0 * 1024.0)),
        ]);
    }
    out += &t.render();
    out.push('\n');
    out
}

/// `--check`: the acceptance gates CI smokes on.
///
/// 1. joint beats independent per-job plans on aggregate goodput AND
///    weighted fairness (both arms under `tcfg`/`rcfg` as given);
/// 2. the joint run is deterministic (two runs, byte-identical
///    makespan, link bytes and per-tenant goodputs);
/// 3. a 1-job `--no-joint` stream reproduces the PR-2
///    [`crate::coordinator::ReplanExecutor`] result byte-for-byte.
pub fn check(
    topo: &Topology,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    rcfg: &ReplanCfg,
    tcfg: &TenancyCfg,
) -> Result<(), String> {
    let (joint, indep) = run_comparison(topo, params, pcfg, rcfg, tcfg);
    check_runs(topo, params, pcfg, rcfg, tcfg, &joint, &indep)
}

/// The `--check` gates against already-executed arms (the CLI reuses
/// the runs it rendered; only the determinism re-run and the 1-job
/// anchor execute fresh here).
#[allow(clippy::too_many_arguments)]
pub fn check_runs(
    topo: &Topology,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    rcfg: &ReplanCfg,
    tcfg: &TenancyCfg,
    joint: &ServeRun,
    indep: &ServeRun,
) -> Result<(), String> {
    let joint_cfg = TenancyCfg { joint: true, ..tcfg.clone() };
    if joint.aggregate_goodput_gbps <= indep.aggregate_goodput_gbps {
        return Err(format!(
            "joint aggregate goodput {:.2} GB/s does not beat independent {:.2} GB/s",
            joint.aggregate_goodput_gbps, indep.aggregate_goodput_gbps
        ));
    }
    if joint.weighted_fairness <= indep.weighted_fairness {
        return Err(format!(
            "joint weighted fairness {:.4} does not beat independent {:.4}",
            joint.weighted_fairness, indep.weighted_fairness
        ));
    }
    // determinism: byte-identical re-run
    let again = run_arm(topo, params, pcfg, rcfg, &joint_cfg);
    if joint.makespan_s.to_bits() != again.makespan_s.to_bits() {
        return Err("joint serve run is not deterministic (makespan)".into());
    }
    for (a, b) in joint.sim.link_bytes.iter().zip(&again.sim.link_bytes) {
        if a.to_bits() != b.to_bits() {
            return Err("joint serve run is not deterministic (link bytes)".into());
        }
    }
    for (a, b) in joint.tenants.iter().zip(&again.tenants) {
        if a.goodput_gbps.to_bits() != b.goodput_gbps.to_bits() {
            return Err(format!("tenant {} goodput not deterministic", a.id));
        }
    }
    // 1-job --no-joint == ReplanExecutor, byte for byte
    let single = TenancyCfg { jobs: 1, joint: false, ..tcfg.clone() };
    let jobs = job_stream(topo, &single);
    let run =
        MultiTenantExecutor::new(topo, params.clone(), pcfg.clone(), rcfg.clone(), single)
            .execute(jobs.clone());
    let demands = jobs[0].demands(topo);
    let incumbent = crate::planner::Planner::new(topo, pcfg.clone()).plan(&demands);
    let reference = crate::coordinator::ReplanExecutor::new(
        topo,
        params.clone(),
        pcfg.clone(),
        rcfg.clone(),
    )
    .execute(&incumbent, &demands);
    if run.makespan_s.to_bits() != reference.report.makespan_s.to_bits() {
        return Err(format!(
            "1-job --no-joint diverged from ReplanExecutor: {} vs {}",
            run.makespan_s, reference.report.makespan_s
        ));
    }
    for (a, b) in run.sim.link_bytes.iter().zip(&reference.sim.link_bytes) {
        if a.to_bits() != b.to_bits() {
            return Err("1-job --no-joint link bytes diverged".into());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance criteria end to end on the default config: joint
    /// beats independent on both metrics, deterministically, and the
    /// 1-job anchor holds.
    #[test]
    fn serve_check_passes_on_defaults() {
        let topo = Topology::paper();
        check(
            &topo,
            &FabricParams::default(),
            &PlannerCfg::default(),
            &ReplanCfg::default(),
            &TenancyCfg::default(),
        )
        .unwrap();
    }

    /// Render paths produce non-empty reports for both modes.
    #[test]
    fn render_smoke() {
        let topo = Topology::paper();
        let tcfg = TenancyCfg { jobs: 2, ..TenancyCfg::default() };
        let s = render(
            &topo,
            &FabricParams::default(),
            &PlannerCfg::default(),
            &ReplanCfg::default(),
            &tcfg,
        );
        assert!(s.contains("joint orchestrator"));
        assert!(s.contains("aggregate goodput"));
        let no_joint = TenancyCfg { joint: false, ..tcfg };
        let s = render(
            &topo,
            &FabricParams::default(),
            &PlannerCfg::default(),
            &ReplanCfg::default(),
            &no_joint,
        );
        assert!(s.contains("--no-joint"));
    }
}
