//! Table I — NIMBLE orchestration-algorithm time vs communication
//! time, intra-node and inter-node, on a 1-D stencil. Paper: the
//! planner costs 0.032–0.048 ms while communication takes 0.2–6.5 ms.

use super::MB;
use crate::baselines::run_round;
use crate::coordinator::NimbleRouter;
use crate::fabric::FabricParams;
use crate::metrics::Table;
use crate::planner::{Demand, Planner, PlannerCfg};
use crate::topology::Topology;
use crate::workloads::stencil::stencil_1d;

pub const SIZES_MB: [f64; 5] = [16.0, 32.0, 64.0, 128.0, 256.0];

#[derive(Clone, Copy, Debug)]
pub struct Table1Row {
    pub size_mb: f64,
    pub intra_algo_s: f64,
    pub intra_comm_s: f64,
    pub inter_algo_s: f64,
    pub inter_comm_s: f64,
}

/// Intra rows plan/execute the node-0 sub-stencil; inter rows the full
/// two-node stencil (whose 3↔4 edge crosses the rails).
pub fn sweep(topo: &Topology, params: &FabricParams, reps: usize) -> Vec<Table1Row> {
    let full = |bytes: f64| stencil_1d(topo, bytes);
    let intra_only = |bytes: f64| {
        full(bytes)
            .into_iter()
            .filter(|d| topo.same_node(d.src, d.dst) && topo.node_of(d.src) == 0)
            .collect::<Vec<Demand>>()
    };
    SIZES_MB
        .iter()
        .map(|&mb| {
            let bytes = mb * MB;
            let (ia, ic) = measure(topo, params, &intra_only(bytes), reps);
            let (ea, ec) = measure(topo, params, &full(bytes), reps);
            Table1Row {
                size_mb: mb,
                intra_algo_s: ia,
                intra_comm_s: ic,
                inter_algo_s: ea,
                inter_comm_s: ec,
            }
        })
        .collect()
}

/// (median plan time, comm makespan) over `reps` planner runs.
fn measure(
    topo: &Topology,
    params: &FabricParams,
    demands: &[Demand],
    reps: usize,
) -> (f64, f64) {
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let mut planner = Planner::new(topo, PlannerCfg::default());
            planner.plan(demands).plan_time_s
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let algo = times[times.len() / 2];
    let mut router = NimbleRouter::default_for(topo);
    let comm = run_round(topo, params, &mut router, demands).makespan_s;
    (algo, comm)
}

pub fn render(topo: &Topology, params: &FabricParams, reps: usize) -> String {
    let rows = sweep(topo, params, reps);
    let mut t = Table::new(&[
        "Size (MB)",
        "Intra Algo (ms)",
        "Intra Comm (ms)",
        "Inter Algo (ms)",
        "Inter Comm (ms)",
    ]);
    for r in &rows {
        t.row(&[
            format!("{}", r.size_mb),
            format!("{:.4}", r.intra_algo_s * 1e3),
            format!("{:.4}", r.intra_comm_s * 1e3),
            format!("{:.4}", r.inter_algo_s * 1e3),
            format!("{:.4}", r.inter_comm_s * 1e3),
        ]);
    }
    format!(
        "Table I planner overhead vs communication (paper: algo 0.032–0.048 ms ≪ comm 0.2–6.5 ms)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_time_negligible_vs_comm() {
        let t = Topology::paper();
        let p = FabricParams::default();
        // the paper's ≫10× margin holds in release; debug builds slow
        // the planner ~10× so only require it not to dominate there
        let factor = if cfg!(debug_assertions) { 1.0 } else { 2.0 };
        for r in sweep(&t, &p, 3) {
            assert!(
                r.intra_algo_s < r.intra_comm_s / factor,
                "intra algo {} vs comm {} at {} MB",
                r.intra_algo_s,
                r.intra_comm_s,
                r.size_mb
            );
            assert!(
                r.inter_algo_s < r.inter_comm_s / factor,
                "inter algo {} vs comm {} at {} MB",
                r.inter_algo_s,
                r.inter_comm_s,
                r.size_mb
            );
        }
    }

    #[test]
    fn comm_time_scales_with_size() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = sweep(&t, &p, 1);
        assert!(rows.last().unwrap().inter_comm_s > rows[0].inter_comm_s * 4.0);
    }
}
