//! Fault injection & recovery experiment (`nimble faults`) —
//! DESIGN.md §13.
//!
//! Flies the named fault scenarios ([`Scenario`]) against three arms on
//! flat and fat-tree topologies:
//!
//! * **static** — the clean planned routing, frozen (no recovery lever);
//! * **replan** — the same plan with the monitor → replan → reroute
//!   loop enabled: replanning *is* the recovery mechanism (dead links
//!   are masked from candidate enumeration, degraded ones re-priced);
//! * **ecmp** — the hash-striping adversary, equally frozen (switches
//!   re-hash around hard failures in real fabrics, but are blind to
//!   degradation — here it shows what capacity-blind striping loses).
//!
//! Two recovery metrics per arm, read off the per-epoch goodput series:
//!
//! * **time-to-recover** — epochs after the first fault until goodput
//!   regains ≥ [`RECOVERY_FRAC`] of the pre-fault steady state;
//! * **goodput retention** — the arm's overall goodput over the clean
//!   planned static goodput `G0` of the same topology.
//!
//! A fourth arm replays a fault scenario under the multi-tenant
//! orchestrator (`nimble serve`): the joint rebalancing loop absorbs
//! the fault across tenants ([`serve_arm`]).
//!
//! `--check` additionally enforces (a) replan retains at least as much
//! goodput as both static arms on every scenario, (b) empty schedules
//! are bit-identical to fault-free runs on both backends, and (c) the
//! degrade scenario's goodput agrees across the fluid and packet
//! backends within the DESIGN.md §10 contract ([`GOODPUT_TOL`]).

use std::collections::BTreeMap;

use super::MB;
use crate::baselines::{EcmpHash, Router};
use crate::coordinator::replan::{EpochStat, ReplanExecutor};
use crate::exp::xcheck::GOODPUT_TOL;
use crate::fabric::faults::{scenario_schedule, FaultSchedule, Scenario, ScenarioParams};
use crate::fabric::{BackendKind, FabricParams};
use crate::metrics::Table;
use crate::orchestrator::{job_stream, MultiTenantExecutor, TenancyCfg};
use crate::planner::{Assignment, Demand, Plan, Planner, PlannerCfg, ReplanCfg};
use crate::telemetry::{Recorder, TraceRecord};
use crate::topology::{GpuId, Topology};
use crate::workloads::skew::hotspot_alltoallv;

/// Replan-epoch cadence every arm is sampled at (also the recovery
/// clock: time-to-recover is reported in these epochs).
pub const CADENCE_S: f64 = 2.0e-4;

/// Recovered = goodput back to this fraction of the pre-fault steady
/// state.
pub const RECOVERY_FRAC: f64 = 0.9;

/// Per-rank payloads sized so the hottest link still carries planned
/// bytes well past the default fault time (t0 = 1 ms): the fault bites
/// mid-flight, and the clean makespan (~2 ms flat) leaves several
/// post-fault epochs to measure recovery in. A frozen plan whose hot
/// link dies must then wait out the flap; the recovering arm reroutes
/// and finishes before the link even restores.
const FLAT_PER_RANK: f64 = 96.0 * MB;
const FAT_TREE_PER_RANK: f64 = 24.0 * MB;
const FAT_TREE_NODES: usize = 4;

/// Epochs after the first fault until goodput regains
/// [`RECOVERY_FRAC`] of the pre-fault steady state (the mean goodput of
/// the epochs up to and including the fault boundary). `None` when the
/// run ends without recovering, or when no epoch precedes the fault.
pub fn recovery_epochs(epochs: &[EpochStat], t0_s: f64, cadence_s: f64) -> Option<usize> {
    // the fault takes effect at the first epoch boundary at/after t0;
    // half-cadence slack absorbs the accumulated boundary float error
    let bidx = epochs.iter().position(|e| e.t_s >= t0_s - 0.5 * cadence_s)?;
    let pre = &epochs[..=bidx];
    let steady = pre.iter().map(|e| e.goodput_gbps).sum::<f64>() / pre.len() as f64;
    if steady <= 0.0 {
        return None;
    }
    epochs[bidx + 1..]
        .iter()
        .position(|e| e.goodput_gbps >= RECOVERY_FRAC * steady)
        .map(|k| k + 1)
}

/// The ECMP adversary's routing materialized as a [`Plan`], so the
/// frozen-arm executor can fly it through the identical epoch-driven
/// fault machinery as the planned arms.
pub fn ecmp_plan(topo: &Topology, demands: &[Demand]) -> Plan {
    let mut ecmp = EcmpHash::new();
    let mut assignments: BTreeMap<(GpuId, GpuId), Assignment> = BTreeMap::new();
    let mut link_load = vec![0.0f64; topo.links.len()];
    for d in demands {
        if d.bytes <= 0.0 {
            continue;
        }
        let parts = ecmp.route(topo, std::slice::from_ref(d));
        for (p, b) in &parts {
            for &h in &p.hops {
                link_load[h] += *b;
            }
        }
        assignments.insert((d.src, d.dst), Assignment { parts });
    }
    Plan { assignments, link_load, plan_time_s: 0.0 }
}

/// One (topology, scenario, arm) outcome.
#[derive(Clone, Debug)]
pub struct FaultRow {
    pub topo: &'static str,
    pub scenario: Scenario,
    pub arm: &'static str,
    pub goodput_gbps: f64,
    /// goodput / clean planned static goodput of the same topology.
    pub retention: f64,
    pub ttr_epochs: Option<usize>,
    pub replans: usize,
    pub preemptions: usize,
}

/// Clean planned static goodput of one topology (the retention
/// denominator `G0`).
#[derive(Clone, Debug)]
pub struct CleanRow {
    pub topo: &'static str,
    pub payload_mb: f64,
    pub goodput_gbps: f64,
}

/// The serve arm: the same seeded job stream, clean vs faulted, under
/// the joint orchestrator.
#[derive(Clone, Debug)]
pub struct ServeFaultRow {
    pub scenario: Scenario,
    pub clean_gbps: f64,
    pub faulted_gbps: f64,
    pub retention: f64,
    pub replans: usize,
    pub preemptions: usize,
    /// Every tenant finished with positive goodput under the faults.
    pub all_tenants_finished: bool,
}

/// Full `nimble faults` outcome.
#[derive(Clone, Debug)]
pub struct FaultsReport {
    pub scenarios: Vec<Scenario>,
    pub cadence_s: f64,
    pub t0_s: f64,
    pub clean: Vec<CleanRow>,
    pub rows: Vec<FaultRow>,
    pub serve: Option<ServeFaultRow>,
}

fn replan_cfg(enable: bool) -> ReplanCfg {
    ReplanCfg { enable, cadence_s: CADENCE_S, margin: 0.1, ..ReplanCfg::default() }
}

struct ArmOut {
    goodput_gbps: f64,
    ttr_epochs: Option<usize>,
    replans: usize,
    preemptions: usize,
}

/// Fly one arm: `incumbent` under `sched`, replanning iff `enable`.
/// The run traces under `label` (a no-op on a disabled recorder).
#[allow(clippy::too_many_arguments)]
fn fly_arm(
    topo: &Topology,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    enable: bool,
    sched: &FaultSchedule,
    incumbent: &Plan,
    demands: &[Demand],
    t0_s: f64,
    rec: &Recorder,
    label: &str,
) -> ArmOut {
    let payload: f64 = demands.iter().map(|d| d.bytes).sum();
    rec.set_run(label);
    rec.emit(|| TraceRecord::Run { cadence_s: CADENCE_S, t0_s, payload_bytes: payload });
    let run = ReplanExecutor::new(topo, params.clone(), pcfg.clone(), replan_cfg(enable))
        .with_faults(sched.clone())
        .with_recorder(rec.clone())
        .execute(incumbent, demands);
    ArmOut {
        goodput_gbps: payload / run.report.makespan_s.max(1e-12) / 1e9,
        ttr_epochs: recovery_epochs(&run.epochs, t0_s, CADENCE_S),
        replans: run.replans,
        preemptions: run.preemptions,
    }
}

/// All arms of every requested scenario on one topology. The fault
/// schedules chase the hottest link of the *clean planned* load
/// profile, so the faults hit where the static plan hurts most.
#[allow(clippy::too_many_arguments)]
pub fn scenario_rows(
    label: &'static str,
    topo: &Topology,
    per_rank_bytes: f64,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    fparams: &ScenarioParams,
    scenarios: &[Scenario],
    with_replan: bool,
) -> (CleanRow, Vec<FaultRow>) {
    scenario_rows_traced(
        label,
        topo,
        per_rank_bytes,
        params,
        pcfg,
        fparams,
        scenarios,
        with_replan,
        &Recorder::disabled(),
    )
}

/// [`scenario_rows`] with a telemetry sink: the clean run traces as
/// `{topo}/clean`, each arm as `{topo}/{scenario}/{arm}`, and every
/// [`FaultRow`] is mirrored as a `fault_row` record whose `run` label
/// points at the arm's deep trace (so `nimble report --check` can
/// recompute retention and time-to-recover from the epoch series).
#[allow(clippy::too_many_arguments)]
pub fn scenario_rows_traced(
    label: &'static str,
    topo: &Topology,
    per_rank_bytes: f64,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    fparams: &ScenarioParams,
    scenarios: &[Scenario],
    with_replan: bool,
    rec: &Recorder,
) -> (CleanRow, Vec<FaultRow>) {
    let hot = topo.gpu(1, 0);
    let demands = hotspot_alltoallv(topo, per_rank_bytes, 0.7, hot);
    let payload: f64 = demands.iter().map(|d| d.bytes).sum();
    let plan = Planner::new(topo, pcfg.clone()).plan(&demands);

    // clean planned static goodput: the retention denominator
    rec.set_run(&format!("{label}/clean"));
    rec.emit(|| TraceRecord::Run {
        cadence_s: CADENCE_S,
        t0_s: -1.0,
        payload_bytes: payload,
    });
    let clean_run =
        ReplanExecutor::new(topo, params.clone(), pcfg.clone(), replan_cfg(false))
            .with_recorder(rec.clone())
            .execute(&plan, &demands);
    let g0 = payload / clean_run.report.makespan_s.max(1e-12) / 1e9;
    let clean = CleanRow { topo: label, payload_mb: payload / MB, goodput_gbps: g0 };

    let adversary = ecmp_plan(topo, &demands);
    let mut rows = Vec::new();
    for &sc in scenarios {
        let sched = scenario_schedule(topo, sc, fparams, Some(&plan.link_load));
        let mut push = |arm: &'static str, out: ArmOut| {
            rec.emit(|| TraceRecord::FaultRow {
                topo: label.to_string(),
                scenario: sc.label().to_string(),
                arm: arm.to_string(),
                goodput_gbps: out.goodput_gbps,
                clean_gbps: g0,
                retention: out.goodput_gbps / g0.max(1e-12),
                ttr_epochs: out.ttr_epochs.map_or(-1.0, |n| n as f64),
                ttr_ms: out.ttr_epochs.map_or(-1.0, |n| n as f64 * CADENCE_S * 1e3),
                replans: out.replans as u64,
                preemptions: out.preemptions as u64,
            });
            rows.push(FaultRow {
                topo: label,
                scenario: sc,
                arm,
                goodput_gbps: out.goodput_gbps,
                retention: out.goodput_gbps / g0.max(1e-12),
                ttr_epochs: out.ttr_epochs,
                replans: out.replans,
                preemptions: out.preemptions,
            });
        };
        let arm_label =
            |arm: &str| format!("{label}/{}/{arm}", sc.label());
        push(
            "static",
            fly_arm(
                topo,
                params,
                pcfg,
                false,
                &sched,
                &plan,
                &demands,
                fparams.t0_s,
                rec,
                &arm_label("static"),
            ),
        );
        if with_replan {
            push(
                "replan",
                fly_arm(
                    topo,
                    params,
                    pcfg,
                    true,
                    &sched,
                    &plan,
                    &demands,
                    fparams.t0_s,
                    rec,
                    &arm_label("replan"),
                ),
            );
        }
        push(
            "ecmp",
            fly_arm(
                topo,
                params,
                pcfg,
                false,
                &sched,
                &adversary,
                &demands,
                fparams.t0_s,
                rec,
                &arm_label("ecmp"),
            ),
        );
    }
    (clean, rows)
}

/// The orchestrator arm: the identical seeded job stream flown clean
/// and under `scenario` (seeded fallback link pick — no single plan's
/// load profile describes a whole stream); the joint loop's epoch
/// rebalancing is the recovery path.
pub fn serve_arm(
    params: &FabricParams,
    pcfg: &PlannerCfg,
    fparams: &ScenarioParams,
    scenario: Scenario,
) -> ServeFaultRow {
    serve_arm_traced(params, pcfg, fparams, scenario, &Recorder::disabled())
}

/// [`serve_arm`] with a telemetry sink: the clean pass traces as
/// `serve/clean`, the faulted pass as `serve/{scenario}`. Both carry
/// `t0_s = -1` so the epoch-series recovery gates of `nimble report
/// --check` (which assume a single-job goodput plateau) skip them —
/// the orchestrator's staggered admissions have no pre-fault steady
/// state to recover *to*; retention is still cross-checked via the
/// mirrored `fault_row` record.
pub fn serve_arm_traced(
    params: &FabricParams,
    pcfg: &PlannerCfg,
    fparams: &ScenarioParams,
    scenario: Scenario,
    rec: &Recorder,
) -> ServeFaultRow {
    let topo = Topology::paper();
    let tcfg = TenancyCfg { jobs: 6, ..TenancyCfg::default() };
    let rcfg = replan_cfg(true);
    rec.set_run("serve/clean");
    let clean = MultiTenantExecutor::new(
        &topo,
        params.clone(),
        pcfg.clone(),
        rcfg.clone(),
        tcfg.clone(),
    )
    .with_recorder(rec.clone())
    .execute(job_stream(&topo, &tcfg));
    rec.emit(|| TraceRecord::Run {
        cadence_s: rcfg.cadence_s,
        t0_s: -1.0,
        payload_bytes: clean.payload_bytes,
    });
    let sched = scenario_schedule(&topo, scenario, fparams, None);
    rec.set_run(&format!("serve/{}", scenario.label()));
    let faulted =
        MultiTenantExecutor::new(&topo, params.clone(), pcfg.clone(), rcfg, tcfg.clone())
            .with_faults(sched)
            .with_recorder(rec.clone())
            .execute(job_stream(&topo, &tcfg));
    rec.emit(|| TraceRecord::Run {
        cadence_s: CADENCE_S,
        t0_s: -1.0,
        payload_bytes: faulted.payload_bytes,
    });
    let retention =
        faulted.aggregate_goodput_gbps / clean.aggregate_goodput_gbps.max(1e-12);
    rec.emit(|| TraceRecord::FaultRow {
        topo: "flat".to_string(),
        scenario: scenario.label().to_string(),
        arm: "serve".to_string(),
        goodput_gbps: faulted.aggregate_goodput_gbps,
        clean_gbps: clean.aggregate_goodput_gbps,
        retention,
        ttr_epochs: -1.0,
        ttr_ms: -1.0,
        replans: faulted.replans as u64,
        preemptions: faulted.preemptions as u64,
    });
    ServeFaultRow {
        scenario,
        clean_gbps: clean.aggregate_goodput_gbps,
        faulted_gbps: faulted.aggregate_goodput_gbps,
        retention,
        replans: faulted.replans,
        preemptions: faulted.preemptions,
        all_tenants_finished: faulted.tenants.iter().all(|t| t.goodput_gbps > 0.0),
    }
}

/// Run the full experiment: every requested scenario × {flat,
/// fat-tree} × {static, replan, ecmp}, plus the serve arm (on the
/// first scenario). `with_replan == false` (`--no-replan`) drops the
/// recovery arms and reports what frozen plans lose on their own.
pub fn run(
    params: &FabricParams,
    pcfg: &PlannerCfg,
    fparams: &ScenarioParams,
    scenarios: &[Scenario],
    with_replan: bool,
) -> FaultsReport {
    run_traced(params, pcfg, fparams, scenarios, with_replan, &Recorder::disabled())
}

/// [`run`] with a telemetry sink (the `nimble faults --trace` path).
/// The `--check` cross-backend and empty-schedule probes stay
/// untraced — they are validators, not headline runs.
pub fn run_traced(
    params: &FabricParams,
    pcfg: &PlannerCfg,
    fparams: &ScenarioParams,
    scenarios: &[Scenario],
    with_replan: bool,
    rec: &Recorder,
) -> FaultsReport {
    let flat = Topology::paper();
    let fat = Topology::fat_tree(FAT_TREE_NODES, 2.0);
    let mut clean = Vec::new();
    let mut rows = Vec::new();
    for (label, topo, per_rank) in [
        ("flat", &flat, FLAT_PER_RANK),
        ("fat-tree", &fat, FAT_TREE_PER_RANK),
    ] {
        let (c, r) = scenario_rows_traced(
            label, topo, per_rank, params, pcfg, fparams, scenarios, with_replan, rec,
        );
        clean.push(c);
        rows.extend(r);
    }
    let serve = if with_replan {
        scenarios.first().map(|&sc| serve_arm_traced(params, pcfg, fparams, sc, rec))
    } else {
        None
    };
    FaultsReport {
        scenarios: scenarios.to_vec(),
        cadence_s: CADENCE_S,
        t0_s: fparams.t0_s,
        clean,
        rows,
        serve,
    }
}

/// The degrade-scenario cross-backend contract (`--check` and the
/// `degrade_cross_backend_within_contract` test): one saturated heavy
/// pair, the planner's hottest rail degraded mid-flight; both the
/// frozen and the recovering arm must land within [`GOODPUT_TOL`] of
/// each other across the fluid and packet backends.
#[derive(Clone, Debug)]
pub struct DegradeXcheck {
    pub fluid_static_gbps: f64,
    pub packet_static_gbps: f64,
    pub fluid_replan_gbps: f64,
    pub packet_replan_gbps: f64,
}

impl DegradeXcheck {
    pub fn static_ratio(&self) -> f64 {
        self.packet_static_gbps / self.fluid_static_gbps.max(1e-12)
    }
    pub fn replan_ratio(&self) -> f64 {
        self.packet_replan_gbps / self.fluid_replan_gbps.max(1e-12)
    }
}

pub fn degrade_xcheck(
    params: &FabricParams,
    pcfg: &PlannerCfg,
    fparams: &ScenarioParams,
) -> DegradeXcheck {
    let topo = Topology::paper();
    let payload = 512.0 * MB;
    let demands = vec![Demand::new(0, 4, payload)];
    let plan = Planner::new(&topo, pcfg.clone()).plan(&demands);
    let sched = scenario_schedule(&topo, Scenario::Degrade, fparams, Some(&plan.link_load));
    let mut fly = |backend: BackendKind, enable: bool| {
        let p = FabricParams { backend, ..params.clone() };
        let run = ReplanExecutor::new(&topo, p, pcfg.clone(), replan_cfg(enable))
            .with_faults(sched.clone())
            .execute(&plan, &demands);
        payload / run.report.makespan_s.max(1e-12) / 1e9
    };
    DegradeXcheck {
        fluid_static_gbps: fly(BackendKind::Fluid, false),
        packet_static_gbps: fly(BackendKind::Packet, false),
        fluid_replan_gbps: fly(BackendKind::Fluid, true),
        packet_replan_gbps: fly(BackendKind::Packet, true),
    }
}

/// Both backends, a faulted and a fault-free-with-empty-schedule run:
/// attaching an empty [`FaultSchedule`] must be bitwise inert.
fn empty_schedule_identity(params: &FabricParams, pcfg: &PlannerCfg) -> Result<(), String> {
    let topo = Topology::paper();
    let demands = vec![Demand::new(0, 4, 64.0 * MB), Demand::new(2, 5, 32.0 * MB)];
    let plan = Planner::new(&topo, pcfg.clone()).plan(&demands);
    for backend in [BackendKind::Fluid, BackendKind::Packet] {
        let p = FabricParams { backend, ..params.clone() };
        let bare = ReplanExecutor::new(&topo, p.clone(), pcfg.clone(), replan_cfg(false))
            .execute(&plan, &demands);
        let empty = ReplanExecutor::new(&topo, p, pcfg.clone(), replan_cfg(false))
            .with_faults(FaultSchedule::default())
            .execute(&plan, &demands);
        if bare.report.makespan_s.to_bits() != empty.report.makespan_s.to_bits()
            || bare.sim.link_bytes != empty.sim.link_bytes
        {
            return Err(format!(
                "empty FaultSchedule is not inert on the {backend:?} backend: \
                 {} vs {} s",
                bare.report.makespan_s, empty.report.makespan_s
            ));
        }
    }
    Ok(())
}

/// The acceptance gate `nimble faults --check` enforces (and CI runs):
///
/// 1. on every (topology, scenario) the replanned arm retains at least
///    as much goodput as the frozen plan *and* the ECMP adversary
///    (0.1% slack — scenarios with no routing escape, e.g. a straggler
///    throttling its own injection, legitimately tie);
/// 2. a dead or degraded link actually triggers replans on the flat
///    topology (the loop is the recovery mechanism, not a bystander);
/// 3. the serve arm finishes every tenant with sane retention;
/// 4. an empty schedule is bitwise inert on both backends;
/// 5. the degrade scenario agrees across fluid and packet backends
///    within ±[`GOODPUT_TOL`] on both arms.
pub fn check(
    rep: &FaultsReport,
    params: &FabricParams,
    pcfg: &PlannerCfg,
    fparams: &ScenarioParams,
) -> Result<(), String> {
    let arm = |topo: &str, sc: Scenario, arm: &str| {
        rep.rows
            .iter()
            .find(|r| r.topo == topo && r.scenario == sc && r.arm == arm)
    };
    for c in &rep.clean {
        for &sc in &rep.scenarios {
            let Some(re) = arm(c.topo, sc, "replan") else {
                return Err("--check requires the replan arm (drop --no-replan)".into());
            };
            for frozen in ["static", "ecmp"] {
                let fr = arm(c.topo, sc, frozen).expect("frozen arm present");
                if re.retention < fr.retention * 0.999 {
                    return Err(format!(
                        "replan retained less than {frozen} on {} {}: {:.3} vs {:.3}",
                        c.topo,
                        sc.label(),
                        re.retention,
                        fr.retention
                    ));
                }
            }
            let has_link_fault =
                matches!(sc, Scenario::Flap | Scenario::Degrade | Scenario::Mixed);
            if c.topo == "flat" && has_link_fault && re.replans == 0 {
                return Err(format!(
                    "{} on flat never triggered a replan — recovery path dead",
                    sc.label()
                ));
            }
        }
    }
    if let Some(s) = &rep.serve {
        if !s.all_tenants_finished {
            return Err(format!(
                "serve arm ({}) left a tenant unfinished under faults",
                s.scenario.label()
            ));
        }
        // quantized admission + plan churn can jitter a few percent
        // either way, but faults must not *help* materially
        if !(s.retention > 0.0 && s.retention <= 1.10) {
            return Err(format!(
                "serve retention out of range on {}: {:.3}",
                s.scenario.label(),
                s.retention
            ));
        }
    }
    empty_schedule_identity(params, pcfg)?;
    let x = degrade_xcheck(params, pcfg, fparams);
    for (arm, ratio) in
        [("static", x.static_ratio()), ("replan", x.replan_ratio())]
    {
        if (ratio - 1.0).abs() > GOODPUT_TOL {
            return Err(format!(
                "degrade {arm} arm disagrees across backends: ratio {:.3} \
                 (tolerance ±{:.0}%)",
                ratio,
                GOODPUT_TOL * 100.0
            ));
        }
    }
    Ok(())
}

pub fn render(rep: &FaultsReport) -> String {
    let mut t = Table::new(&[
        "topo",
        "scenario",
        "arm",
        "goodput (GB/s)",
        "retention",
        "ttr (epochs)",
        "ttr (ms)",
        "replans",
        "preempt",
    ]);
    for r in &rep.rows {
        let (ttr, ttr_ms) = match r.ttr_epochs {
            Some(k) => (format!("{k}"), format!("{:.2}", k as f64 * rep.cadence_s * 1e3)),
            None => ("-".into(), "-".into()),
        };
        t.row(&[
            r.topo.to_string(),
            r.scenario.label().to_string(),
            r.arm.to_string(),
            format!("{:.1}", r.goodput_gbps),
            format!("{:.3}", r.retention),
            ttr,
            ttr_ms,
            format!("{}", r.replans),
            format!("{}", r.preemptions),
        ]);
    }
    let clean: Vec<String> = rep
        .clean
        .iter()
        .map(|c| {
            format!(
                "{} {:.1} GB/s ({:.0} MB aggregate)",
                c.topo, c.goodput_gbps, c.payload_mb
            )
        })
        .collect();
    let serve = match &rep.serve {
        Some(s) => format!(
            "serve ({}): clean {:.1} -> faulted {:.1} GB/s aggregate \
             (retention {:.3}, {} replans, {} preemptions)\n",
            s.scenario.label(),
            s.clean_gbps,
            s.faulted_gbps,
            s.retention,
            s.replans,
            s.preemptions
        ),
        None => String::new(),
    };
    format!(
        "Fault injection & recovery (epoch {:.2} ms, first fault at {:.2} ms; \
         recovery = goodput back to {:.0}% of pre-fault steady state)\n\
         clean planned goodput: {}\n{}{}",
        rep.cadence_s * 1e3,
        rep.t0_s * 1e3,
        RECOVERY_FRAC * 100.0,
        clean.join(", "),
        t.render(),
        serve
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ep(t_s: f64, goodput_gbps: f64) -> EpochStat {
        EpochStat { t_s, deviation: 0.0, replanned: false, preempted: 0, goodput_gbps }
    }

    /// The recovery clock reads the goodput series exactly: steady
    /// state from the pre-fault epochs, recovery at the first
    /// post-fault epoch back above the threshold.
    #[test]
    fn recovery_epochs_reads_the_series() {
        let c = 2.0e-4;
        let epochs: Vec<EpochStat> = vec![
            ep(1.0 * c, 100.0),
            ep(2.0 * c, 100.0),
            ep(3.0 * c, 100.0),
            ep(4.0 * c, 100.0),
            ep(5.0 * c, 100.0), // fault boundary (t0 = 1 ms = 5 epochs)
            ep(6.0 * c, 10.0),
            ep(7.0 * c, 40.0),
            ep(8.0 * c, 95.0), // ≥ 90% of steady ⇒ recovered here
            ep(9.0 * c, 100.0),
        ];
        assert_eq!(recovery_epochs(&epochs, 1.0e-3, c), Some(3));
        // never recovers
        let flat: Vec<EpochStat> =
            (1..=8).map(|k| ep(k as f64 * c, if k <= 5 { 100.0 } else { 20.0 })).collect();
        assert_eq!(recovery_epochs(&flat, 1.0e-3, c), None);
        // no epoch at/after the fault time
        assert_eq!(recovery_epochs(&epochs[..2], 1.0e-3, c), None);
    }

    /// A flap on the flat testbed: the replanned arm must retain at
    /// least as much goodput as both frozen arms, and must actually
    /// fire (the ISSUE's replan-as-recovery claim, end to end through
    /// the experiment driver).
    #[test]
    fn flap_flat_replan_beats_frozen_arms() {
        let params = FabricParams::default();
        let pcfg = PlannerCfg::default();
        let fparams = ScenarioParams::default();
        let (clean, rows) = scenario_rows(
            "flat",
            &Topology::paper(),
            FLAT_PER_RANK,
            &params,
            &pcfg,
            &fparams,
            &[Scenario::Flap],
            true,
        );
        assert!(clean.goodput_gbps > 0.0);
        assert_eq!(rows.len(), 3);
        let get = |arm: &str| rows.iter().find(|r| r.arm == arm).unwrap();
        let (st, re, ec) = (get("static"), get("replan"), get("ecmp"));
        assert!(re.replans >= 1, "flap did not trigger a replan");
        assert!(
            re.retention >= st.retention,
            "replan retained less than static: {:.3} vs {:.3}",
            re.retention,
            st.retention
        );
        assert!(
            re.retention >= ec.retention,
            "replan retained less than ecmp: {:.3} vs {:.3}",
            re.retention,
            ec.retention
        );
        // the frozen planned arm must wait out the outage; the
        // recovering arm reroutes within a few epochs
        assert!(re.ttr_epochs.is_some(), "replan arm never re-reached steady state");
    }

    /// Satellite 3: the degrade scenario's goodput agrees across the
    /// fluid and packet backends within the DESIGN.md §10 contract, on
    /// both the frozen and the recovering arm.
    #[test]
    fn degrade_cross_backend_within_contract() {
        let x = degrade_xcheck(
            &FabricParams::default(),
            &PlannerCfg::default(),
            &ScenarioParams::default(),
        );
        for (arm, ratio) in
            [("static", x.static_ratio()), ("replan", x.replan_ratio())]
        {
            assert!(
                (ratio - 1.0).abs() <= GOODPUT_TOL,
                "degrade {arm} arm fluid/packet ratio {ratio:.3} outside ±{:.0}%",
                GOODPUT_TOL * 100.0
            );
        }
        // the recovering arm beats the frozen one on both backends
        assert!(x.fluid_replan_gbps > x.fluid_static_gbps);
        assert!(x.packet_replan_gbps > x.packet_static_gbps);
    }
}
