//! Fig 8 — MoE end-to-end latency breakdown, NCCL vs NIMBLE, over
//! global token counts {2K..64K} × hotspot ratios {0.4..0.9}.
//! Paper: average speedup 1.13× (hotspot 0.4) → 1.26× (0.9), peak
//! 1.35× at 16K tokens / 0.9; compute identical between methods.

use crate::baselines::NcclLike;
use crate::coordinator::NimbleRouter;
use crate::fabric::FabricParams;
use crate::metrics::Table;
use crate::moe::{run_moe_step, MoeStep};
use crate::runtime::ComputeModel;
use crate::topology::Topology;
use crate::workloads::moe_traffic::MoeConfig;

pub const TOKENS: [usize; 6] = [2048, 4096, 8192, 16384, 32768, 65536];
pub const HOTSPOTS: [f64; 4] = [0.4, 0.5, 0.7, 0.9];

#[derive(Clone, Copy, Debug)]
pub struct Fig8Row {
    pub tokens: usize,
    pub hotspot: f64,
    pub nccl: MoeStep,
    pub nimble: MoeStep,
}

impl Fig8Row {
    pub fn speedup(&self) -> f64 {
        self.nccl.total_s() / self.nimble.total_s()
    }
}

pub fn sweep(topo: &Topology, params: &FabricParams) -> Vec<Fig8Row> {
    let cm = ComputeModel::default();
    let mut out = Vec::new();
    for &hot in &HOTSPOTS {
        for &tok in &TOKENS {
            let cfg = MoeConfig::paper(tok, hot);
            let nccl = run_moe_step(topo, params, &cm, &mut NcclLike::new(), &cfg);
            let nimble =
                run_moe_step(topo, params, &cm, &mut NimbleRouter::default_for(topo), &cfg);
            out.push(Fig8Row { tokens: tok, hotspot: hot, nccl, nimble });
        }
    }
    out
}

pub fn render(topo: &Topology, params: &FabricParams) -> String {
    let rows = sweep(topo, params);
    let mut t = Table::new(&[
        "hotspot",
        "tokens",
        "nccl disp",
        "compute",
        "nccl comb",
        "nim disp",
        "nim comb (ms)",
        "speedup",
    ]);
    for r in &rows {
        t.row(&[
            format!("{:.1}", r.hotspot),
            format!("{}", r.tokens),
            format!("{:.3}", r.nccl.dispatch_s * 1e3),
            format!("{:.3}", r.nccl.compute_s * 1e3),
            format!("{:.3}", r.nccl.combine_s * 1e3),
            format!("{:.3}", r.nimble.dispatch_s * 1e3),
            format!("{:.3}", r.nimble.combine_s * 1e3),
            format!("{:.2}", r.speedup()),
        ]);
    }
    format!(
        "Fig 8 MoE step breakdown (paper: avg 1.13×@0.4 → 1.26×@0.9, peak 1.35×)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_band_matches_paper_shape() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = sweep(&t, &p);
        // averages per hotspot rise with the ratio
        let avg = |h: f64| {
            let v: Vec<f64> = rows
                .iter()
                .filter(|r| r.hotspot == h)
                .map(|r| r.speedup())
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        let a04 = avg(0.4);
        let a09 = avg(0.9);
        assert!(a09 > a04, "hotter should be faster: {a04:.3} vs {a09:.3}");
        assert!(a04 > 1.0, "NIMBLE should win on average at 0.4: {a04:.3}");
        assert!((1.03..2.0).contains(&a09), "0.9 avg out of band: {a09:.3}");
        // the paper's "enable region": tokens ≥ 16K & hotspot ≥ 0.7 ⇒
        // consistently faster (paper: >1.16×; our compute model is more
        // generous to the baseline — see DESIGN.md §2)
        for r in rows.iter().filter(|r| r.tokens >= 16384 && r.hotspot >= 0.7) {
            assert!(r.speedup() > 1.05, "{}t/{} ⇒ {:.2}", r.tokens, r.hotspot, r.speedup());
        }
    }

    #[test]
    fn compute_column_identical() {
        let t = Topology::paper();
        let p = FabricParams::default();
        for r in sweep(&t, &p) {
            assert!((r.nccl.compute_s - r.nimble.compute_s).abs() < 1e-12);
        }
    }
}
