//! Fluid ↔ packet cross-validation (`nimble xcheck`).
//!
//! The repo carries two independent fabric models of the same
//! calibrated hardware: the max-min fluid engine (every §V artifact)
//! and the packet-level discrete-event simulator
//! ([`crate::fabric::packet::PacketSim`]). This driver flies the same
//! flow sets on both and
//!
//! * asserts **goodput agreement** within [`GOODPUT_TOL`] on the
//!   Fig 6 point-to-point anchors and the Fig 7-style skewed
//!   All-to-Allv (planned routing) — the fidelity contract of
//!   DESIGN.md §10;
//! * reports the **tail metrics only the packet backend can see**:
//!   nearest-rank p50/p95/p99 chunk latency and peak queue depths
//!   ([`crate::metrics::TailReport`]);
//! * re-runs the `nimble replan` PhasedHotRows comparison **on the
//!   packet backend** ([`replan_tail`]): the execution-time loop must
//!   deliver strictly lower p99 chunk latency (and higher goodput)
//!   than flying the stale static plan — a claim the fluid model
//!   cannot even express, since it has no queues.

use super::MB;
use crate::coordinator::replan::ReplanExecutor;
use crate::exp::scale::plan_flows;
use crate::fabric::fluid::{Flow, FluidSim};
use crate::fabric::packet::PacketSim;
use crate::fabric::{BackendKind, FabricParams};
use crate::metrics::{Table, TailReport};
use crate::planner::{Planner, PlannerCfg, ReplanCfg};
use crate::topology::path::candidates;
use crate::topology::Topology;
use crate::util::hist::LatencyHist;
use crate::util::rng::Rng;
use crate::workloads::dynamic::PhasedHotRows;
use crate::workloads::skew::{hotspot_alltoallv, hotspot_alltoallv_jittered};

/// Documented agreement tolerance: on every anchor the packet
/// backend's aggregate goodput must sit within ±15% of the fluid
/// engine's, **at the calibrated anchor payloads** (≥ 64 MB — where
/// the paper's own curves saturate). The models share calibration but
/// not mechanism (max-min rate sharing vs FIFO queueing + pacing), so
/// they are expected to differ by a few percent; below saturation the
/// gap legitimately widens, because queueing delay — which only the
/// packet model has — dominates small transfers (DESIGN.md §10).
pub const GOODPUT_TOL: f64 = 0.15;

/// One cross-validated flow set.
#[derive(Clone, Debug)]
pub struct XcheckRow {
    pub name: &'static str,
    pub fluid_gbps: f64,
    pub packet_gbps: f64,
    /// Tail metrics from the packet run (the fluid engine has none).
    pub tail: TailReport,
}

impl XcheckRow {
    /// packet / fluid goodput ratio.
    pub fn ratio(&self) -> f64 {
        self.packet_gbps / self.fluid_gbps.max(1e-12)
    }

    pub fn agrees(&self) -> bool {
        (self.ratio() - 1.0).abs() <= GOODPUT_TOL
    }
}

/// Fly `flows` on both backends.
fn run_both(
    topo: &Topology,
    params: &FabricParams,
    flows: &[Flow],
    name: &'static str,
) -> XcheckRow {
    let payload: f64 = flows.iter().map(|f| f.bytes).sum();
    let fluid = FluidSim::new(topo, params.clone()).run(flows);
    let mut pk = PacketSim::new(topo, params.clone(), flows);
    pk.run_to_completion().expect("fault-free xcheck run cannot stall");
    let packet = pk.result();
    XcheckRow {
        name,
        fluid_gbps: payload / fluid.makespan.max(1e-12) / 1e9,
        packet_gbps: payload / packet.makespan.max(1e-12) / 1e9,
        tail: TailReport::from_stats(&pk.tail()).expect("packet run delivered chunks"),
    }
}

/// The Fig 6 / Fig 7 anchor suite at `payload_bytes` per flow (p2p)
/// and per rank (All-to-Allv).
pub fn anchor_rows(
    topo: &Topology,
    params: &FabricParams,
    payload_bytes: f64,
) -> Vec<XcheckRow> {
    let mut rows = Vec::new();
    let intra = candidates(topo, 0, 1, true);
    rows.push(run_both(
        topo,
        params,
        &[Flow::new(intra[0].clone(), payload_bytes)],
        "fig6a 1-path",
    ));
    rows.push(run_both(
        topo,
        params,
        &[
            Flow::new(intra[0].clone(), payload_bytes),
            Flow::new(intra[1].clone(), payload_bytes * params.relay_rho),
        ],
        "fig6a 2-path",
    ));
    rows.push(run_both(
        topo,
        params,
        &intra[..3]
            .iter()
            .map(|p| Flow::new(p.clone(), payload_bytes))
            .collect::<Vec<_>>(),
        "fig6a 3-path",
    ));
    let inter = candidates(topo, 0, topo.gpu(1, 0), true);
    rows.push(run_both(
        topo,
        params,
        &[Flow::new(inter[0].clone(), payload_bytes)],
        "fig6b 1-rail",
    ));
    rows.push(run_both(
        topo,
        params,
        &inter
            .iter()
            .map(|p| Flow::new(p.clone(), payload_bytes))
            .collect::<Vec<_>>(),
        "fig6b 4-rail",
    ));
    // Fig 7-style skewed All-to-Allv, routed by Algorithm 1: the
    // planned multi-path splits are exactly what the coordinator would
    // fly, so this cross-validates the routing the paper's claims rest
    // on, not just isolated point-to-point pipes.
    let mut planner = Planner::new(topo, PlannerCfg::default());
    let hot = topo.gpu(1, 0);
    let demands = hotspot_alltoallv(topo, payload_bytes, 0.7, hot);
    rows.push(run_both(
        topo,
        params,
        &plan_flows(&planner.plan(&demands)),
        "a2a hot 0.7",
    ));
    // the jittered variant the scale sweep flies (same seed)
    let mut rng = Rng::new(crate::exp::scale::JITTER_SEED);
    let (_, jittered) =
        hotspot_alltoallv_jittered(topo, payload_bytes, 0.5, &mut rng);
    rows.push(run_both(
        topo,
        params,
        &plan_flows(&planner.plan(&jittered)),
        "a2a jitter 0.5",
    ));
    rows
}

/// The `nimble replan` PhasedHotRows comparison, flown on the packet
/// backend: static stale plan vs the execution-time loop, chunk
/// latencies pooled across rounds.
#[derive(Clone, Debug)]
pub struct ReplanXcheck {
    pub rounds: usize,
    pub static_p99_us: f64,
    pub replanned_p99_us: f64,
    pub static_p50_us: f64,
    pub replanned_p50_us: f64,
    pub static_goodput_gbps: f64,
    pub replanned_goodput_gbps: f64,
    pub replans: usize,
    pub preemptions: usize,
}

/// Run `rounds` phase-shifting hot-row rounds on the packet backend,
/// static round-0 plan vs the monitor → replan → reroute loop (the
/// identical [`ReplanExecutor`] code path — only `params.backend`
/// differs from `nimble replan`).
pub fn replan_tail(
    topo: &Topology,
    params: &FabricParams,
    rounds: usize,
    row_mb: f64,
) -> ReplanXcheck {
    let pk = FabricParams { backend: BackendKind::Packet, ..params.clone() };
    let rcfg = ReplanCfg {
        enable: true,
        cadence_s: 2.0e-4,
        margin: 0.1,
        ..ReplanCfg::default()
    };
    let sched = PhasedHotRows::paper_default(topo, row_mb * MB);
    let d0 = sched.demands_at(topo, 0);
    let p0 = Planner::new(topo, PlannerCfg::default()).plan(&d0);

    let mut static_exec = ReplanExecutor::new(
        topo,
        pk.clone(),
        PlannerCfg::default(),
        ReplanCfg { enable: false, ..rcfg.clone() },
    );
    let mut replan_exec =
        ReplanExecutor::new(topo, pk, PlannerCfg::default(), rcfg);

    let mut incumbent = p0.clone();
    let mut static_lat = LatencyHist::new();
    let mut replanned_lat = LatencyHist::new();
    let mut payload = 0.0f64;
    let mut static_time = 0.0f64;
    let mut replanned_time = 0.0f64;
    let mut replans = 0usize;
    let mut preemptions = 0usize;
    for round in 0..rounds {
        let demands = sched.demands_at(topo, round);
        payload += demands.iter().map(|d| d.bytes).sum::<f64>();
        let s = static_exec.execute(&p0, &demands);
        let r = replan_exec.execute(&incumbent, &demands);
        incumbent = r.final_plan.clone();
        static_time += s.report.makespan_s;
        replanned_time += r.report.makespan_s;
        replans += r.replans;
        preemptions += r.preemptions;
        static_lat.merge(&s.tail.expect("packet backend").sojourn);
        replanned_lat.merge(&r.tail.expect("packet backend").sojourn);
    }
    // pooled per-round histograms merge exactly (bucket-wise count
    // addition), so both percentiles read off one merged histogram
    ReplanXcheck {
        rounds,
        static_p99_us: static_lat.quantile_s(99.0) * 1e6,
        replanned_p99_us: replanned_lat.quantile_s(99.0) * 1e6,
        static_p50_us: static_lat.quantile_s(50.0) * 1e6,
        replanned_p50_us: replanned_lat.quantile_s(50.0) * 1e6,
        static_goodput_gbps: payload / static_time.max(1e-12) / 1e9,
        replanned_goodput_gbps: payload / replanned_time.max(1e-12) / 1e9,
        replans,
        preemptions,
    }
}

/// Full cross-validation outcome.
#[derive(Clone, Debug)]
pub struct XcheckReport {
    pub payload_mb: f64,
    pub rows: Vec<XcheckRow>,
    pub replan: ReplanXcheck,
}

/// Run the whole suite. `payload_mb` drives the anchors; `rounds` ×
/// `row_mb` drives the PhasedHotRows arm.
pub fn run(
    topo: &Topology,
    params: &FabricParams,
    payload_mb: f64,
    rounds: usize,
    row_mb: f64,
) -> XcheckReport {
    XcheckReport {
        payload_mb,
        rows: anchor_rows(topo, params, payload_mb * MB),
        replan: replan_tail(topo, params, rounds, row_mb),
    }
}

/// The acceptance gate `nimble xcheck --check` enforces (and CI runs):
/// every anchor agrees within [`GOODPUT_TOL`], and on the packet
/// backend the execution-time loop strictly beats the static plan on
/// both p99 chunk latency and goodput.
pub fn check(rep: &XcheckReport) -> Result<(), String> {
    for r in &rep.rows {
        if !r.agrees() {
            return Err(format!(
                "anchor '{}' disagrees: fluid {:.1} vs packet {:.1} GB/s \
                 (ratio {:.3}, tolerance ±{:.0}%)",
                r.name,
                r.fluid_gbps,
                r.packet_gbps,
                r.ratio(),
                GOODPUT_TOL * 100.0
            ));
        }
    }
    let rp = &rep.replan;
    if rp.replans == 0 {
        return Err("replan arm never fired on the packet backend".into());
    }
    if rp.replanned_p99_us >= rp.static_p99_us {
        return Err(format!(
            "execution-time loop did not cut p99 chunk latency: {:.1} vs {:.1} µs",
            rp.replanned_p99_us, rp.static_p99_us
        ));
    }
    if rp.replanned_goodput_gbps <= rp.static_goodput_gbps {
        return Err(format!(
            "execution-time loop did not raise goodput: {:.1} vs {:.1} GB/s",
            rp.replanned_goodput_gbps, rp.static_goodput_gbps
        ));
    }
    Ok(())
}

pub fn render(rep: &XcheckReport) -> String {
    let mut t = Table::new(&[
        "anchor",
        "fluid (GB/s)",
        "packet (GB/s)",
        "ratio",
        "p50 (µs)",
        "p95 (µs)",
        "p99 (µs)",
        "peak q (KiB)",
        "chunks",
    ]);
    for r in &rep.rows {
        t.row(&[
            r.name.to_string(),
            format!("{:.1}", r.fluid_gbps),
            format!("{:.1}", r.packet_gbps),
            format!("{:.3}", r.ratio()),
            format!("{:.1}", r.tail.p50_us),
            format!("{:.1}", r.tail.p95_us),
            format!("{:.1}", r.tail.p99_us),
            format!("{:.0}", r.tail.peak_queue_bytes / 1024.0),
            format!("{}", r.tail.chunks),
        ]);
    }
    let rp = &rep.replan;
    format!(
        "Fluid ↔ packet cross-validation ({:.0} MB anchors, agreement tolerance ±{:.0}%)\n{}\
         \nPhasedHotRows on the packet backend ({} rounds, {} replans, {} preemptions):\n\
         \x20 static plan    : p50 {:>8.1} µs  p99 {:>9.1} µs  goodput {:>6.1} GB/s\n\
         \x20 replanned loop : p50 {:>8.1} µs  p99 {:>9.1} µs  goodput {:>6.1} GB/s\n\
         \x20 p99 latency cut: {:.2}x\n",
        rep.payload_mb,
        GOODPUT_TOL * 100.0,
        t.render(),
        rp.rounds,
        rp.replans,
        rp.preemptions,
        rp.static_p50_us,
        rp.static_p99_us,
        rp.static_goodput_gbps,
        rp.replanned_p50_us,
        rp.replanned_p99_us,
        rp.replanned_goodput_gbps,
        rp.static_p99_us / rp.replanned_p99_us.max(1e-12),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fidelity contract at the calibrated anchor payload: every
    /// anchor agrees within the documented tolerance on both backends.
    #[test]
    fn anchors_agree_within_tolerance() {
        let topo = Topology::paper();
        let params = FabricParams::default();
        let rows = anchor_rows(&topo, &params, 64.0 * MB);
        assert_eq!(rows.len(), 7);
        for r in &rows {
            assert!(
                r.agrees(),
                "'{}' disagrees: fluid {:.1} vs packet {:.1} (ratio {:.3})",
                r.name,
                r.fluid_gbps,
                r.packet_gbps,
                r.ratio()
            );
            assert!(r.tail.chunks > 0);
            assert!(r.tail.p50_us <= r.tail.p99_us);
        }
        // congestion is visible where it should be: the planned skewed
        // All-to-Allv queues far deeper than a lone p2p flow
        let lone = &rows[0].tail;
        let a2a = &rows[5].tail;
        assert!(
            a2a.peak_queue_bytes > lone.peak_queue_bytes,
            "skewed collective showed no extra queueing: {} vs {}",
            a2a.peak_queue_bytes,
            lone.peak_queue_bytes
        );
    }

    /// The acceptance claim: on the packet backend, execution-time
    /// re-planning strictly cuts p99 chunk latency AND raises goodput
    /// over the stale static plan, and `check` wires all of it up.
    #[test]
    fn replanned_hot_rows_cut_p99_latency() {
        let topo = Topology::paper();
        let params = FabricParams::default();
        let rep = run(&topo, &params, 64.0, 3, 24.0);
        let rp = &rep.replan;
        assert!(rp.replans >= 1, "loop never fired");
        assert!(
            rp.replanned_p99_us < rp.static_p99_us,
            "p99 not cut: {} vs {} µs",
            rp.replanned_p99_us,
            rp.static_p99_us
        );
        assert!(
            rp.replanned_goodput_gbps > rp.static_goodput_gbps,
            "goodput not raised: {} vs {}",
            rp.replanned_goodput_gbps,
            rp.static_goodput_gbps
        );
        check(&rep).expect("xcheck acceptance gate");
        let text = render(&rep);
        assert!(text.contains("cross-validation"));
        assert!(text.contains("p99 latency cut"));
    }
}
