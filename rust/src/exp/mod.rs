//! Experiment drivers — one per paper table/figure (DESIGN.md §4),
//! shared between the `nimble` CLI, the examples and the benches so
//! every surface regenerates identical numbers.

pub mod ablate;
pub mod faults;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod interference;
pub mod replan;
pub mod scale;
pub mod sendrecv;
pub mod serve;
pub mod table1;
pub mod xcheck;

pub const MB: f64 = 1024.0 * 1024.0;
