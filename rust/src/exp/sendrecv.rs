//! Async Send/Recv experiment (paper §I evaluation highlight):
//! "1.15–2.3× speedup at 8 MB and up to 3.4× at 256 MB over the
//! baseline as imbalance grows, while matching baselines under
//! balanced traffic."

use super::MB;
use crate::baselines::SinglePath;
use crate::collectives::sendrecv::{imbalanced_batch, sendrecv_batch};
use crate::coordinator::NimbleRouter;
use crate::fabric::FabricParams;
use crate::metrics::Table;
use crate::topology::Topology;

pub const SIZES_MB: [f64; 3] = [8.0, 64.0, 256.0];
pub const IMBALANCES: [f64; 5] = [1.0, 2.0, 4.0, 8.0, 16.0];

#[derive(Clone, Copy, Debug)]
pub struct SrRow {
    pub size_mb: f64,
    pub imbalance: f64,
    pub baseline_s: f64,
    pub nimble_s: f64,
}

impl SrRow {
    pub fn speedup(&self) -> f64 {
        self.baseline_s / self.nimble_s
    }
}

pub fn sweep(topo: &Topology, params: &FabricParams) -> Vec<SrRow> {
    let mut out = Vec::new();
    for &mb in &SIZES_MB {
        for &imb in &IMBALANCES {
            let batch = imbalanced_batch(topo, mb * MB, imb);
            let base = sendrecv_batch(topo, params, &mut SinglePath::new(), &batch);
            let nim =
                sendrecv_batch(topo, params, &mut NimbleRouter::default_for(topo), &batch);
            out.push(SrRow {
                size_mb: mb,
                imbalance: imb,
                baseline_s: base.makespan_s,
                nimble_s: nim.makespan_s,
            });
        }
    }
    out
}

pub fn render(topo: &Topology, params: &FabricParams) -> String {
    let rows = sweep(topo, params);
    let mut t = Table::new(&[
        "size (MB)",
        "imbalance",
        "baseline (ms)",
        "nimble (ms)",
        "speedup",
    ]);
    for r in &rows {
        t.row(&[
            format!("{}", r.size_mb),
            format!("{}", r.imbalance),
            format!("{:.3}", r.baseline_s * 1e3),
            format!("{:.3}", r.nimble_s * 1e3),
            format!("{:.2}", r.speedup()),
        ]);
    }
    format!(
        "Async Send/Recv imbalance sweep (paper: 1.15–2.3× @8 MB, up to 3.4× @256 MB)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_grows_with_size_and_imbalance() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = sweep(&t, &p);
        let get = |mb: f64, imb: f64| {
            rows.iter()
                .find(|r| r.size_mb == mb && r.imbalance == imb)
                .unwrap()
                .speedup()
        };
        // grows with imbalance at fixed size (vs the balanced batch;
        // the curve asymptotes near the 278/120 multipath ceiling so
        // it need not be strictly monotone at the top end)
        assert!(get(256.0, 16.0) > get(256.0, 1.0));
        assert!(get(64.0, 8.0) > get(64.0, 1.0));
        // larger messages benefit at least as much at high imbalance
        assert!(get(256.0, 16.0) >= get(8.0, 16.0) * 0.9);
        // paper band: 8 MB ∈ [1.0, 2.5]; 256 MB up to ~3.4
        let s8 = get(8.0, 8.0);
        assert!((0.95..2.6).contains(&s8), "8 MB speedup {s8}");
        let s256 = get(256.0, 16.0);
        assert!(s256 > 1.5 && s256 < 4.0, "256 MB speedup {s256}");
        // never slower than baseline anywhere
        for r in &rows {
            assert!(r.speedup() > 0.95, "regression at {r:?}");
        }
    }
}
