//! Fig 7 — skewed All-to-Allv under a hotspot-ratio sweep, NCCL vs
//! OpenMPI vs NIMBLE (8 GPUs / 2 nodes). Paper: parity (MPI slightly
//! ahead) at mild skew and small messages; NIMBLE up to 5.2× over
//! NCCL at hotspot ≥ 0.7.

use crate::baselines::{MpiLike, NcclLike, Router};
use crate::collectives::alltoallv::alltoallv_demands;
use crate::coordinator::NimbleRouter;
use crate::fabric::FabricParams;
use crate::metrics::Table;
use crate::topology::Topology;
use crate::workloads::skew::hotspot_alltoallv;

#[derive(Clone, Copy, Debug)]
pub struct Fig7Row {
    pub hotspot: f64,
    pub nccl_s: f64,
    pub mpi_s: f64,
    pub nimble_s: f64,
}

impl Fig7Row {
    pub fn speedup_vs_nccl(&self) -> f64 {
        self.nccl_s / self.nimble_s
    }
    pub fn speedup_vs_mpi(&self) -> f64 {
        self.mpi_s / self.nimble_s
    }
}

pub const RATIOS: [f64; 8] = [0.125, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.9];

/// Sweep hotspot ratios for one per-rank payload size.
pub fn sweep(topo: &Topology, params: &FabricParams, payload_bytes: f64) -> Vec<Fig7Row> {
    let hot = topo.gpu(1, 0); // GPU 4: remote hotspot for node 0
    RATIOS
        .iter()
        .map(|&ratio| {
            let demands = hotspot_alltoallv(topo, payload_bytes, ratio, hot);
            let run = |r: &mut dyn Router| {
                alltoallv_demands(topo, params, r, &demands).makespan_s
            };
            Fig7Row {
                hotspot: ratio,
                nccl_s: run(&mut NcclLike::new()),
                mpi_s: run(&mut MpiLike::new()),
                nimble_s: run(&mut NimbleRouter::default_for(topo)),
            }
        })
        .collect()
}

pub fn render(topo: &Topology, params: &FabricParams, payload_bytes: f64) -> String {
    let rows = sweep(topo, params, payload_bytes);
    let mut t = Table::new(&[
        "hotspot",
        "nccl (ms)",
        "mpi (ms)",
        "nimble (ms)",
        "× vs nccl",
        "× vs mpi",
    ]);
    for r in &rows {
        t.row(&[
            format!("{:.3}", r.hotspot),
            format!("{:.3}", r.nccl_s * 1e3),
            format!("{:.3}", r.mpi_s * 1e3),
            format!("{:.3}", r.nimble_s * 1e3),
            format!("{:.2}", r.speedup_vs_nccl()),
            format!("{:.2}", r.speedup_vs_mpi()),
        ]);
    }
    format!(
        "Fig 7 skewed All-to-Allv, payload {:.0} MB/rank (paper: up to 5.2× vs NCCL at ratio ≥ 0.7)\n{}",
        payload_bytes / super::MB,
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::MB;

    #[test]
    fn high_skew_hits_multiple_x() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = sweep(&t, &p, 64.0 * MB);
        let last = rows.last().unwrap();
        assert!(last.hotspot == 0.9);
        assert!(
            last.speedup_vs_nccl() > 3.0,
            "0.9 hotspot speedup {:.2}",
            last.speedup_vs_nccl()
        );
        // uniform-ish end: near parity (within 15%)
        let first = rows.first().unwrap();
        assert!(first.speedup_vs_nccl() > 0.85 && first.speedup_vs_nccl() < 1.6,
            "uniform speedup {:.2}", first.speedup_vs_nccl());
    }

    #[test]
    fn speedup_monotone_ish_in_ratio() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = sweep(&t, &p, 64.0 * MB);
        let s: Vec<f64> = rows.iter().map(|r| r.speedup_vs_nccl()).collect();
        assert!(s.last().unwrap() > &s[0]);
    }

    #[test]
    fn small_messages_mpi_competitive() {
        let t = Topology::paper();
        let p = FabricParams::default();
        // 256 KB per rank: kernel-path overhead dominates; the paper
        // says OpenMPI "can be slightly better" here
        let rows = sweep(&t, &p, 0.25 * MB);
        let mild = &rows[1]; // ratio 0.2
        assert!(
            mild.mpi_s < mild.nccl_s * 1.05,
            "mpi {:.4}ms vs nccl {:.4}ms",
            mild.mpi_s * 1e3,
            mild.nccl_s * 1e3
        );
        // NIMBLE must not fall apart at small sizes (threshold keeps
        // it single-path ⇒ ≈ NCCL)
        assert!(mild.nimble_s < mild.nccl_s * 1.1);
    }
}
