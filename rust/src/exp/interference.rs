//! §V-E — multi-tenant interference: NIMBLE re-slices *one job's*
//! traffic around background load on the shared fabric (it is not a
//! cross-job scheduler; fairness stays with the fabric's CC layer).
//!
//! Setup: a background tenant runs a persistent neighbor-exchange on a
//! subset of links; the foreground job runs a skewed All-to-Allv.
//! NIMBLE's adaptive mode observes the combined link pressure via its
//! monitor and routes the next round around it; NCCL stays static.
//! We report foreground makespan and p99 across rounds.
//!
//! Also here: the §VII "Limitations" experiment — the same skewed
//! workload on a DGX-style NVSwitch topology, where intra-node
//! forwarding is structurally unavailable and only inter-node
//! multi-rail balancing remains.
//!
//! The rounds fly on whatever [`FabricBackend`] the config selects
//! (`[fabric.packet] backend`): the fluid engine by default —
//! bit-identical to the pre-trait runs — or the packet-level
//! discrete-event simulator, so §V-E queueing behavior is observable
//! too. This was the last experiment constructing `FluidSim` directly.

use super::MB;
use crate::baselines::{NcclLike, Router};
use crate::coordinator::NimbleRouter;
use crate::fabric::backend::make_backend;
use crate::fabric::fluid::{Flow, SimResult};
use crate::fabric::FabricParams;
use crate::metrics::Table;
use crate::topology::path::candidates;
use crate::topology::Topology;
use crate::util::stats::percentile;
use crate::workloads::skew::hotspot_alltoallv;
use crate::workloads::stencil::stencil_1d;

/// Fly one round's flow set to completion on the configured backend.
fn run_round_flows(topo: &Topology, params: &FabricParams, flows: &[Flow]) -> SimResult {
    let mut backend = make_backend(topo, params.clone(), flows);
    backend
        .run_to_completion()
        .expect("fault-free round cannot stall");
    backend.result()
}

/// One engine's foreground latency stats under background load.
#[derive(Clone, Debug)]
pub struct InterferenceResult {
    pub engine: String,
    pub makespans: Vec<f64>,
    pub p99_s: f64,
}

/// Run `rounds` of foreground skewed All-to-Allv while a background
/// stencil tenant occupies part of the fabric.
pub fn run_interference(
    topo: &Topology,
    params: &FabricParams,
    rounds: usize,
) -> Vec<InterferenceResult> {
    let fg = hotspot_alltoallv(topo, 48.0 * MB, 0.7, topo.gpu(1, 0));
    let bg = stencil_1d(topo, 96.0 * MB);
    let bg_flows = |mode| {
        bg.iter()
            .map(|d| {
                Flow::new(candidates(topo, d.src, d.dst, false).remove(0), d.bytes)
                    .with_mode(mode)
            })
            .collect::<Vec<_>>()
    };

    let mut out = Vec::new();
    // static NCCL
    {
        let mut nccl = NcclLike::new();
        let mut makespans = Vec::new();
        for _ in 0..rounds {
            let mut flows = nccl.route_flows(topo, &fg);
            let n_fg = flows.len();
            flows.extend(bg_flows(nccl.mode()));
            let sim = run_round_flows(topo, params, &flows);
            let fg_finish = sim.flows[..n_fg]
                .iter()
                .map(|f| f.finish_t)
                .fold(0.0f64, f64::max);
            makespans.push(fg_finish);
        }
        let p99 = percentile(&makespans, 99.0);
        out.push(InterferenceResult { engine: "nccl".into(), makespans, p99_s: p99 });
    }
    // adaptive NIMBLE: each round's plan is warm-started from the
    // previous round's observed (fg + bg) link bytes
    {
        let mut nim = NimbleRouter::adaptive_for(topo);
        let mut makespans = Vec::new();
        for _ in 0..rounds {
            let mut flows = nim.route_flows(topo, &fg);
            let n_fg = flows.len();
            flows.extend(bg_flows(nim.mode()));
            let sim = run_round_flows(topo, params, &flows);
            nim.monitor.observe(&sim.link_bytes);
            let fg_finish = sim.flows[..n_fg]
                .iter()
                .map(|f| f.finish_t)
                .fold(0.0f64, f64::max);
            makespans.push(fg_finish);
        }
        let p99 = percentile(&makespans, 99.0);
        out.push(InterferenceResult { engine: "nimble".into(), makespans, p99_s: p99 });
    }
    out
}

/// §VII: the same skewed All-to-Allv on HGX (all-to-all NVLink) vs a
/// DGX-style NVSwitch node. Returns (engine, hgx_ms, dgx_ms) rows.
pub fn nvswitch_limitation(params: &FabricParams) -> Vec<(String, f64, f64)> {
    let hgx = Topology::paper();
    let dgx = Topology::dgx_nvswitch(2, 4, 4);
    let mut out = Vec::new();
    let makes: [fn() -> Box<dyn Router>; 2] = [
        || Box::new(NcclLike::new()),
        || Box::new(NimbleRouter::default_for(&Topology::paper())),
    ];
    for make in makes {
        let mut name = String::new();
        let mut times = Vec::new();
        for topo in [&hgx, &dgx] {
            let demands = hotspot_alltoallv(topo, 64.0 * MB, 0.9, topo.gpu(1, 0));
            let mut router = make();
            let rep = crate::baselines::run_round(topo, params, router.as_mut(), &demands);
            name = rep.engine.clone();
            times.push(rep.makespan_s);
        }
        out.push((name, times[0], times[1]));
    }
    out
}

pub fn render(topo: &Topology, params: &FabricParams) -> String {
    let mut out = String::new();
    let rows = run_interference(topo, params, 8);
    let mut t = Table::new(&["engine", "fg round 1 (ms)", "fg round 8 (ms)", "fg p99 (ms)"]);
    for r in &rows {
        t.row(&[
            r.engine.clone(),
            format!("{:.3}", r.makespans[0] * 1e3),
            format!("{:.3}", r.makespans.last().unwrap() * 1e3),
            format!("{:.3}", r.p99_s * 1e3),
        ]);
    }
    out += &format!(
        "§V-E multi-tenant interference: foreground skewed All-to-Allv vs background stencil\n{}\n",
        t.render()
    );
    let mut t = Table::new(&["engine", "HGX all-to-all (ms)", "DGX NVSwitch (ms)"]);
    for (name, hgx, dgx) in nvswitch_limitation(params) {
        t.row(&[name, format!("{:.3}", hgx * 1e3), format!("{:.3}", dgx * 1e3)]);
    }
    out += &format!(
        "§VII limitation: skewed All-to-Allv on HGX vs DGX-NVSwitch (intra-node forwarding unavailable)\n{}",
        t.render()
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nimble_trims_tails_under_background_load() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = run_interference(&t, &p, 6);
        let nccl = &rows[0];
        let nim = &rows[1];
        assert!(
            nim.p99_s < nccl.p99_s,
            "NIMBLE should trim the tail: {} vs {}",
            nim.p99_s,
            nccl.p99_s
        );
        // steady-state (post-adaptation) rounds beat round 1 or at
        // least don't regress
        let last = *nim.makespans.last().unwrap();
        assert!(last <= nim.makespans[0] * 1.05);
    }

    #[test]
    fn nvswitch_removes_intra_gain_but_keeps_inter() {
        let p = FabricParams::default();
        let rows = nvswitch_limitation(&p);
        let (_, nccl_hgx, nccl_dgx) = rows[0].clone();
        let (_, nim_hgx, nim_dgx) = rows[1].clone();
        // NIMBLE still wins on DGX (inter-node rails), but by less
        // than on HGX
        let gain_hgx = nccl_hgx / nim_hgx;
        let gain_dgx = nccl_dgx / nim_dgx;
        assert!(gain_dgx > 1.5, "inter-node balancing should survive: {gain_dgx}");
        assert!(
            gain_hgx >= gain_dgx * 0.99,
            "HGX gain {gain_hgx} should be ≥ DGX gain {gain_dgx}"
        );
    }

    #[test]
    fn dgx_topology_has_no_intra_detours() {
        let t = Topology::dgx_nvswitch(2, 4, 4);
        assert_eq!(candidates(&t, 0, 1, true).len(), 1);
        // inter-node rails unchanged
        assert_eq!(candidates(&t, 0, 4, true).len(), 4);
    }
}
