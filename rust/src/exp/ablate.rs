//! Ablations over NIMBLE's design choices (DESIGN.md §4):
//! max-vs-sum path cost, cost-curve shape, λ and ε sweeps, hysteresis
//! (oscillation), size-threshold, rail matching (PXN), and the MWU
//! optimality gap against the exact IP on a tiny instance.

use super::MB;
use crate::baselines::{run_round, NcclLike};
use crate::coordinator::NimbleRouter;
use crate::fabric::FabricParams;
use crate::metrics::Table;
use crate::planner::{
    exact::exact_min_max, CostShape, Demand, Planner, PlannerCfg,
};
use crate::topology::Topology;
use crate::workloads::skew::hotspot_alltoallv;

fn skewed_demands(topo: &Topology) -> Vec<Demand> {
    hotspot_alltoallv(topo, 128.0 * MB, 0.8, topo.gpu(1, 0))
}

fn run_with_cfg(topo: &Topology, params: &FabricParams, cfg: PlannerCfg) -> f64 {
    let mut router = NimbleRouter::new(topo, cfg);
    run_round(topo, params, &mut router, &skewed_demands(topo)).makespan_s
}

/// Max vs sum path metric + cost shapes, on the skewed workload.
pub fn cost_metric(topo: &Topology, params: &FabricParams) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let mut base = PlannerCfg::default();
    out.push(("max(link) [paper]".into(), run_with_cfg(topo, params, base.clone())));
    base.cost.sum_cost = true;
    out.push(("sum(link) [dijkstra-style]".into(), run_with_cfg(topo, params, base)));
    for (name, shape) in [
        ("exp(alpha=40)", CostShape::Exponential { alpha: 40.0 }),
        ("poly(p=2)", CostShape::Polynomial { p: 2.0 }),
    ] {
        let mut cfg = PlannerCfg::default();
        cfg.cost.shape = shape;
        out.push((format!("max(link), {name}"), run_with_cfg(topo, params, cfg)));
    }
    out
}

/// λ sweep: plan quality (makespan) and planner time.
pub fn lambda_sweep(topo: &Topology, params: &FabricParams) -> Vec<(f64, f64, f64)> {
    [0.05, 0.1, 0.25, 0.5, 0.9]
        .iter()
        .map(|&lambda| {
            let cfg = PlannerCfg { lambda, ..PlannerCfg::default() };
            let mut planner = Planner::new(topo, cfg.clone());
            let plan = planner.plan(&skewed_demands(topo));
            let makespan = run_with_cfg(topo, params, cfg);
            (lambda, plan.plan_time_s, makespan)
        })
        .collect()
}

/// ε (chunk granularity) sweep.
pub fn epsilon_sweep(topo: &Topology, params: &FabricParams) -> Vec<(f64, f64, f64)> {
    [64.0 * 1024.0, 256.0 * 1024.0, 1024.0 * 1024.0, 4096.0 * 1024.0]
        .iter()
        .map(|&eps| {
            let cfg = PlannerCfg { epsilon_bytes: eps, ..PlannerCfg::default() };
            let mut planner = Planner::new(topo, cfg.clone());
            let plan = planner.plan(&skewed_demands(topo));
            let makespan = run_with_cfg(topo, params, cfg);
            (eps, plan.plan_time_s, makespan)
        })
        .collect()
}

/// Size-threshold ablation: disable the ≤1 MB guard and watch small
/// messages regress.
pub fn size_threshold(topo: &Topology, params: &FabricParams) -> (f64, f64) {
    let demands = hotspot_alltoallv(topo, 0.5 * MB, 0.8, topo.gpu(1, 0));
    let with_guard = {
        let mut r = NimbleRouter::default_for(topo);
        run_round(topo, params, &mut r, &demands).makespan_s
    };
    let without = {
        let mut cfg = PlannerCfg::default();
        cfg.cost.multipath_min_bytes = 0.0;
        cfg.cost.penalty_scale = 0.0;
        let mut r = NimbleRouter::new(topo, cfg);
        run_round(topo, params, &mut r, &demands).makespan_s
    };
    (with_guard, without)
}

/// Rail-matching ablation: NCCL with PXN vs without, under skew.
pub fn rail_matching(topo: &Topology, params: &FabricParams) -> (f64, f64) {
    let demands = skewed_demands(topo);
    let pxn = run_round(topo, params, &mut NcclLike::new(), &demands).makespan_s;
    let nopxn =
        run_round(topo, params, &mut NcclLike::without_pxn(), &demands).makespan_s;
    (pxn, nopxn)
}

/// MWU gap vs the exact IP optimum on a tiny instance.
pub fn exact_gap(topo: &Topology) -> (f64, f64) {
    let demands = vec![
        Demand::new(0, 1, 240.0 * MB),
        Demand::new(2, 1, 120.0 * MB),
        Demand::new(3, 1, 60.0 * MB),
    ];
    let (z_star, _) = exact_min_max(topo, &demands, 6).unwrap();
    let mut planner = Planner::new(topo, PlannerCfg::default());
    let z = planner.plan(&demands).max_norm_load(topo);
    (z_star, z)
}

pub fn render(topo: &Topology, params: &FabricParams) -> String {
    let mut out = String::new();

    let mut t = Table::new(&["path metric / cost shape", "makespan (ms)"]);
    for (name, s) in cost_metric(topo, params) {
        t.row(&[name, format!("{:.3}", s * 1e3)]);
    }
    out += &format!("Ablation: path-cost metric (skewed All-to-Allv)\n{}\n", t.render());

    let mut t = Table::new(&["lambda", "plan time (ms)", "makespan (ms)"]);
    for (l, pt, ms) in lambda_sweep(topo, params) {
        t.row(&[format!("{l}"), format!("{:.4}", pt * 1e3), format!("{:.3}", ms * 1e3)]);
    }
    out += &format!("Ablation: flow fraction λ\n{}\n", t.render());

    let mut t = Table::new(&["epsilon (KB)", "plan time (ms)", "makespan (ms)"]);
    for (e, pt, ms) in epsilon_sweep(topo, params) {
        t.row(&[
            format!("{}", e / 1024.0),
            format!("{:.4}", pt * 1e3),
            format!("{:.3}", ms * 1e3),
        ]);
    }
    out += &format!("Ablation: chunk granularity ε\n{}\n", t.render());

    let (with_g, without_g) = size_threshold(topo, params);
    out += &format!(
        "Ablation: ≤1 MB single-path guard — with: {:.3} ms, without: {:.3} ms ({}× regression when disabled)\n\n",
        with_g * 1e3,
        without_g * 1e3,
        format!("{:.2}", without_g / with_g)
    );

    let (pxn, nopxn) = rail_matching(topo, params);
    out += &format!(
        "Ablation: rail matching (NCCL) — PXN: {:.3} ms, no PXN: {:.3} ms\n\n",
        pxn * 1e3,
        nopxn * 1e3
    );

    let (z_star, z) = exact_gap(topo);
    out += &format!(
        "MWU vs exact IP (tiny instance): exact Z*={:.4} ms, MWU Z={:.4} ms, gap {:.1}%\n",
        z_star * 1e3,
        z * 1e3,
        100.0 * (z / z_star - 1.0)
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_metric_not_worse_than_sum() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = cost_metric(&t, &p);
        let max_ms = rows[0].1;
        let sum_ms = rows[1].1;
        assert!(max_ms <= sum_ms * 1.1, "max {max_ms} vs sum {sum_ms}");
    }

    #[test]
    fn threshold_guard_protects_small_messages() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let (with_g, without) = size_threshold(&t, &p);
        assert!(without >= with_g * 0.99, "guard should never hurt");
    }

    #[test]
    fn exact_gap_is_bounded() {
        let t = Topology::paper();
        let (z_star, z) = exact_gap(&t);
        assert!(z >= z_star - 1e-12);
        assert!(z <= z_star * 1.5, "gap too big: {z} vs {z_star}");
    }

    #[test]
    fn lambda_extremes_still_valid() {
        let t = Topology::paper();
        let p = FabricParams::default();
        for (_, pt, ms) in lambda_sweep(&t, &p) {
            assert!(pt >= 0.0 && ms > 0.0);
        }
    }
}
