//! Fig 6 — point-to-point speedup from additional paths, four panels:
//! (a) intra-node bandwidth vs size for 1/2/3 paths,
//! (b) inter-node bandwidth vs size for 1/2/4 NICs,
//! (c) intra-node 2-hop forwarding overhead vs direct,
//! (d) inter-node multi-hop GPU-NIC path vs rail-matched direct.

use super::MB;
use crate::fabric::fluid::{Flow, FluidSim};
use crate::fabric::pipeline::PipelineModel;
use crate::fabric::{FabricParams, XferMode};
use crate::metrics::Table;
use crate::topology::path::candidates;
use crate::topology::Topology;

pub const SIZES_MB: [f64; 10] =
    [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0];

/// Panel (a): aggregate GPU0→GPU1 bandwidth with 1, 2, 3 paths.
/// Paper anchors: 120 / 213.1 / 278.2 GB/s at saturation.
pub fn fig6a(topo: &Topology, params: &FabricParams) -> Vec<(f64, f64, f64, f64)> {
    let sim = FluidSim::new(topo, params.clone());
    let cands = candidates(topo, 0, 1, true);
    SIZES_MB
        .iter()
        .map(|&mb| {
            let bytes = mb * MB;
            let one = {
                let r = sim.run(&[Flow::new(cands[0].clone(), bytes)]);
                bytes / r.makespan / 1e9
            };
            let two = {
                // split ∝ achievable rates (direct : ρ·direct)
                let b2 = bytes * params.relay_rho;
                let r = sim.run(&[
                    Flow::new(cands[0].clone(), bytes),
                    Flow::new(cands[1].clone(), b2),
                ]);
                (bytes + b2) / r.makespan / 1e9
            };
            let three = {
                let r = sim.run(&[
                    Flow::new(cands[0].clone(), bytes),
                    Flow::new(cands[1].clone(), bytes),
                    Flow::new(cands[2].clone(), bytes),
                ]);
                3.0 * bytes / r.makespan / 1e9
            };
            (mb, one, two, three)
        })
        .collect()
}

/// Panel (b): GPU0→GPU4 aggregate bandwidth with 1, 2, 4 rails.
/// Paper anchors: 45.1 / ~90 / 170.0 GB/s.
pub fn fig6b(topo: &Topology, params: &FabricParams) -> Vec<(f64, f64, f64, f64)> {
    let sim = FluidSim::new(topo, params.clone());
    let cands = candidates(topo, 0, topo.gpu(1, 0), true);
    let run_k = |bytes: f64, k: usize| {
        let flows: Vec<Flow> =
            cands.iter().take(k).map(|p| Flow::new(p.clone(), bytes)).collect();
        let r = sim.run(&flows);
        k as f64 * bytes / r.makespan / 1e9
    };
    SIZES_MB
        .iter()
        .map(|&mb| {
            let b = mb * MB;
            (mb, run_k(b, 1), run_k(b, 2), run_k(b, 4))
        })
        .collect()
}

/// Panel (c): standalone 2-hop path bandwidth as a fraction of the
/// direct path (chunk-level pipeline model). The paper disables
/// multi-path ≤ 1 MB because this ratio collapses there.
pub fn fig6c(topo: &Topology, params: &FabricParams) -> Vec<(f64, f64, f64, f64)> {
    let m = PipelineModel::new(topo, params.clone());
    let cands = candidates(topo, 0, 1, true);
    SIZES_MB
        .iter()
        .map(|&mb| {
            let b = mb * MB;
            let direct = m.bandwidth_gbps(&cands[0], b, XferMode::Kernel);
            let two_hop = m.bandwidth_gbps(&cands[1], b, XferMode::Kernel);
            (mb, direct, two_hop, two_hop / direct)
        })
        .collect()
}

/// Panel (d): inter-node paths — rail-matched direct (1 hop), GPU
/// forwarded rail-matched (3 hops) and raw cross-rail — NIC is the
/// bottleneck so forwarding is nearly free.
pub fn fig6d(topo: &Topology, params: &FabricParams) -> Vec<(f64, f64, f64, f64)> {
    let m = PipelineModel::new(topo, params.clone());
    // gpu1 → gpu6: rail 1 = src-matched (2 hops incl. dst-side),
    // rail 3 = fully forwarded (3 hops); cross path for contrast.
    let inter = candidates(topo, 1, topo.gpu(1, 2), true);
    let matched = inter.iter().find(|p| p.hops.len() == 2).unwrap().clone();
    let forwarded = inter.iter().find(|p| p.hops.len() == 3).unwrap().clone();
    let cross = crate::topology::path::cross_rail_path(topo, 1, topo.gpu(1, 2)).unwrap();
    SIZES_MB
        .iter()
        .map(|&mb| {
            let b = mb * MB;
            (
                mb,
                m.bandwidth_gbps(&matched, b, XferMode::Kernel),
                m.bandwidth_gbps(&forwarded, b, XferMode::Kernel),
                m.bandwidth_gbps(&cross, b, XferMode::Kernel),
            )
        })
        .collect()
}

pub fn render(topo: &Topology, params: &FabricParams, part: &str) -> String {
    let mut out = String::new();
    let fmt = |x: f64| format!("{x:.1}");
    if part == "a" || part == "all" {
        let mut t = Table::new(&["size (MB)", "1 path", "2 paths", "3 paths (GB/s)"]);
        for (mb, a, b, c) in fig6a(topo, params) {
            t.row(&[format!("{mb}"), fmt(a), fmt(b), fmt(c)]);
        }
        out += &format!("Fig 6(a) intra-node multi-path bandwidth (paper: 120 / 213.1 / 278.2 at saturation)\n{}\n", t.render());
    }
    if part == "b" || part == "all" {
        let mut t = Table::new(&["size (MB)", "1 NIC", "2 NICs", "4 NICs (GB/s)"]);
        for (mb, a, b, c) in fig6b(topo, params) {
            t.row(&[format!("{mb}"), fmt(a), fmt(b), fmt(c)]);
        }
        out += &format!("Fig 6(b) inter-node multi-rail bandwidth (paper: 45.1 / ~90 / 170.0 at saturation)\n{}\n", t.render());
    }
    if part == "c" || part == "all" {
        let mut t = Table::new(&["size (MB)", "direct", "2-hop (GB/s)", "ratio"]);
        for (mb, a, b, r) in fig6c(topo, params) {
            t.row(&[format!("{mb}"), fmt(a), fmt(b), format!("{r:.3}")]);
        }
        out += &format!("Fig 6(c) intra-node forwarding overhead (multi-path disabled ≤1 MB)\n{}\n", t.render());
    }
    if part == "d" || part == "all" {
        let mut t =
            Table::new(&["size (MB)", "rail-matched", "GPU-forwarded", "cross-rail (GB/s)"]);
        for (mb, a, b, c) in fig6d(topo, params) {
            t.row(&[format!("{mb}"), fmt(a), fmt(b), fmt(c)]);
        }
        out += &format!("Fig 6(d) inter-node forwarding overhead (paper: rail-matched 45.1, forwarding ≈ free)\n{}\n", t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6a_anchors_hold() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = fig6a(&t, &p);
        let last = rows.last().unwrap();
        assert!((last.1 - 120.0).abs() < 5.0, "direct {}", last.1);
        assert!((last.2 - 213.1).abs() < 9.0, "2-path {}", last.2);
        assert!((last.3 - 278.2).abs() < 11.0, "3-path {}", last.3);
        // saturation: 64 MB within 10% of the 512 MB value
        let at64 = rows.iter().find(|r| r.0 == 64.0).unwrap();
        assert!(at64.1 / last.1 > 0.9);
    }

    #[test]
    fn fig6b_anchors_hold() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = fig6b(&t, &p);
        let last = rows.last().unwrap();
        assert!((last.1 - 45.1).abs() < 2.0, "1 NIC {}", last.1);
        assert!((last.3 - 170.0).abs() < 7.0, "4 NIC {}", last.3);
        // 2 NICs "nearly double"
        assert!(last.2 / last.1 > 1.85);
    }

    #[test]
    fn fig6c_ratio_improves_with_size() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let rows = fig6c(&t, &p);
        assert!(rows.first().unwrap().3 < rows.last().unwrap().3);
        assert!((rows.last().unwrap().3 - p.relay_rho).abs() < 0.1);
    }

    #[test]
    fn fig6d_forwarding_cheap_cross_rail_costly() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let last = *fig6d(&t, &p).last().unwrap();
        assert!(last.2 / last.1 > 0.93, "forwarding overhead: {} vs {}", last.1, last.2);
        assert!(last.3 < last.1 * 0.8, "cross-rail should lag: {}", last.3);
    }

    #[test]
    fn render_produces_all_panels() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let s = render(&t, &p, "all");
        for tag in ["6(a)", "6(b)", "6(c)", "6(d)"] {
            assert!(s.contains(tag), "missing {tag}");
        }
    }
}
