//! The simulated hardware fabric (DESIGN.md §5).
//!
//! The paper's testbed (H100 NVLink mesh + 4× NDR400 rails) is not
//! available here, so the fabric is replaced by three complementary
//! models calibrated to the paper's own §V-B measurements:
//!
//! * [`fluid`] — flow-level progressive-filling simulator with max-min
//!   fair sharing over link/endpoint/node capacity constraints. This is
//!   the workhorse for Figs 6a/6b/7/8 and Table I (steady-state
//!   bandwidth sharing under contention).
//! * [`packet`] — chunk-granular discrete-event simulator (per-link
//!   FIFO queues, store-and-forward serialization, per-hop propagation
//!   latency, seeded round-robin injection). The only model that can
//!   express queueing delay, incast and tail latency; cross-validated
//!   against [`fluid`] by `nimble xcheck` (DESIGN.md §10).
//! * [`pipeline`] — chunk-level closed-form model of the paper's §IV-C
//!   kernel pipeline (P2P buffer credits, per-hop chunk movement),
//!   used for the transient/overhead studies (Figs 6c/6d) and to
//!   property-check that its steady-state throughput equals the fluid
//!   model's bottleneck rate.
//!
//! [`backend`] defines the [`FabricBackend`] trait the coordinator's
//! execution-time loop drives, with [`fluid::SimEngine`] (default) and
//! [`packet::PacketSim`] as the two swappable implementations.
//!
//! [`faults`] injects deterministic degradation (link flaps, throttled
//! rails, straggler nodes) into either backend via
//! [`FabricBackend::apply_fault`]; the coordinator's replan loop is the
//! recovery mechanism (DESIGN.md §13).
//!
//! Calibration anchors (from the paper):
//! * direct NVLink path: 120 GB/s effective, saturating ≳64 MB
//! * +1 relay path: 213.1 GB/s aggregate ⇒ relay pass-through
//!   efficiency ρ = (213.1 − 120)/120 = 0.776
//! * +2 relay paths: 278.2 GB/s aggregate ⇒ per-GPU injection cap
//!   I_sat = 278.2 GB/s (the relays drop to (278.2−120)/2 = 79.1 each)
//! * single rail: 45.1 GB/s, saturating ≳32 MB; 4 rails: 170.0 GB/s
//!   aggregate ⇒ per-node network injection cap A_net = 170.0 GB/s
//! * multi-path disabled ≤1 MB (kernel-pipeline overhead dominates)

pub mod backend;
pub mod faults;
pub mod fluid;
pub mod packet;
pub mod packet_par;
pub mod pipeline;

pub use backend::{make_backend, BlameKey, FabricBackend, FabricStall, TailStats, WindowAttr};
pub use faults::{Fault, FaultEvent, FaultSchedule, FaultsCfg, Scenario, ScenarioParams};

use crate::topology::{LinkKind, Path, Topology};

/// How a transfer is driven. Kernel-based paths (NCCL, NIMBLE) pay a
/// larger launch/sync overhead but can do multi-hop forwarding;
/// copy-engine (DMA) paths (MPI/UCX) start faster, which is why the
/// paper observes OpenMPI winning at small message sizes (§V-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum XferMode {
    Kernel,
    CopyEngine,
}

/// Calibrated fabric model parameters. All bandwidths in GB/s
/// (1 GB = 1e9 bytes), all latencies in microseconds, sizes in bytes.
#[derive(Clone, Debug)]
pub struct FabricParams {
    /// Half-saturation message size for NVLink paths: eff = S/(S+S_half).
    pub s_half_intra: f64,
    /// Half-saturation message size for NIC rail paths.
    pub s_half_inter: f64,
    /// Relay (forwarding GPU) pass-through efficiency: a relayed stream
    /// is capped at `relay_rho × nvlink_gbps`.
    pub relay_rho: f64,
    /// Per-GPU injection (HBM read + SM copy) cap.
    pub inject_cap_gbps: f64,
    /// Per-GPU receive (HBM write) cap.
    pub recv_cap_gbps: f64,
    /// Per-node aggregate NIC cap (sum over rails actually achievable).
    pub node_net_cap_gbps: f64,
    /// Kernel-based path setup latency (launch + channel sync).
    pub alpha_kernel_us: f64,
    /// Copy-engine (DMA) path setup latency.
    pub alpha_copy_engine_us: f64,
    /// Per-hop pipeline latency (credit handshake / RDMA post).
    pub hop_lat_us: f64,
    /// P2P staging buffer per channel (paper: 10 MB per thread block).
    pub p2p_buf_bytes: f64,
    /// Default pipeline chunk size.
    pub chunk_bytes: f64,
    /// Per-chunk kernel handshake overhead (counter check + sync);
    /// mostly overlapped with the copy in steady state, so small.
    pub chunk_ovh_us: f64,
    /// Per-chunk RDMA post overhead (CPU thread issues ibv_post).
    pub rdma_post_us: f64,
    /// Which simulation backend the coordinator's execution-time loop
    /// flies on ([`backend::make_backend`]). Defaults to the fluid
    /// engine so every pre-existing experiment reproduces bit-identically.
    pub backend: BackendKind,
    /// Packet-backend knobs (`[fabric.packet]` in the TOML config).
    pub packet: PacketParams,
}

/// Selects the [`FabricBackend`] implementation flown by the
/// coordinator ([`backend::make_backend`]). `[fabric.packet] backend`
/// in the TOML config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Flow-level max-min fluid engine ([`fluid::SimEngine`]) — the
    /// default, and the only backend the static experiments use.
    Fluid,
    /// Chunk-granular discrete-event simulator
    /// ([`packet::PacketSim`]): adds queueing/tail-latency fidelity at
    /// higher event cost.
    Packet,
}

/// Event-queue implementation flown by the packet engine
/// (`[fabric.packet] scheduler`). Both process the identical event
/// sequence — traces, results and tail stats are byte-identical
/// (pinned in `tests/fabric_props.rs`) — so this knob trades nothing
/// but speed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Calendar-queue timing wheel with a one-slot fast lane
    /// ([`crate::util::eventq::WheelQueue`]): amortized `O(1)` per
    /// event, allocation-free once warm. The default.
    Wheel,
    /// The original global `BinaryHeap<Reverse<(t, seq, ev)>>`,
    /// retained as the equivalence oracle (`O(log n)` per event).
    Heap,
}

/// Calibration of the packet-level backend (`[fabric.packet]`). The
/// defaults derive from the same paper measurements as the rest of
/// [`FabricParams`]: the per-hop wire latency is `hop_lat_us` restated
/// in nanoseconds, and the sender window is the §IV-C 10 MB P2P
/// staging-buffer credit.
#[derive(Clone, Copy, Debug)]
pub struct PacketParams {
    /// MTU of the simulator: payloads are carved into cells of at most
    /// this many bytes (each flow uses equal-size cells so byte
    /// conservation is exact).
    pub cell_bytes: f64,
    /// Per-flow in-flight window (injected but undelivered bytes) —
    /// the credit-return backpressure bound, default the 10 MB P2P
    /// staging buffer.
    pub buffer_bytes: f64,
    /// Per-hop propagation latency in nanoseconds (default: the
    /// `hop_lat_us` handshake latency, 3 µs).
    pub latency_ns: u64,
    /// Arbitration seed: rotates each endpoint's initial round-robin
    /// pointer. Identical seeds ⇒ byte-identical event traces.
    pub seed: u64,
    /// Event-queue implementation (`scheduler = "wheel" | "heap"`).
    pub scheduler: SchedulerKind,
    /// Worker threads for the partitioned event loop
    /// ([`packet_par::PartitionedPacket`]). Results are byte-identical
    /// for every value — node-disjoint partitions are merged in a
    /// canonical order — so this, too, trades nothing but speed.
    pub threads: usize,
    /// Debug oracle: also keep the exact per-chunk sojourn/transit
    /// sample vectors (`TailStats::sojourn_exact_s`/`transit_exact_s`)
    /// alongside the bounded streaming histograms. O(chunks) memory —
    /// tests only; production runs leave this off.
    pub exact_tail: bool,
}

impl Default for PacketParams {
    fn default() -> Self {
        PacketParams {
            cell_bytes: 256.0 * 1024.0,
            buffer_bytes: 10.0 * 1024.0 * 1024.0,
            latency_ns: 3_000,
            seed: 0x9AC4E7,
            scheduler: SchedulerKind::Wheel,
            threads: 1,
            exact_tail: false,
        }
    }
}

impl Default for FabricParams {
    fn default() -> Self {
        FabricParams {
            s_half_intra: 3.0 * 1024.0 * 1024.0,
            s_half_inter: 1.5 * 1024.0 * 1024.0,
            relay_rho: 0.776,
            inject_cap_gbps: 278.2,
            recv_cap_gbps: 278.2,
            node_net_cap_gbps: 170.0,
            alpha_kernel_us: 15.0,
            alpha_copy_engine_us: 6.0,
            hop_lat_us: 3.0,
            p2p_buf_bytes: 10.0 * 1024.0 * 1024.0,
            chunk_bytes: 512.0 * 1024.0,
            chunk_ovh_us: 0.3,
            rdma_post_us: 1.0,
            backend: BackendKind::Fluid,
            packet: PacketParams::default(),
        }
    }
}

impl FabricParams {
    /// Size-dependent efficiency for a path whose bottleneck is kind
    /// `k`: the classic latency/bandwidth saturation curve.
    pub fn eff(&self, bytes: f64, inter: bool) -> f64 {
        let s_half = if inter { self.s_half_inter } else { self.s_half_intra };
        bytes / (bytes + s_half)
    }

    /// Path setup latency in seconds.
    pub fn start_latency_s(&self, path: &Path, mode: XferMode) -> f64 {
        let alpha = match mode {
            XferMode::Kernel => self.alpha_kernel_us,
            XferMode::CopyEngine => self.alpha_copy_engine_us,
        };
        (alpha + self.hop_lat_us * path.hops.len() as f64) * 1e-6
    }

    /// Per-flow attainable rate ceiling (GB/s) for `bytes` routed over
    /// `path`: bottleneck link capacity × size efficiency, further
    /// capped by relay pass-through when the path forwards through
    /// intermediate GPUs.
    pub fn flow_rate_cap_gbps(&self, topo: &Topology, path: &Path, bytes: f64) -> f64 {
        let mut bottleneck = f64::INFINITY;
        let mut has_rail = false;
        for &h in &path.hops {
            let l = topo.link(h);
            if !matches!(l.kind, LinkKind::NvLink) {
                has_rail = true;
            }
            bottleneck = bottleneck.min(l.cap_gbps);
        }
        let mut cap = bottleneck * self.eff(bytes, has_rail);
        if path.relay_count() > 0 {
            cap = cap.min(self.relay_rho * topo.nvlink_gbps * self.eff(bytes, has_rail));
        }
        cap
    }
}

/// Convert GB/s to bytes/second.
pub fn gbps_to_bps(gbps: f64) -> f64 {
    gbps * 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::path::candidates;

    #[test]
    fn efficiency_curve_saturates_where_paper_says() {
        let p = FabricParams::default();
        let mb = 1024.0 * 1024.0;
        // intra: ~64 MB to reach ≥95% of peak
        assert!(p.eff(64.0 * mb, false) > 0.95);
        assert!(p.eff(1.0 * mb, false) < 0.30);
        // inter: ~32 MB to reach ≥95%
        assert!(p.eff(32.0 * mb, true) > 0.95);
    }

    #[test]
    fn rate_cap_direct_vs_relay() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let big = 256.0 * 1024.0 * 1024.0;
        let cands = candidates(&t, 0, 1, true);
        let direct = &cands[0];
        let relay = &cands[1];
        let rd = p.flow_rate_cap_gbps(&t, direct, big);
        let rr = p.flow_rate_cap_gbps(&t, relay, big);
        assert!(rd > 117.0 && rd <= 120.0, "direct {rd}");
        // relay capped at rho*120 ≈ 93.1
        assert!(rr > 90.0 && rr < 94.0, "relay {rr}");
    }

    #[test]
    fn rail_path_bottleneck_is_nic() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let big = 256.0 * 1024.0 * 1024.0;
        for path in candidates(&t, 1, 6, true) {
            let r = p.flow_rate_cap_gbps(&t, &path, big);
            assert!(r > 44.0 && r <= 45.1, "rail path capped by NIC, got {r}");
        }
    }

    #[test]
    fn copy_engine_starts_faster() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let path = &candidates(&t, 0, 1, false)[0];
        assert!(
            p.start_latency_s(path, XferMode::CopyEngine)
                < p.start_latency_s(path, XferMode::Kernel)
        );
    }
}
