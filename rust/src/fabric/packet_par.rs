//! Partitioned parallel event loop for the packet engine (DESIGN.md
//! §9): run one [`PacketSim`] per **node-disjoint flow component** and
//! advance the components on worker threads between epoch boundaries.
//!
//! ## Why this is legal
//!
//! Two flows interact in the packet engine only through shared
//! resources: a source GPU's injector, a destination GPU's receive
//! stage, a link's FIFO, or a node's NIC-aggregate token clock. A
//! flow's **footprint** is exactly that resource set — `{src, dst}`
//! GPUs, its hop links, and the nodes those hops charge. Union-find
//! over footprints yields components that provably never touch each
//! other's state, so each component's event stream is independent of
//! the others and can run on its own scheduler without any
//! synchronization. Within a component, event order is the engine's
//! usual total `(time, seq)` key — nothing about arbitration changes.
//!
//! ## Determinism and thread invariance
//!
//! Partition structure is a pure function of the flow sequence (never
//! of thread count or timing), each sub-simulation is deterministic on
//! its own, and every merged observable is assembled in **canonical
//! component order** (component creation order, which is itself
//! input-order determined): traces merge by `(time, component rank,
//! within-component position)`, latency histograms merge by exact
//! bucket-count addition, per-link counters sum. Worker threads only decide *when*
//! each component advances, never *what* it computes — so results are
//! byte-identical for every `[fabric.packet] threads` value, pinned by
//! `prop_partitioned_thread_count_invariance` in
//! `tests/fabric_props.rs`.
//!
//! With a single connected component (every collective whose flows
//! share endpoints — e.g. one all-to-all) the wrapper degenerates to
//! exactly one inline [`PacketSim`]: physics, traces and tail stats
//! are bit-identical to the monolithic engine. Multi-tenant serving
//! workloads with disjoint tenant placements are where the partition
//! fans out.
//!
//! ## Merges
//!
//! A later `add_flows` epoch can issue a flow that bridges two live
//! components (a re-routed residual crossing tenants' rails). The
//! victim component's state is transplanted into the survivor
//! ([`PacketSim::absorb`]): per-resource state moves without collision
//! (the components were disjoint), pending events re-enter the
//! survivor's queue in `(t, seq)` order, and flow tickets are
//! rewritten. Components live in a generation-checked
//! [`Slab`] — a stale [`Handle`] from a merged-away component can
//! never alias the slot's next tenant.

use super::backend::{reduce_blame, BlameKey, FabricStall, TailStats, WindowAttr};
use super::faults::Fault;
use super::fluid::{Flow, FlowResult, SimResult};
use super::packet::{PacketSim, TraceEvent};
use super::FabricParams;
use crate::topology::Topology;
use crate::util::arena::{Handle, Slab};
use std::collections::BTreeMap;

/// Where a globally indexed flow lives: which component (generation
/// checked) and which local index inside it. Rewritten on merges, so a
/// lookup through a stale handle indicates a logic error and is
/// reported by the slab rather than silently reading a reused slot.
#[derive(Clone, Copy, Debug)]
struct FlowTicket {
    sub: Handle,
    local: u32,
}

/// The partitioned packet backend ([`super::BackendKind::Packet`] via
/// [`super::make_backend`]). Public surface mirrors [`PacketSim`];
/// flow indices are global issue order.
pub struct PartitionedPacket<'a> {
    topo: &'a Topology,
    params: FabricParams,
    threads: usize,
    subs: Slab<PacketSim<'a>>,
    /// Live components in creation order — the canonical merge order.
    order: Vec<Handle>,
    /// Global flow index → component + local index.
    tickets: Vec<FlowTicket>,
    /// Per-component global flow ids in local-index order.
    sub_flows: BTreeMap<Handle, Vec<u32>>,
    /// Per-component claimed sites (see [`Self::flow_sites`]).
    footprint: BTreeMap<Handle, Vec<usize>>,
    /// Site → owning component. Site ids: GPU `g` → `g`, node `n` →
    /// `ng + n`, link `l` → `ng + nn + l`.
    site_owner: Vec<Option<Handle>>,
    /// Faults applied so far, replayed onto components created later
    /// (scale state is global; a fresh component must see it too).
    fault_log: Vec<Fault>,
    t_ns: u64,
    trace_on: bool,
}

impl<'a> PartitionedPacket<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams, flows: &[Flow]) -> Self {
        let n_sites = topo.num_gpus() + topo.nodes + topo.links.len();
        let mut pp = PartitionedPacket {
            topo,
            threads: params.packet.threads.max(1),
            params,
            subs: Slab::new(),
            order: Vec::new(),
            tickets: Vec::new(),
            sub_flows: BTreeMap::new(),
            footprint: BTreeMap::new(),
            site_owner: vec![None; n_sites],
            fault_log: Vec::new(),
            t_ns: 0,
            trace_on: false,
        };
        pp.add_flows(flows);
        pp
    }

    /// The shared-resource sites a flow's events can touch.
    fn flow_sites(&self, f: &Flow) -> Vec<usize> {
        let ng = self.topo.num_gpus();
        let nn = self.topo.nodes;
        let mut sites = Vec::with_capacity(2 + 3 * f.path.hops.len());
        sites.push(f.path.src);
        sites.push(f.path.dst);
        for &h in &f.path.hops {
            sites.push(ng + nn + h);
            let l = self.topo.link(h);
            if let Some(n) = self.topo.nic_out_node(l) {
                sites.push(ng + n);
            }
            if let Some(n) = self.topo.nic_in_node(l) {
                sites.push(ng + n);
            }
        }
        sites.sort_unstable();
        sites.dedup();
        sites
    }

    /// Live components (components = partition count the experiments
    /// report).
    pub fn num_components(&self) -> usize {
        self.order.len()
    }

    /// Merge `victim` into `target`: transplant simulator state,
    /// rewrite tickets, re-own sites.
    fn merge(&mut self, target: Handle, victim: Handle) {
        debug_assert_ne!(target, victim);
        let vsim = self.subs.remove(victim).expect("victim component is live");
        let tsim = self.subs.get_mut(target).expect("target component is live");
        let base = tsim.absorb(vsim);
        let moved = self.sub_flows.remove(&victim).unwrap_or_default();
        for &gid in &moved {
            let tk = &mut self.tickets[gid as usize];
            tk.sub = target;
            tk.local += base;
        }
        self.sub_flows.entry(target).or_default().extend(moved);
        let sites = self.footprint.remove(&victim).unwrap_or_default();
        for &s in &sites {
            self.site_owner[s] = Some(target);
        }
        self.footprint.entry(target).or_default().extend(sites);
        self.order.retain(|&h| h != victim);
    }

    /// Register additional flows; returns the first new global index.
    /// Groups the batch by connectivity (union-find over sites), opens
    /// new components for unclaimed groups, and merges components a
    /// bridging flow couples.
    pub fn add_flows(&mut self, flows: &[Flow]) -> usize {
        let first = self.tickets.len();
        if flows.is_empty() {
            return first;
        }
        // union-find over the sites the new flows touch
        let n_sites = self.site_owner.len();
        let mut parent: Vec<u32> = vec![u32::MAX; n_sites]; // MAX = untouched root
        fn find(parent: &mut [u32], mut s: usize) -> usize {
            while parent[s] != u32::MAX && parent[s] as usize != s {
                let gp = parent[parent[s] as usize];
                if gp != u32::MAX {
                    parent[s] = gp; // path halving
                }
                s = parent[s] as usize;
            }
            s
        }
        let site_lists: Vec<Vec<usize>> =
            flows.iter().map(|f| self.flow_sites(f)).collect();
        for sites in &site_lists {
            let r0 = find(&mut parent, sites[0]);
            parent[r0] = r0 as u32;
            for &s in &sites[1..] {
                let r = find(&mut parent, s);
                parent[r] = r0 as u32;
            }
        }
        // group the batch's flows by root, in first-appearance order
        let mut group_of_root: BTreeMap<usize, usize> = BTreeMap::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, sites) in site_lists.iter().enumerate() {
            let root = find(&mut parent, sites[0]);
            let gi = *group_of_root.entry(root).or_insert_with(|| {
                groups.push(Vec::new());
                groups.len() - 1
            });
            groups[gi].push(i);
        }
        let mut tickets: Vec<Option<FlowTicket>> = vec![None; flows.len()];
        for group in groups {
            // every live component owning one of the group's sites
            let mut owners: Vec<Handle> = Vec::new();
            for &i in &group {
                for &s in &site_lists[i] {
                    if let Some(h) = self.site_owner[s] {
                        if !owners.contains(&h) {
                            owners.push(h);
                        }
                    }
                }
            }
            // canonical target: the oldest involved component
            owners.sort_by_key(|h| {
                self.order.iter().position(|x| x == h).expect("owner is live")
            });
            let target = match owners.first() {
                Some(&t) => {
                    for &victim in &owners[1..] {
                        self.merge(t, victim);
                    }
                    t
                }
                None => {
                    let mut sim =
                        PacketSim::new(self.topo, self.params.clone(), &[]);
                    sim.warp_clock_ns(self.t_ns);
                    sim.set_trace(self.trace_on);
                    for f in &self.fault_log {
                        sim.apply_fault(f);
                    }
                    let h = self.subs.insert(sim);
                    self.order.push(h);
                    h
                }
            };
            // claim the group's sites
            let fp = self.footprint.entry(target).or_default();
            for &i in &group {
                for &s in &site_lists[i] {
                    if self.site_owner[s].is_none() {
                        self.site_owner[s] = Some(target);
                        fp.push(s);
                    }
                }
            }
            // issue the group's flows, preserving batch-relative order
            let batch: Vec<Flow> = group.iter().map(|&i| flows[i].clone()).collect();
            let sim = self.subs.get_mut(target).expect("target component is live");
            let local0 = sim.add_flows(&batch) as u32;
            let ids = self.sub_flows.entry(target).or_default();
            for (j, &i) in group.iter().enumerate() {
                tickets[i] = Some(FlowTicket { sub: target, local: local0 + j as u32 });
                ids.push((first + i) as u32);
            }
        }
        self.tickets
            .extend(tickets.into_iter().map(|t| t.expect("every flow grouped")));
        first
    }

    /// Advance every component to `t_stop`, on `threads` workers when
    /// more than one component is live. Thread assignment only decides
    /// scheduling; each component's computation is identical, so the
    /// outcome is byte-identical for every thread count.
    pub fn advance_to(&mut self, t_stop: f64) -> Result<(), FabricStall> {
        let stall = if self.threads <= 1 || self.order.len() <= 1 {
            let mut results: Vec<(Handle, Result<(), FabricStall>)> = Vec::new();
            for (h, sim) in self.subs.iter_mut() {
                results.push((h, sim.advance_to(t_stop)));
            }
            self.first_stall(results)
        } else {
            let mut sims: Vec<(Handle, &mut PacketSim<'a>)> =
                self.subs.iter_mut().collect();
            let n = sims.len();
            let per = n.div_ceil(self.threads.min(n));
            let mut results: Vec<(Handle, Result<(), FabricStall>)> =
                Vec::with_capacity(n);
            std::thread::scope(|scope| {
                let mut joins = Vec::new();
                for chunk in sims.chunks_mut(per) {
                    joins.push(scope.spawn(move || {
                        chunk
                            .iter_mut()
                            .map(|(h, sim)| (*h, sim.advance_to(t_stop)))
                            .collect::<Vec<_>>()
                    }));
                }
                for j in joins {
                    results.extend(j.join().expect("event-loop worker panicked"));
                }
            });
            self.first_stall(results)
        };
        for h in &self.order {
            let c = self.subs.get(*h).expect("live").clock_ns();
            self.t_ns = self.t_ns.max(c);
        }
        // mirror the monolithic engine: a bounded advance moves the
        // clock to the epoch boundary even with no components live
        if t_stop.is_finite() {
            self.t_ns = self.t_ns.max(super::packet::ns_of(t_stop));
        }
        match stall {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// The stall of the lowest-rank stalled component (canonical, so
    /// the reported error does not depend on worker scheduling).
    fn first_stall(
        &self,
        results: Vec<(Handle, Result<(), FabricStall>)>,
    ) -> Option<FabricStall> {
        for h in &self.order {
            if let Some((_, Err(e))) = results.iter().find(|(hh, _)| hh == h) {
                return Some(*e);
            }
        }
        None
    }

    /// Run every remaining event (no epoch bound).
    pub fn run_to_completion(&mut self) -> Result<(), FabricStall> {
        self.advance_to(f64::INFINITY)
    }

    pub fn is_done(&self) -> bool {
        self.order.iter().all(|&h| self.subs.get(h).expect("live").is_done())
    }

    pub fn now(&self) -> f64 {
        self.t_ns as f64 * 1e-9
    }

    /// Events processed across all components.
    pub fn events(&self) -> u64 {
        self.order.iter().map(|&h| self.subs.get(h).expect("live").events()).sum()
    }

    /// Self-profiling counters merged across components in canonical
    /// order. Each counter is a per-component sum and each component's
    /// trajectory is thread-count invariant, so the merged profile is
    /// identical for every `[fabric.packet] threads`.
    pub fn profile(&self) -> crate::fabric::backend::EngineProfile {
        let mut p = crate::fabric::backend::EngineProfile::default();
        for &h in &self.order {
            let sub = self.subs.get(h).expect("live").profile();
            p.events += sub.events;
            p.sched_pushes += sub.sched_pushes;
            p.sched_pops += sub.sched_pops;
            p.solver_invocations += sub.solver_invocations;
        }
        p
    }

    fn sim_of(&self, i: usize) -> (&PacketSim<'a>, usize) {
        let tk = self.tickets[i];
        let sim = self.subs.get(tk.sub).expect("stale flow ticket");
        (sim, tk.local as usize)
    }

    pub fn residual_bytes(&self, i: usize) -> f64 {
        let (sim, l) = self.sim_of(i);
        sim.residual_bytes(l)
    }

    pub fn moved_bytes(&self, i: usize) -> f64 {
        let (sim, l) = self.sim_of(i);
        sim.moved_bytes(l)
    }

    pub fn is_live(&self, i: usize) -> bool {
        let (sim, l) = self.sim_of(i);
        sim.is_live(l)
    }

    pub fn flow(&self, i: usize) -> &Flow {
        let (sim, l) = self.sim_of(i);
        sim.flow(l)
    }

    pub fn num_flows(&self) -> usize {
        self.tickets.len()
    }

    pub fn preempt(&mut self, i: usize) -> f64 {
        let tk = self.tickets[i];
        self.subs.get_mut(tk.sub).expect("stale flow ticket").preempt(tk.local as usize)
    }

    /// Broadcast a fault to every component (capacity-scale state is
    /// global) and log it for components created later.
    pub fn apply_fault(&mut self, fault: &Fault) {
        for (_, sim) in self.subs.iter_mut() {
            sim.apply_fault(fault);
        }
        self.fault_log.push(*fault);
    }

    pub fn take_window(&mut self) -> Vec<f64> {
        let mut out = vec![0.0; self.topo.links.len()];
        for (_, sim) in self.subs.iter_mut() {
            for (o, w) in out.iter_mut().zip(sim.take_window()) {
                *o += w;
            }
        }
        out
    }

    /// Window bytes with blame decomposition (see
    /// [`super::backend::WindowAttr`]). Components are link-disjoint,
    /// so each link's blame map receives entries from at most one
    /// component; the canonical [`reduce_blame`] reduction therefore
    /// reproduces exactly the per-link totals [`Self::take_window`]
    /// would have returned (additions against 0.0 are exact), for every
    /// thread count.
    pub fn take_window_attr(&mut self) -> WindowAttr {
        let mut per_link: Vec<BTreeMap<BlameKey, f64>> =
            vec![BTreeMap::new(); self.topo.links.len()];
        for &h in &self.order.clone() {
            let sub = self.subs.get_mut(h).expect("live").take_window_attr();
            for (l, entries) in sub.blame.into_iter().enumerate() {
                for (k, b) in entries {
                    *per_link[l].entry(k).or_insert(0.0) += b;
                }
            }
        }
        reduce_blame(per_link)
    }

    /// Record compact event traces in every component (and components
    /// created later).
    pub fn set_trace(&mut self, on: bool) {
        self.trace_on = on;
        for (_, sim) in self.subs.iter_mut() {
            sim.set_trace(on);
        }
    }

    /// The merged trace in canonical `(time, component rank, position)`
    /// order — deterministic and thread-count invariant. With one
    /// component this is exactly the monolithic engine's trace.
    pub fn trace(&self) -> Vec<TraceEvent> {
        let mut all: Vec<(u64, usize, usize, TraceEvent)> = Vec::new();
        for (rank, &h) in self.order.iter().enumerate() {
            let sim = self.subs.get(h).expect("live");
            for (pos, &e) in sim.trace().iter().enumerate() {
                all.push((e.0, rank, pos, e));
            }
        }
        all.sort_unstable_by_key(|&(t, r, p, _)| (t, r, p));
        all.into_iter().map(|(_, _, _, e)| e).collect()
    }

    /// Snapshot the outcome in global flow-index order.
    pub fn result(&self) -> SimResult {
        let mut flows: Vec<FlowResult> = vec![
            FlowResult { start_t: 0.0, finish_t: f64::NAN, bytes: 0.0 };
            self.tickets.len()
        ];
        let mut link_bytes = vec![0.0; self.topo.links.len()];
        for &h in &self.order {
            let sim = self.subs.get(h).expect("live");
            let r = sim.result();
            let ids = self.sub_flows.get(&h).map(|v| v.as_slice()).unwrap_or(&[]);
            debug_assert_eq!(ids.len(), r.flows.len());
            for (&gid, fr) in ids.iter().zip(r.flows) {
                flows[gid as usize] = fr;
            }
            for (lb, b) in link_bytes.iter_mut().zip(&r.link_bytes) {
                *lb += b;
            }
        }
        let makespan = flows
            .iter()
            .map(|f| f.finish_t)
            .filter(|t| !t.is_nan())
            .fold(0.0, f64::max);
        SimResult { flows, link_bytes, makespan }
    }

    /// Tail observations merged in canonical component-rank order:
    /// latency histograms merge by exact bucket-count addition (so the
    /// merge is order-independent and thread-count invariant), per-key
    /// maps union (disjoint components can still share a tenant tag),
    /// peak depths take elementwise max.
    pub fn tail(&self) -> TailStats {
        let mut out = TailStats {
            peak_queue_bytes: vec![0.0; self.topo.links.len()],
            peak_recv_queue_bytes: vec![0.0; self.topo.num_gpus()],
            ..TailStats::default()
        };
        for &h in &self.order {
            let t = self.subs.get(h).expect("live").tail();
            out.sojourn.merge(&t.sojourn);
            out.transit.merge(&t.transit);
            out.sojourn_exact_s.extend(t.sojourn_exact_s);
            out.transit_exact_s.extend(t.transit_exact_s);
            for (k, v) in t.per_pair_sojourn {
                out.per_pair_sojourn.entry(k).or_default().merge(&v);
            }
            for (k, v) in t.per_tag_sojourn {
                out.per_tag_sojourn.entry(k).or_default().merge(&v);
            }
            for (o, p) in out.peak_queue_bytes.iter_mut().zip(t.peak_queue_bytes) {
                *o = o.max(p);
            }
            for (o, p) in
                out.peak_recv_queue_bytes.iter_mut().zip(t.peak_recv_queue_bytes)
            {
                *o = o.max(p);
            }
            out.delivered_chunks += t.delivered_chunks;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::SchedulerKind;
    use crate::topology::path::candidates;

    const MB: f64 = 1024.0 * 1024.0;

    fn params_with(threads: usize) -> FabricParams {
        let mut p = FabricParams::default();
        p.packet.threads = threads;
        p
    }

    /// Guaranteed multi-component workload: intra-node NVLink flows on
    /// distinct nodes share no GPU, link or NIC-charged node, so the
    /// partition provably splits them.
    fn disjoint_flows(topo: &Topology) -> Vec<Flow> {
        let gpn = topo.gpus_per_node;
        let mut flows = Vec::new();
        for node in 0..2 {
            let s = node * gpn;
            let d = node * gpn + 1;
            let p = candidates(topo, s, d, false).remove(0);
            flows.push(Flow::new(p, 16.0 * MB));
        }
        flows
    }

    /// Intra-node flows on different nodes are node-disjoint: the
    /// wrapper runs them as separate components, and the physics match
    /// the monolithic engine bit-for-bit (identical per-flow events).
    #[test]
    fn disjoint_flows_partition_and_match_monolithic() {
        let t = Topology::paper();
        let flows = disjoint_flows(&t);
        let mut par = PartitionedPacket::new(&t, params_with(1), &flows);
        assert_eq!(par.num_components(), 2, "expected two components");
        par.run_to_completion().expect("no stall");
        let rp = par.result();

        let mut mono = PacketSim::new(&t, FabricParams::default(), &flows);
        mono.run_to_completion().expect("no stall");
        let rm = mono.result();

        assert_eq!(rp.makespan.to_bits(), rm.makespan.to_bits());
        assert_eq!(rp.link_bytes, rm.link_bytes);
        for (a, b) in rp.flows.iter().zip(&rm.flows) {
            assert_eq!(a.finish_t.to_bits(), b.finish_t.to_bits());
            assert_eq!(a.bytes.to_bits(), b.bytes.to_bits());
        }
        assert_eq!(par.events(), mono.events());
    }

    /// One connected component (shared source GPU) degenerates to a
    /// single inline PacketSim: trace, result and tails bit-identical
    /// to the monolithic engine.
    #[test]
    fn single_component_is_bit_identical_to_monolithic() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, 1, true);
        let flows = vec![
            Flow::new(cands[0].clone(), 16.0 * MB),
            Flow::new(cands[1].clone(), 8.0 * MB).at(0.0002),
        ];
        let mut par = PartitionedPacket::new(&t, params_with(8), &flows);
        assert_eq!(par.num_components(), 1);
        par.set_trace(true);
        par.run_to_completion().expect("no stall");

        let mut mono = PacketSim::new(&t, FabricParams::default(), &flows);
        mono.set_trace(true);
        mono.run_to_completion().expect("no stall");

        assert_eq!(par.trace(), mono.trace().to_vec());
        assert_eq!(par.result().makespan.to_bits(), mono.result().makespan.to_bits());
        let (tp, tm) = (par.tail(), mono.tail());
        assert_eq!(tp.sojourn, tm.sojourn);
        assert_eq!(tp.per_pair_sojourn, tm.per_pair_sojourn);
    }

    /// Thread count must not change a single byte of the outcome.
    #[test]
    fn thread_count_invariance() {
        let t = Topology::paper();
        // 4 disjoint intra-node components + timing spread
        let gpn = t.gpus_per_node;
        let flows: Vec<Flow> = (0..4)
            .map(|node| {
                let s = node * gpn;
                let p = candidates(&t, s, s + 1, false).remove(0);
                Flow::new(p, (8.0 + node as f64) * MB).at(node as f64 * 1e-4)
            })
            .collect();
        let drive = |threads: usize| {
            let mut par = PartitionedPacket::new(&t, params_with(threads), &flows);
            par.set_trace(true);
            par.run_to_completion().expect("no stall");
            (par.trace(), par.result(), par.tail().sojourn, par.events())
        };
        let (tr1, r1, so1, ev1) = drive(1);
        for threads in [2, 8] {
            let (tr, r, so, ev) = drive(threads);
            assert_eq!(tr1, tr, "trace diverged at threads={threads}");
            assert_eq!(ev1, ev);
            assert_eq!(r1.makespan.to_bits(), r.makespan.to_bits());
            assert_eq!(r1.link_bytes, r.link_bytes);
            assert_eq!(so1, so);
        }
    }

    /// A bridging flow forces a merge: the two components' state is
    /// transplanted into one, every flow still finishes, bytes conserve
    /// and tickets stay valid across the merge.
    #[test]
    fn bridging_flow_merges_components() {
        let t = Topology::paper();
        let flows = disjoint_flows(&t);
        let mut par = PartitionedPacket::new(&t, params_with(2), &flows);
        assert_eq!(par.num_components(), 2);
        par.advance_to(0.0002).expect("no stall");
        // bridge: node 0 GPU → node 1 GPU (touches both components'
        // source GPUs through its endpoints and NIC charges)
        let gpn = t.gpus_per_node;
        let bridge = candidates(&t, 0, gpn + 1, true).remove(0);
        let idx = par.add_flows(&[Flow::new(bridge, 8.0 * MB).at(par.now())]);
        assert_eq!(idx, 2);
        assert_eq!(par.num_components(), 1, "bridge must merge components");
        par.run_to_completion().expect("no stall");
        assert!(par.is_done());
        let r = par.result();
        let total: f64 = r.flows.iter().map(|f| f.bytes).sum();
        assert!((total - (16.0 + 16.0 + 8.0) * MB).abs() < 1.0, "total={total}");
        for i in 0..3 {
            assert!(!par.is_live(i));
            assert!(par.residual_bytes(i) < 1.0);
        }
    }

    /// Merged runs still agree with a monolithic engine that saw the
    /// same flow sequence (same issue order, same epoch boundary).
    #[test]
    fn merge_preserves_physics_vs_monolithic() {
        let t = Topology::paper();
        let gpn = t.gpus_per_node;
        let base = disjoint_flows(&t);
        let bridge_path = candidates(&t, 0, gpn + 1, true).remove(0);
        let epoch = 0.0002;

        let mut par = PartitionedPacket::new(&t, params_with(2), &base);
        par.advance_to(epoch).expect("no stall");
        par.add_flows(&[Flow::new(bridge_path.clone(), 8.0 * MB).at(epoch)]);
        par.run_to_completion().expect("no stall");
        let rp = par.result();

        let mut mono = PacketSim::new(&t, FabricParams::default(), &base);
        mono.advance_to(epoch).expect("no stall");
        mono.add_flows(&[Flow::new(bridge_path, 8.0 * MB).at(epoch)]);
        mono.run_to_completion().expect("no stall");
        let rm = mono.result();

        // the components' internal event interleavings are identical
        // (disjoint state), so even finish times agree bitwise
        for (a, b) in rp.flows.iter().zip(&rm.flows) {
            assert_eq!(a.finish_t.to_bits(), b.finish_t.to_bits());
        }
        assert_eq!(rp.link_bytes, rm.link_bytes);
    }

    /// Faults broadcast to every component, including ones created
    /// after the fault (the log replays onto them).
    #[test]
    fn faults_reach_components_created_later() {
        let t = Topology::paper();
        let gpn = t.gpus_per_node;
        let first = disjoint_flows(&t);
        let mut par = PartitionedPacket::new(&t, params_with(1), &first[..1]);
        // degrade node 1's rail-0 before its component exists
        let p1 = candidates(&t, gpn, gpn + 1, false).remove(0);
        par.apply_fault(&Fault::StragglerNode { node: 1, inject_factor: 0.25 });
        par.add_flows(&[Flow::new(p1.clone(), 16.0 * MB)]);
        assert_eq!(par.num_components(), 2);
        par.run_to_completion().expect("no stall");
        let slow = par.result().flows[1].finish_t;

        let mut healthy = PartitionedPacket::new(&t, params_with(1), &first[..1]);
        healthy.add_flows(&[Flow::new(p1, 16.0 * MB)]);
        healthy.run_to_completion().expect("no stall");
        let fast = healthy.result().flows[1].finish_t;
        assert!(
            slow > 1.5 * fast,
            "late component ignored the straggler fault: {slow} vs {fast}"
        );
    }

    /// Both schedulers drive the partitioned wrapper to byte-identical
    /// outcomes (the sub-simulation equivalence lifts through the
    /// canonical merge).
    #[test]
    fn partitioned_wheel_matches_partitioned_heap() {
        let t = Topology::paper();
        let flows = disjoint_flows(&t);
        let drive = |kind: SchedulerKind| {
            let mut p = params_with(2);
            p.packet.scheduler = kind;
            let mut par = PartitionedPacket::new(&t, p, &flows);
            par.set_trace(true);
            par.run_to_completion().expect("no stall");
            (par.trace(), par.result(), par.events())
        };
        let (tw, rw, ew) = drive(SchedulerKind::Wheel);
        let (th, rh, eh) = drive(SchedulerKind::Heap);
        assert_eq!(tw, th);
        assert_eq!(ew, eh);
        assert_eq!(rw.makespan.to_bits(), rh.makespan.to_bits());
        assert_eq!(rw.link_bytes, rh.link_bytes);
    }
}
