//! Chunk-level model of the paper's §IV-C kernel pipeline.
//!
//! A message of `bytes` is cut into chunks that advance hop-by-hop
//! through the path. Each intermediate GPU holds a small P2P staging
//! buffer; a chunk may be pushed over hop *h* only when the buffer at
//! the receiving end of *h* has a free slot — exactly the
//! sent/received counter-pair flow control the paper describes. The
//! sender never overruns a relay (credits), and steady-state
//! throughput is set by the slowest stage, which is why the planner
//! prices a path by its **max** link cost rather than the sum.
//!
//! Stage service times:
//! * NVLink hop from the source GPU: `chunk/cap + chunk_ovh`
//! * NVLink hop leaving a relay GPU: `chunk/(ρ·cap) + chunk_ovh`
//!   (relay pass-through reads + rewrites HBM/L2)
//! * NIC rail hop: `chunk/cap + rdma_post` (CPU thread posts the WQE)
//!
//! Exact finish time via the standard blocking-pipeline recurrence
//! (chunk-major DP with credit back-pressure).

use super::{gbps_to_bps, FabricParams, XferMode};
use crate::topology::{LinkKind, Path, Topology};

/// Result of a single pipelined transfer.
#[derive(Clone, Copy, Debug)]
pub struct PipeResult {
    pub finish_s: f64,
    pub chunks: usize,
    /// Steady-state (bottleneck-stage) rate in GB/s.
    pub steady_gbps: f64,
}

impl PipeResult {
    pub fn gbps(&self, bytes: f64) -> f64 {
        bytes / self.finish_s / 1e9
    }
}

pub struct PipelineModel<'a> {
    pub topo: &'a Topology,
    pub params: FabricParams,
}

impl<'a> PipelineModel<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams) -> Self {
        PipelineModel { topo, params }
    }

    /// Per-chunk service time (seconds) of hop index `h` on `path`.
    fn stage_service_s(&self, path: &Path, h: usize, chunk: f64) -> f64 {
        let p = &self.params;
        let link = self.topo.link(path.hops[h]);
        match link.kind {
            LinkKind::NvLink => {
                let cap = if h > 0 {
                    // leaving a relay GPU: pass-through penalty
                    p.relay_rho * link.cap_gbps
                } else {
                    link.cap_gbps
                };
                chunk / gbps_to_bps(cap) + p.chunk_ovh_us * 1e-6
            }
            LinkKind::Rail { .. } | LinkKind::CrossRail { .. } | LinkKind::LeafUp { .. } => {
                chunk / gbps_to_bps(link.cap_gbps) + p.rdma_post_us * 1e-6
            }
            // switch-internal forwarding: store-and-forward
            // serialization only, no per-chunk CPU posting
            LinkKind::LeafDown { .. }
            | LinkKind::SpineUp { .. }
            | LinkKind::SpineDown { .. } => chunk / gbps_to_bps(link.cap_gbps),
        }
    }

    /// Simulate one message over `path`. `chunk` defaults to
    /// `params.chunk_bytes` (clamped so there are ≥1 chunks).
    pub fn transfer(&self, path: &Path, bytes: f64, mode: XferMode) -> PipeResult {
        let p = &self.params;
        let chunk = p.chunk_bytes.min(bytes).max(1.0);
        let n = (bytes / chunk).ceil() as usize;
        let hops = path.hops.len();
        // credits: how many chunks each staging buffer holds
        let credits = ((p.p2p_buf_bytes / chunk).floor() as usize).max(1);
        let start = p.start_latency_s(path, mode);
        let svc: Vec<f64> = (0..hops).map(|h| self.stage_service_s(path, h, chunk)).collect();

        // depart[h] = departure time of the *previous* chunk from hop h;
        // window[h][k mod credits] = departure time of chunk k from hop h
        // (needed for the credit constraint of hop h-1).
        let mut prev_depart = vec![start; hops];
        let mut ring: Vec<Vec<f64>> = vec![vec![f64::NEG_INFINITY; credits]; hops];
        let mut last = start;
        for k in 0..n {
            let mut arrive = start; // chunk ready at the source immediately
            for h in 0..hops {
                let mut t = arrive.max(prev_depart[h]);
                // credit back-pressure: buffer at the receiving end of
                // hop h (which feeds hop h+1) must have a free slot —
                // chunk k-credits must have departed hop h+1.
                if h + 1 < hops && k >= credits {
                    t = t.max(ring[h + 1][(k - credits) % credits]);
                }
                let depart = t + svc[h];
                prev_depart[h] = depart;
                ring[h][k % credits] = depart;
                arrive = depart;
            }
            last = arrive;
        }
        let bottleneck = svc.iter().cloned().fold(0.0, f64::max);
        PipeResult {
            finish_s: last,
            chunks: n,
            steady_gbps: chunk / bottleneck / 1e9,
        }
    }

    /// Achieved bandwidth for a message size (GB/s).
    pub fn bandwidth_gbps(&self, path: &Path, bytes: f64, mode: XferMode) -> f64 {
        self.transfer(path, bytes, mode).gbps(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::path::{candidates, cross_rail_path};
    use crate::topology::Topology;

    const MB: f64 = 1024.0 * 1024.0;

    fn model(t: &Topology) -> PipelineModel<'_> {
        PipelineModel::new(t, FabricParams::default())
    }

    #[test]
    fn direct_large_message_near_peak() {
        let t = Topology::paper();
        let m = model(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let bw = m.bandwidth_gbps(&p, 1024.0 * MB, XferMode::Kernel);
        assert!(bw > 80.0 && bw <= 120.0, "bw={bw}");
    }

    /// Fig 6c shape: 2-hop standalone pays the relay penalty; the
    /// relative overhead shrinks as the message grows.
    #[test]
    fn two_hop_overhead_shrinks_with_size() {
        let t = Topology::paper();
        let m = model(&t);
        let cands = candidates(&t, 0, 1, true);
        let (direct, two_hop) = (&cands[0], &cands[1]);
        let ratio = |bytes: f64| {
            m.bandwidth_gbps(two_hop, bytes, XferMode::Kernel)
                / m.bandwidth_gbps(direct, bytes, XferMode::Kernel)
        };
        let small = ratio(1.0 * MB);
        let large = ratio(256.0 * MB);
        assert!(large > small, "overhead should amortize: {small} vs {large}");
        // large-message 2-hop ≈ ρ of direct
        assert!((large - 0.776).abs() < 0.1, "large ratio {large}");
    }

    /// Fig 6d shape: on an inter-node path the NIC is the bottleneck,
    /// so GPU forwarding for rail-matching costs almost nothing.
    #[test]
    fn inter_node_forwarding_is_cheap() {
        let t = Topology::paper();
        let m = model(&t);
        let inter = candidates(&t, 1, 6, true);
        let matched_direct = inter
            .iter()
            .find(|p| p.hops.len() == 2) // rail 1: no src-side hop
            .unwrap();
        let forwarded = inter.iter().find(|p| p.hops.len() == 3).unwrap();
        let big = 256.0 * MB;
        let a = m.bandwidth_gbps(matched_direct, big, XferMode::Kernel);
        let b = m.bandwidth_gbps(forwarded, big, XferMode::Kernel);
        assert!(b / a > 0.93, "forwarding overhead too high: {a} vs {b}");
    }

    #[test]
    fn cross_rail_worse_than_matched() {
        let t = Topology::paper();
        let m = model(&t);
        let big = 128.0 * MB;
        let matched = candidates(&t, 0, 5, true)
            .into_iter()
            .find(|p| p.hops.len() == 2)
            .unwrap();
        let cross = cross_rail_path(&t, 0, 5).unwrap();
        let a = m.bandwidth_gbps(&matched, big, XferMode::Kernel);
        let b = m.bandwidth_gbps(&cross, big, XferMode::Kernel);
        assert!(b < a, "cross-rail {b} should lose to matched {a}");
    }

    #[test]
    fn steady_state_matches_bottleneck_stage() {
        let t = Topology::paper();
        let m = model(&t);
        let p = candidates(&t, 1, 6, true).remove(0);
        let r = m.transfer(&p, 512.0 * MB, XferMode::Kernel);
        // achieved bw approaches the steady-state (bottleneck stage) rate
        let bw = r.gbps(512.0 * MB);
        assert!(bw / r.steady_gbps > 0.9, "bw={bw} steady={}", r.steady_gbps);
        assert!(bw <= r.steady_gbps * 1.001);
    }

    #[test]
    fn single_chunk_message() {
        let t = Topology::paper();
        let m = model(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let r = m.transfer(&p, 1000.0, XferMode::Kernel);
        assert_eq!(r.chunks, 1);
        assert!(r.finish_s > 0.0);
    }

    #[test]
    fn tiny_credits_still_complete() {
        let t = Topology::paper();
        let defaults = FabricParams::default();
        // 1 credit: staging buffer holds exactly one chunk
        let params = FabricParams { p2p_buf_bytes: defaults.chunk_bytes, ..defaults };
        let m = PipelineModel::new(&t, params);
        let p = candidates(&t, 0, 1, true).remove(1); // 2-hop
        let r = m.transfer(&p, 16.0 * MB, XferMode::Kernel);
        assert!(r.finish_s.is_finite() && r.finish_s > 0.0);
        // 1-credit pipeline serializes: strictly slower than default
        let m2 = model(&t);
        let r2 = m2.transfer(&p, 16.0 * MB, XferMode::Kernel);
        assert!(r.finish_s > r2.finish_s);
    }
}
