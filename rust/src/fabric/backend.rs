//! The fabric-backend trait behind the execution-time loop.
//!
//! [`FabricBackend`] is the exact surface the coordinator's monitor →
//! replan → reroute loop ([`crate::coordinator::ReplanExecutor`])
//! drives: issue flows on planned paths, advance virtual time to a
//! replan epoch, sample per-link byte windows, preempt a flow's
//! residual bytes and re-issue them on new paths. Extracting it as a
//! trait makes the loop backend-agnostic:
//!
//! * [`SimEngine`] (fluid, [`BackendKind::Fluid`], the default) — the
//!   resumable max-min engine every pre-existing experiment runs on;
//!   selecting it routes through the identical code path, so results
//!   stay **bit-identical** to the pre-trait executor.
//! * [`PartitionedPacket`] ([`BackendKind::Packet`]) — the
//!   chunk-granular discrete-event simulator, the only backend that
//!   can report queueing delay and tail latency
//!   ([`FabricBackend::tail`]). It runs one [`PacketSim`] per
//!   node-disjoint flow component and merges observations in canonical
//!   order, so results are byte-identical for every thread count
//!   (`[fabric.packet] threads`).
//!
//! `nimble xcheck` cross-validates fluid and packet (same flows, both
//! backends, goodput agreement within a stated tolerance — DESIGN.md
//! §10).
//!
//! ## Adding a third backend
//!
//! Implement the trait (the engine owns its own event representation;
//! nothing outside the `fabric` module sees events), add a variant to
//! [`BackendKind`] and a match arm in [`make_backend`], and extend the
//! `tests/fabric_props.rs` conservation properties to cover it. The
//! coordinator, monitor and planner need no changes.

use super::fluid::{Flow, SimEngine, SimResult};
use super::packet::PacketSim;
use super::packet_par::PartitionedPacket;
use super::{BackendKind, FabricParams};
use crate::topology::Topology;
use crate::util::hist::LatencyHist;
use std::collections::BTreeMap;
use std::fmt;

/// One tenant/pair contributor to a link's window bytes: the blame key
/// is ([`Flow::tag`], src GPU, dst GPU) and the value is the bytes that
/// contributor completed across the link during the window.
pub type BlameKey = (u64, usize, usize);

/// Attribution of one monitoring window
/// ([`FabricBackend::take_window_attr`]): the per-link byte totals the
/// monitor consumes plus, per link, the decomposition of those bytes by
/// (tenant tag, src, dst).
///
/// **Conservation invariant (DESIGN.md §16):** `totals` is computed by
/// summing each link's blame entries in ascending key order, and
/// [`FabricBackend::take_window`] runs the *same* canonical summation —
/// so summing `blame[l]` in listed order reproduces `totals[l]`
/// bit-exactly, and an attribution-sampling run feeds the monitor the
/// bit-identical totals a plain `take_window` run would (the observer-
/// purity contract).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowAttr {
    /// Per-link window bytes (the exact `take_window` payload).
    pub totals: Vec<f64>,
    /// Per-link blame entries, sorted ascending by key. Empty for
    /// backends that do not attribute (the trait default).
    pub blame: Vec<Vec<(BlameKey, f64)>>,
}

/// The canonical blame reduction: per-flow window contributions arrive
/// bucketed per link by (tag, src, dst) (the `BTreeMap` fixes the key
/// order), and each link's total is the sum of its bucket values in
/// ascending key order. f64 addition is not associative, so fixing
/// this one summation order — and routing `take_window` *and*
/// `take_window_attr` through it — is what makes the per-link totals
/// bit-identical in both modes and the blame sums conserve bit-exactly.
pub(crate) fn reduce_blame(per_link: Vec<BTreeMap<BlameKey, f64>>) -> WindowAttr {
    let mut totals = Vec::with_capacity(per_link.len());
    let mut blame = Vec::with_capacity(per_link.len());
    for m in per_link {
        let entries: Vec<(BlameKey, f64)> = m.into_iter().collect();
        let mut t = 0.0f64;
        for &(_, b) in &entries {
            t += b;
        }
        totals.push(t);
        blame.push(entries);
    }
    WindowAttr { totals, blame }
}

/// A fabric advance that cannot make progress: live flows remain but
/// the event queue is empty, so no future event will ever deliver
/// them. Reached through zero-capacity misconfiguration — a link left
/// dead with no restore scheduled, every path of a flow down — and
/// reported as a typed error (it used to be a panic deep inside the
/// event loop) so callers can surface *which* run wedged and when.
///
/// Only an **unbounded** advance reports this: a bounded epoch advance
/// that runs out of events simply waits at the epoch boundary for the
/// coordinator's next decision (replanning around the dead link is the
/// recovery mechanism, DESIGN.md §13).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FabricStall {
    /// Flows still live (not delivered, not preempted) at the stall.
    pub live_flows: usize,
    /// Virtual time (seconds) the engine had reached.
    pub t_s: f64,
}

impl fmt::Display for FabricStall {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "fabric stalled at t={:.6}s: {} live flow(s) but no pending events \
             (zero-capacity path or un-restored dead link)",
            self.t_s, self.live_flows
        )
    }
}

impl std::error::Error for FabricStall {}

/// Queueing/latency observations only a discrete-event backend can
/// produce ([`FabricBackend::tail`]). Latency distributions are kept
/// as deterministic log-bucketed streaming histograms
/// ([`LatencyHist`], DESIGN.md §16) so memory stays bounded over
/// long-horizon runs: O(log range) buckets instead of O(chunks)
/// samples. Histograms merge by exact bucket-count addition, which is
/// what the partitioned packet engine's canonical component merge
/// relies on. The percentile reduction lives in
/// [`crate::metrics::TailReport`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TailStats {
    /// Per delivered chunk: issue (incl. setup latency) → delivery.
    pub sojourn: LatencyHist,
    /// Per delivered chunk: first-queue entry → delivery (the pure
    /// network transit + queueing component).
    pub transit: LatencyHist,
    /// Sojourn latency histograms grouped by (src, dst) pair.
    pub per_pair_sojourn: BTreeMap<(usize, usize), LatencyHist>,
    /// Sojourn latency histograms grouped by [`Flow::tag`] (the
    /// multi-tenant orchestrator stamps the tenant/job id; untagged
    /// flows land under 0).
    pub per_tag_sojourn: BTreeMap<u64, LatencyHist>,
    /// Peak queued bytes per link (excludes the cell in service).
    pub peak_queue_bytes: Vec<f64>,
    /// Peak queued bytes per destination GPU's receive stage.
    pub peak_recv_queue_bytes: Vec<f64>,
    /// Chunks delivered end-to-end.
    pub delivered_chunks: u64,
    /// Exact per-chunk sojourn samples (seconds, delivery order).
    /// Populated only in the `exact_tail` debug mode
    /// (`PacketParams::exact_tail`) — the unbounded-memory oracle the
    /// histogram error bound is tested against.
    pub sojourn_exact_s: Vec<f64>,
    /// Exact per-chunk transit samples (debug mode only, see
    /// [`TailStats::sojourn_exact_s`]).
    pub transit_exact_s: Vec<f64>,
}

/// Engine self-profiling counters ([`FabricBackend::profile`]) — the
/// raw ingredients of the telemetry `profile` record. Counters are
/// simulation-deterministic (no wall clock): for the packet engine
/// `sched_pushes`/`sched_pops` count scheduler operations (wheel or
/// heap), for the fluid engine `solver_invocations` counts max-min
/// rate solves. [`PartitionedPacket`] merges per-component counters in
/// canonical component order, so the totals are thread-count
/// invariant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineProfile {
    /// Events processed (same unit as [`FabricBackend::events`]).
    pub events: u64,
    /// Scheduler insertions (packet backends; 0 for fluid).
    pub sched_pushes: u64,
    /// Scheduler removals (packet backends; 0 for fluid).
    pub sched_pops: u64,
    /// Max-min rate solves (fluid backend; 0 for packet).
    pub solver_invocations: u64,
}

/// The surface [`crate::coordinator::ReplanExecutor`] needs from a
/// fabric simulation engine. Flow indices are issue order, exactly as
/// [`SimEngine`] numbers them.
pub trait FabricBackend {
    /// Register additional flows (initial issue or re-issued residuals
    /// at a replan epoch); returns the index of the first new flow.
    fn add_flows(&mut self, flows: &[Flow]) -> usize;
    /// Advance the event loop until `t_stop` (a replan epoch boundary)
    /// or until every flow completes, whichever comes first. An
    /// unbounded advance that wedges reports [`FabricStall`].
    fn advance_to(&mut self, t_stop: f64) -> Result<(), FabricStall>;
    /// Run every remaining event (no epoch bound).
    fn run_to_completion(&mut self) -> Result<(), FabricStall> {
        self.advance_to(f64::INFINITY)
    }
    /// All flows delivered or preempted.
    fn is_done(&self) -> bool;
    /// Current virtual time (seconds).
    fn now(&self) -> f64;
    /// Events processed so far (the unit of `events/sec` throughput).
    fn events(&self) -> u64;
    /// Bytes flow `i` still has to deliver (0 once finished/preempted).
    fn residual_bytes(&self, i: usize) -> f64;
    /// Bytes flow `i` has delivered so far.
    fn moved_bytes(&self, i: usize) -> f64;
    /// Whether flow `i` is still in flight (issued or queued).
    fn is_live(&self, i: usize) -> bool;
    /// The flow registered under index `i`.
    fn flow(&self, i: usize) -> &Flow;
    /// Preempt flow `i` mid-transfer; returns its residual bytes for
    /// re-issue on other paths via [`FabricBackend::add_flows`].
    fn preempt(&mut self, i: usize) -> f64;
    /// Apply a fault event to the running fabric (link death/recovery,
    /// rail degradation, straggler throttle — see
    /// [`crate::fabric::faults`]). Fault-free runs never call this, so
    /// they stay bit-identical to builds without the fault layer.
    fn apply_fault(&mut self, fault: &super::faults::Fault);
    /// Per-link bytes moved since the previous call (the monitor's
    /// sampling window); resets the window counters.
    fn take_window(&mut self) -> Vec<f64>;
    /// Like [`FabricBackend::take_window`], but also decomposes each
    /// link's window bytes by (tenant tag, src, dst). `totals` carries
    /// the bit-identical bytes `take_window` would have returned (see
    /// [`WindowAttr`]); the default for attribution-less backends
    /// returns empty blame.
    fn take_window_attr(&mut self) -> WindowAttr {
        WindowAttr { totals: self.take_window(), blame: Vec::new() }
    }
    /// Snapshot the outcome (same shape for every backend).
    fn result(&self) -> SimResult;
    /// Latency/queue-depth observations, when the backend records them
    /// (the packet backend does; the fluid backend cannot).
    fn tail(&self) -> Option<TailStats> {
        None
    }
    /// Self-profiling counters (telemetry `profile` record). The
    /// default reports only [`FabricBackend::events`]; backends
    /// override to expose their scheduler/solver counters.
    fn profile(&self) -> EngineProfile {
        EngineProfile { events: self.events(), ..Default::default() }
    }
}

/// Instantiate the backend `params.backend` selects, seeded with
/// `flows`. [`BackendKind::Fluid`] constructs the same [`SimEngine`]
/// the pre-trait executor did — byte-for-byte the same trajectory.
/// [`BackendKind::Packet`] constructs the partitioned engine; with a
/// single connected flow component it degenerates to exactly one
/// [`PacketSim`] flown inline, so its physics and traces match the
/// monolithic engine's.
pub fn make_backend<'a>(
    topo: &'a Topology,
    params: FabricParams,
    flows: &[Flow],
) -> Box<dyn FabricBackend + 'a> {
    match params.backend {
        BackendKind::Fluid => Box::new(SimEngine::new(topo, params, flows)),
        BackendKind::Packet => Box::new(PartitionedPacket::new(topo, params, flows)),
    }
}

impl<'a> FabricBackend for SimEngine<'a> {
    fn add_flows(&mut self, flows: &[Flow]) -> usize {
        SimEngine::add_flows(self, flows)
    }
    fn advance_to(&mut self, t_stop: f64) -> Result<(), FabricStall> {
        SimEngine::advance_to(self, t_stop);
        // the fluid engine solves rates in closed form each step and
        // cannot wedge: a zero-rate flow still has a finite next event
        Ok(())
    }
    fn is_done(&self) -> bool {
        SimEngine::is_done(self)
    }
    fn now(&self) -> f64 {
        SimEngine::now(self)
    }
    fn events(&self) -> u64 {
        SimEngine::events(self)
    }
    fn residual_bytes(&self, i: usize) -> f64 {
        SimEngine::residual_bytes(self, i)
    }
    fn moved_bytes(&self, i: usize) -> f64 {
        SimEngine::moved_bytes(self, i)
    }
    fn is_live(&self, i: usize) -> bool {
        SimEngine::is_live(self, i)
    }
    fn flow(&self, i: usize) -> &Flow {
        SimEngine::flow(self, i)
    }
    fn preempt(&mut self, i: usize) -> f64 {
        SimEngine::preempt(self, i)
    }
    fn apply_fault(&mut self, fault: &super::faults::Fault) {
        SimEngine::apply_fault(self, fault)
    }
    fn take_window(&mut self) -> Vec<f64> {
        SimEngine::take_window(self)
    }
    fn take_window_attr(&mut self) -> WindowAttr {
        SimEngine::take_window_attr(self)
    }
    fn result(&self) -> SimResult {
        SimEngine::result(self)
    }
    fn profile(&self) -> EngineProfile {
        // the fluid engine's event unit IS a rate solve: each step
        // re-solves max-min rates for the active flow set
        let e = SimEngine::events(self);
        EngineProfile { events: e, solver_invocations: e, ..Default::default() }
    }
}

impl<'a> FabricBackend for PacketSim<'a> {
    fn add_flows(&mut self, flows: &[Flow]) -> usize {
        PacketSim::add_flows(self, flows)
    }
    fn advance_to(&mut self, t_stop: f64) -> Result<(), FabricStall> {
        PacketSim::advance_to(self, t_stop)
    }
    fn is_done(&self) -> bool {
        PacketSim::is_done(self)
    }
    fn now(&self) -> f64 {
        PacketSim::now(self)
    }
    fn events(&self) -> u64 {
        PacketSim::events(self)
    }
    fn residual_bytes(&self, i: usize) -> f64 {
        PacketSim::residual_bytes(self, i)
    }
    fn moved_bytes(&self, i: usize) -> f64 {
        PacketSim::moved_bytes(self, i)
    }
    fn is_live(&self, i: usize) -> bool {
        PacketSim::is_live(self, i)
    }
    fn flow(&self, i: usize) -> &Flow {
        PacketSim::flow(self, i)
    }
    fn preempt(&mut self, i: usize) -> f64 {
        PacketSim::preempt(self, i)
    }
    fn apply_fault(&mut self, fault: &super::faults::Fault) {
        PacketSim::apply_fault(self, fault)
    }
    fn take_window(&mut self) -> Vec<f64> {
        PacketSim::take_window(self)
    }
    fn take_window_attr(&mut self) -> WindowAttr {
        PacketSim::take_window_attr(self)
    }
    fn result(&self) -> SimResult {
        PacketSim::result(self)
    }
    fn tail(&self) -> Option<TailStats> {
        Some(PacketSim::tail(self))
    }
    fn profile(&self) -> EngineProfile {
        PacketSim::profile(self)
    }
}

impl<'a> FabricBackend for PartitionedPacket<'a> {
    fn add_flows(&mut self, flows: &[Flow]) -> usize {
        PartitionedPacket::add_flows(self, flows)
    }
    fn advance_to(&mut self, t_stop: f64) -> Result<(), FabricStall> {
        PartitionedPacket::advance_to(self, t_stop)
    }
    fn is_done(&self) -> bool {
        PartitionedPacket::is_done(self)
    }
    fn now(&self) -> f64 {
        PartitionedPacket::now(self)
    }
    fn events(&self) -> u64 {
        PartitionedPacket::events(self)
    }
    fn residual_bytes(&self, i: usize) -> f64 {
        PartitionedPacket::residual_bytes(self, i)
    }
    fn moved_bytes(&self, i: usize) -> f64 {
        PartitionedPacket::moved_bytes(self, i)
    }
    fn is_live(&self, i: usize) -> bool {
        PartitionedPacket::is_live(self, i)
    }
    fn flow(&self, i: usize) -> &Flow {
        PartitionedPacket::flow(self, i)
    }
    fn preempt(&mut self, i: usize) -> f64 {
        PartitionedPacket::preempt(self, i)
    }
    fn apply_fault(&mut self, fault: &super::faults::Fault) {
        PartitionedPacket::apply_fault(self, fault)
    }
    fn take_window(&mut self) -> Vec<f64> {
        PartitionedPacket::take_window(self)
    }
    fn take_window_attr(&mut self) -> WindowAttr {
        PartitionedPacket::take_window_attr(self)
    }
    fn result(&self) -> SimResult {
        PartitionedPacket::result(self)
    }
    fn tail(&self) -> Option<TailStats> {
        Some(PartitionedPacket::tail(self))
    }
    fn profile(&self) -> EngineProfile {
        PartitionedPacket::profile(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::faults::Fault;
    use crate::topology::path::candidates;

    const MB: f64 = 1024.0 * 1024.0;

    /// Driving the fluid engine through the trait object is the same
    /// code path as driving it directly — bit-identical results (the
    /// guarantee that keeps every pre-trait experiment unchanged).
    #[test]
    fn fluid_backend_matches_direct_engine_bitwise() {
        let topo = Topology::paper();
        let cands = candidates(&topo, 0, 1, true);
        let flows = vec![
            Flow::new(cands[0].clone(), 96.0 * MB),
            Flow::new(cands[1].clone(), 48.0 * MB).at(0.0004),
        ];
        let mut direct = SimEngine::new(&topo, FabricParams::default(), &flows);
        direct.run_to_completion();
        let a = direct.result();

        let mut boxed = make_backend(&topo, FabricParams::default(), &flows);
        boxed.run_to_completion().expect("fluid cannot stall");
        let b = boxed.result();

        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.link_bytes, b.link_bytes);
        for (x, y) in a.flows.iter().zip(&b.flows) {
            assert_eq!(x.finish_t.to_bits(), y.finish_t.to_bits());
        }
        assert!(boxed.tail().is_none(), "fluid backend cannot observe tails");
    }

    /// The selector actually switches implementations.
    #[test]
    fn selector_picks_packet_backend() {
        let topo = Topology::paper();
        let p = candidates(&topo, 0, 1, false).remove(0);
        let mut params = FabricParams { backend: BackendKind::Packet, ..Default::default() };
        params.packet.cell_bytes = 64.0 * 1024.0;
        let mut be = make_backend(&topo, params, &[Flow::new(p, 4.0 * MB)]);
        be.run_to_completion().expect("no stall");
        assert!(be.is_done());
        let tail = be.tail().expect("packet backend records tails");
        assert_eq!(tail.delivered_chunks, 64, "4 MB / 64 KB cells");
        assert_eq!(tail.sojourn.total(), 64);
        assert!(tail.sojourn_exact_s.is_empty(), "exact oracle is opt-in");
    }

    /// Regression for the old `"stuck: packet simulation has live
    /// flows but no events"` panic: a zero-capacity misconfiguration
    /// (a flow's only link dead with no restore scheduled) now surfaces
    /// the typed [`FabricStall`] through the trait instead of aborting
    /// the process.
    #[test]
    fn zero_capacity_run_reports_stall_through_trait() {
        let topo = Topology::paper();
        let p = candidates(&topo, 0, 4, false).remove(0); // single rail hop
        let link = p.hops[0];
        let params = FabricParams { backend: BackendKind::Packet, ..Default::default() };
        let mut be = make_backend(&topo, params, &[Flow::new(p, 8.0 * MB)]);
        be.apply_fault(&Fault::LinkDown { link });
        let err = be.run_to_completion().expect_err("dead link must stall");
        assert_eq!(err.live_flows, 1);
        assert!(err.t_s >= 0.0);
        assert!(!be.is_done());
        // the error formats with enough context to diagnose the wedge
        let msg = err.to_string();
        assert!(msg.contains("live flow"), "unhelpful stall message: {msg}");
        // a bounded epoch advance over the same wedge is NOT an error:
        // the coordinator replans at the boundary instead
        be.advance_to(be.now() + 0.001).expect("bounded advance waits");
    }
}
